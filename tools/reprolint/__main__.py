"""CLI: ``python -m tools.reprolint src/`` — exit 0 when clean, 1 with
``path:line: [check] message`` diagnostics otherwise."""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    from tools.reprolint import run_checks
    from tools.reprolint.checks import CHECKS, load_all
    load_all()
    p = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="repo-specific static analysis for the FastCache "
                    "serving stack")
    p.add_argument("roots", nargs="*", default=["src"],
                   help="package roots to scan (default: src)")
    p.add_argument("--static-only", action="store_true",
                   help="AST checks only: skip the runtime policy-registry "
                        "validation (no jax import)")
    p.add_argument("--tests-dir", default=None,
                   help="tests directory for kernel-parity "
                        "(default: <root>/../tests)")
    p.add_argument("--checks", default=None,
                   help="comma-separated subset of checks to run")
    p.add_argument("--list-checks", action="store_true")
    args = p.parse_args(argv)

    if args.list_checks:
        for name in sorted(CHECKS):
            doc = (sys.modules[CHECKS[name].__module__].__doc__
                   or "").strip().splitlines()[0]
            print(f"{name:22s} {doc}")
        return 0

    checks = ([c.strip() for c in args.checks.split(",") if c.strip()]
              if args.checks else None)
    diags = []
    for root in args.roots:
        diags.extend(run_checks(root, checks=checks,
                                static_only=args.static_only,
                                tests_dir=args.tests_dir))
    for d in diags:
        print(d)
    if diags:
        print(f"reprolint: {len(diags)} finding(s)", file=sys.stderr)
        return 1
    print("reprolint: clean", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
