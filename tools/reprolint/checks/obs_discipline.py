"""obs-discipline: the observability plane's two standing rules.

1. **Unique metric names.**  Every metric is registered through the
   ``counter(...)`` / ``histogram(...)`` helpers of the obs metrics
   module; registering the same name twice means two call sites believe
   they own the series and their increments silently merge.  The runtime
   registry raises on conflicting re-registration, but only on the code
   path that actually imports both sites — this check catches it
   statically across the whole tree.

2. **``MetricsCollector.harvest`` stays off the jit path.**  Harvest is
   the metrics plane's ONLY device->host sync point, sanctioned at run
   end / window close on the host orchestration path.  A harvest call
   reachable from a jit root would either fail at trace time or — worse —
   silently pin device values into the trace and force per-step syncs,
   exactly what the device-resident design exists to prevent.  Reuses the
   JitScope call graph: any call in a jit-reachable function that
   resolves to a ``harvest`` method of a ``MetricsCollector`` class is
   flagged.

3. **Audit calls stay statically guarded.**  The shadow-compute audit
   plane (the obs package's ``audit`` module) roughly doubles an audited
   step; the engines' contract is that with ``audit_fraction == 0`` the
   whole plane is *statically dead* — not traced, not compiled.  The only
   construct that guarantees that is a host-side Python ``if`` on a
   static flag, so: any call from jit-reachable code *outside* the audit
   module that resolves into the audit module must sit lexically inside
   an ``if`` whose test mentions an audit-named flag (``self._audit_on``,
   ``audit_fraction``, ...).  A ``lax.cond``/``jnp.where`` guard does NOT
   count — both branches still trace.

The registration helpers are recognized structurally (functions named
``counter``/``histogram`` defined in an ``obs`` module; collectors as
classes named ``MetricsCollector``; the audit plane as any module named
``audit`` inside an ``obs`` package), so fixture trees exercise the check
without importing the real package.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from tools.reprolint.checks import LintContext, register_check
from tools.reprolint.diagnostics import Diagnostic
from tools.reprolint.jitscope import own_nodes

REGISTER_FN_NAMES = ("counter", "histogram")
COLLECTOR_CLASS = "MetricsCollector"
HARVEST_METHOD = "harvest"
AUDIT_MODULE = "audit"


def _is_obs_module(module: str) -> bool:
    parts = module.split(".")
    return "obs" in parts


def _is_audit_module(module: str) -> bool:
    parts = module.split(".")
    return "obs" in parts and parts[-1] == AUDIT_MODULE


def _register_fns(ctx: LintContext) -> Set[str]:
    """Qualnames of the metric-registration helpers: top-level functions
    named counter/histogram living in an ``obs`` package module."""
    out: Set[str] = set()
    for qn, fi in ctx.index.functions.items():
        if fi.cls is None and fi.name in REGISTER_FN_NAMES \
                and _is_obs_module(fi.module):
            out.add(qn)
    return out


def _harvest_fns(ctx: LintContext) -> Set[str]:
    """Qualnames of ``MetricsCollector.harvest`` methods (any class of
    that name, across the scanned tree)."""
    out: Set[str] = set()
    for ci in ctx.index.classes.values():
        if ci.name == COLLECTOR_CLASS and HARVEST_METHOD in ci.methods:
            out.add(ci.methods[HARVEST_METHOD])
    return out


def _audit_fns(ctx: LintContext) -> Set[str]:
    """Qualnames of every function/method defined in an obs package's
    ``audit`` module — the surface whose call sites rule 3 polices."""
    out: Set[str] = set()
    for qn, fi in ctx.index.functions.items():
        if _is_audit_module(fi.module):
            out.add(qn)
    return out


def _mentions_audit(test: ast.AST) -> bool:
    for n in ast.walk(test):
        if isinstance(n, ast.Name) and "audit" in n.id.lower():
            return True
        if isinstance(n, ast.Attribute) and "audit" in n.attr.lower():
            return True
    return False


def _own_calls_with_guard(fn_node: ast.AST) -> List[Tuple[ast.Call, bool]]:
    """Every Call belonging to this scope (same boundary as
    ``own_nodes``: stops at nested function/class bodies, keeps their
    decorators and inline lambdas), paired with whether it sits lexically
    inside an ``if`` whose test mentions an audit-named flag.  Both the
    body and the else arm count as guarded — only the *static* Python
    branch matters, and either arm is dead for one flag value."""
    out: List[Tuple[ast.Call, bool]] = []

    def rec(node: ast.AST, guarded: bool) -> None:
        for child in ast.iter_child_nodes(node):
            g = guarded
            if isinstance(node, ast.If) and child is not node.test \
                    and _mentions_audit(node.test):
                g = True
            if isinstance(child, ast.Call):
                out.append((child, g))
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                for dec in child.decorator_list:
                    if isinstance(dec, ast.Call):
                        out.append((dec, g))
                    rec(dec, g)
                continue
            rec(child, g)

    rec(fn_node, False)
    return out


def _literal_name(call: ast.Call) -> Optional[str]:
    """The registered metric name when it is a string literal (first
    positional arg or ``name=``); None for computed names."""
    target: Optional[ast.AST] = call.args[0] if call.args else None
    if target is None:
        for kw in call.keywords:
            if kw.arg == "name":
                target = kw.value
    if isinstance(target, ast.Constant) and isinstance(target.value, str):
        return target.value
    return None


@register_check("obs-discipline")
def check(ctx: LintContext) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    register_fns = _register_fns(ctx)
    harvest_fns = _harvest_fns(ctx)

    # ---- rule 1: metric names registered at most once -----------------
    # walk every call site in the tree (module level + function bodies),
    # resolve it through the scope machinery, and track name -> first site
    first_site: Dict[str, Tuple[str, int]] = {}
    if register_fns:
        sites = []
        for mod in ctx.index.modules.values():
            for node in own_nodes(mod.tree):
                if isinstance(node, ast.Call):
                    sites.append((node, None, mod))
        for fi in ctx.index.functions.values():
            mod = ctx.index.modules[fi.module]
            for node in own_nodes(fi.node):
                if isinstance(node, ast.Call):
                    sites.append((node, fi, mod))
        # deterministic order: by file then line
        sites.sort(key=lambda s: (s[2].path, s[0].lineno))
        for node, fi, mod in sites:
            if not ctx.scope.resolve_callable(node.func, fi, mod) \
                    & register_fns:
                continue
            name = _literal_name(node)
            if name is None:
                continue
            prev = first_site.get(name)
            if prev is None:
                first_site[name] = (mod.path, node.lineno)
            elif prev != (mod.path, node.lineno):
                diags.append(Diagnostic(
                    mod.path, node.lineno, "obs-discipline",
                    f"metric {name!r} is already registered at "
                    f"{prev[0]}:{prev[1]}; two registration sites would "
                    f"silently merge their series — reuse the exported "
                    f"name instead"))

    # ---- rule 2: harvest unreachable from any jit region --------------
    if harvest_fns:
        for qn in sorted(ctx.scope.reachable):
            fi = ctx.index.functions[qn]
            mod = ctx.index.modules[fi.module]
            for node in own_nodes(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                if ctx.scope.resolve_callable(node.func, fi, mod) \
                        & harvest_fns:
                    diags.append(Diagnostic(
                        mod.path, node.lineno, "obs-discipline",
                        f"`MetricsCollector.harvest()` called in "
                        f"`{fi.name}`, which is reachable from a jitted "
                        f"entry point; harvest is the metrics plane's "
                        f"only device->host sync and must stay on the "
                        f"host orchestration path (run end / window "
                        f"close)"))
        for hq in sorted(harvest_fns & ctx.scope.reachable):
            fi = ctx.index.functions[hq]
            mod = ctx.index.modules[fi.module]
            diags.append(Diagnostic(
                mod.path, fi.node.lineno, "obs-discipline",
                f"`{fi.qualname}` is itself reachable from a jitted "
                f"entry point; the harvest sync point must never enter "
                f"a trace"))

    # ---- rule 3: audit-plane calls statically guarded -----------------
    audit_fns = _audit_fns(ctx)
    if audit_fns:
        for qn in sorted(ctx.scope.reachable):
            fi = ctx.index.functions[qn]
            if _is_audit_module(fi.module):
                continue        # the plane may call itself freely
            mod = ctx.index.modules[fi.module]
            for call, guarded in _own_calls_with_guard(fi.node):
                if guarded:
                    continue
                if ctx.scope.resolve_callable(call.func, fi, mod) \
                        & audit_fns:
                    diags.append(Diagnostic(
                        mod.path, call.lineno, "obs-discipline",
                        f"audit-plane call in `{fi.name}` (jit-reachable) "
                        f"is not under a static `if <audit flag>:` guard; "
                        f"without one the shadow forward traces into "
                        f"every program even at audit_fraction == 0 — "
                        f"guard the call with the engine's static audit "
                        f"flag (e.g. `if self._audit_on:`)"))
    return diags
