"""host-sync-in-jit: no device->host synchronization inside the jit region.

``float(x)`` / ``int(x)`` / ``x.item()`` / ``np.asarray(x)`` on a traced
array force a concrete value mid-trace: under ``jax.jit`` they either fail
(TracerConversionError) or — when tracing succeeds because the value is
static — silently pin what should be a traced input, forcing a recompile
per value.  On accelerators they stall the dispatch pipeline.  Inside any
function reachable from a jit root (see jitscope), conversions of traced
values are flagged; ``.item()`` / ``.tolist()`` / ``.block_until_ready()``
and ``jax.device_get`` are flagged unconditionally — they have no
legitimate in-trace use.

Host-side code (engine admission, stats summaries) is untouched: it is not
reachable from any jit root.
"""
from __future__ import annotations

import ast
from typing import List

from tools.reprolint.checks import LintContext, register_check
from tools.reprolint.diagnostics import Diagnostic
from tools.reprolint.jitscope import own_nodes

ALWAYS_BAD_METHODS = {"item", "tolist", "block_until_ready"}
CONVERSIONS = {"float", "int"}  # bool() belongs to tracer-control-flow


@register_check("host-sync-in-jit")
def check(ctx: LintContext) -> List[Diagnostic]:
    diags = []
    for qn in sorted(ctx.scope.reachable):
        fi = ctx.index.functions[qn]
        mod = ctx.index.modules[fi.module]
        for node in own_nodes(fi.node):
            if not isinstance(node, ast.Call):
                continue
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in ALWAYS_BAD_METHODS):
                diags.append(Diagnostic(
                    mod.path, node.lineno, "host-sync-in-jit",
                    f"`.{node.func.attr}()` in `{fi.name}` forces a "
                    f"device->host sync inside the jit region "
                    f"(reachable from a jitted entry point)"))
                continue
            resolved = ctx.scope.resolve_external(node.func, mod)
            if resolved == "jax.device_get":
                diags.append(Diagnostic(
                    mod.path, node.lineno, "host-sync-in-jit",
                    f"`jax.device_get` in `{fi.name}` has no in-trace "
                    f"use; it forces a host transfer"))
                continue
            any_tainted = any(ctx.scope.expr_tainted(fi, a)
                              for a in node.args)
            if resolved in CONVERSIONS and any_tainted:
                diags.append(Diagnostic(
                    mod.path, node.lineno, "host-sync-in-jit",
                    f"`{resolved}()` on a traced value in `{fi.name}` "
                    f"concretizes mid-trace; keep it an array "
                    f"(jnp.float32(x) / x.astype) or move it off the "
                    f"jit path"))
            elif (resolved is not None
                  and resolved.split(".")[0] == "numpy" and any_tainted):
                diags.append(Diagnostic(
                    mod.path, node.lineno, "host-sync-in-jit",
                    f"numpy call `{ast.unparse(node.func)}` on a traced "
                    f"value in `{fi.name}` forces a host round-trip; "
                    f"use the jnp equivalent"))
    return diags
