"""no-bare-assert: library code must not rely on ``assert``.

``python -O`` strips asserts, so an assert guarding a shape or contract
silently stops guarding in optimized runs; and a bare assert carries no
message for the caller.  Library code raises ``ValueError`` / ``TypeError``
with a diagnostic message instead.  Tests are exempt (they are never run
under ``-O`` and pytest rewrites asserts) — reprolint only scans the
package root, so this exemption falls out of the scan scope.
"""
from __future__ import annotations

import ast
from typing import List

from tools.reprolint.checks import LintContext, register_check
from tools.reprolint.diagnostics import Diagnostic


@register_check("no-bare-assert")
def check(ctx: LintContext) -> List[Diagnostic]:
    diags = []
    for mod in ctx.index.modules.values():
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assert):
                diags.append(Diagnostic(
                    mod.path, node.lineno, "no-bare-assert",
                    "assert in library code is stripped under `python -O`; "
                    "raise ValueError/TypeError with a message instead"))
    return diags
