"""kernel-parity: every Pallas kernel must have a pure-jnp reference twin
and a test that compares them.

A kernel without a ``ref.py`` counterpart has no ground truth — interpret
mode only proves the kernel agrees with itself.  A twin without a parity
test drifts silently: the kernel gets optimized, the reference doesn't get
re-checked.  For every module under ``kernels/`` that calls
``pl.pallas_call``, each public entry function must (a) exist by the same
name in ``kernels/ref.py`` and (b) be referenced on BOTH sides (``ref.<n>``
and ``ops.<n>`` / the kernel module) by some module in the tests dir.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Set, Tuple

from tools.reprolint.checks import LintContext, register_check
from tools.reprolint.diagnostics import Diagnostic

CHECK = "kernel-parity"
SKIP = ("ops", "ref", "__init__", "")


def _uses_pallas(tree: ast.Module) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            d = ast.unparse(node.func)
            if d.endswith("pallas_call"):
                return True
    return False


def _test_refs(tests_dir: Path, impl_modules: Set[str]
               ) -> Tuple[Set[str], Set[str]]:
    """Names referenced through a ``ref`` alias / an implementation alias
    across all test modules.  ``from repro.kernels.ops import foo`` counts
    as an implementation-side reference to ``foo``."""
    ref_names: Set[str] = set()
    impl_names: Set[str] = set()
    if not tests_dir.is_dir():
        return ref_names, impl_names
    for path in sorted(tests_dir.rglob("*.py")):
        try:
            tree = ast.parse(path.read_text())
        except (SyntaxError, OSError):
            continue
        ref_aliases: Set[str] = set()
        impl_aliases: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    full = f"{node.module}.{a.name}"
                    bound = a.asname or a.name
                    if full.endswith("kernels.ref"):
                        ref_aliases.add(bound)
                    elif any(full == m or full.startswith(m + ".")
                             for m in impl_modules):
                        if full in impl_modules:
                            impl_aliases.add(bound)
                        else:  # direct from-import of the entry fn
                            impl_names.add(a.name)
            elif isinstance(node, ast.Import):
                for a in node.names:
                    bound = a.asname or a.name.split(".")[0]
                    if a.name.endswith("kernels.ref"):
                        ref_aliases.add(bound)
                    elif a.name in impl_modules:
                        impl_aliases.add(bound)
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute) and \
                    isinstance(node.value, ast.Name):
                if node.value.id in ref_aliases:
                    ref_names.add(node.attr)
                if node.value.id in impl_aliases:
                    impl_names.add(node.attr)
    return ref_names, impl_names


@register_check(CHECK)
def check(ctx: LintContext) -> List[Diagnostic]:
    kernel_mods = {m.module.rsplit(".", 1)[-1]: m
                   for m in ctx.index.modules.values()
                   if "/kernels/" in m.path.replace("\\", "/")
                   or m.module.endswith(".kernels")}
    ref_mod = kernel_mods.get("ref")
    impl_modules = {m.module for short, m in kernel_mods.items()
                    if short not in ("ref", "")}
    ref_names, impl_names = _test_refs(ctx.tests_dir, impl_modules)

    diags = []
    for short, mod in sorted(kernel_mods.items()):
        if short in SKIP or not _uses_pallas(mod.tree):
            continue
        entries = [n for n in mod.tree.body
                   if isinstance(n, ast.FunctionDef)
                   and not n.name.startswith("_")]
        if not entries:
            diags.append(Diagnostic(
                mod.path, 1, CHECK,
                f"Pallas kernel module `{short}` has no public entry "
                f"function to pair with kernels/ref.py"))
            continue
        for fn in entries:
            if ref_mod is None or fn.name not in ref_mod.top_functions:
                diags.append(Diagnostic(
                    mod.path, fn.lineno, CHECK,
                    f"Pallas kernel `{fn.name}` has no pure-jnp "
                    f"counterpart of the same name in kernels/ref.py — "
                    f"interpret mode alone is not a ground truth"))
                continue
            if fn.name not in ref_names or fn.name not in impl_names:
                side = ("ref." + fn.name if fn.name not in ref_names
                        else "the implementation side of " + fn.name)
                diags.append(Diagnostic(
                    mod.path, fn.lineno, CHECK,
                    f"no test under {ctx.tests_dir} references {side}; "
                    f"kernel/reference parity for `{fn.name}` is "
                    f"unverified"))
    return diags
