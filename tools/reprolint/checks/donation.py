"""donation-discipline: a buffer donated to a jitted call is dead after
the call — reading it again is a use-after-free the CPU backend may not
catch (on TPU, donation aliases the output into the input buffer; jax
raises on a *traced* reuse but a host-side read of a deleted array fails
only at access time, deep inside whatever touched it).

The check records every ``self.X = jax.jit(fn, donate_argnums=(...))``
binding, then at each ``self.X(...)`` call site verifies that every donated
positional argument that is a plain name / attribute / subscript is rebound
before its next use.  Rebinding a *prefix* kills the whole expression
(``ref, got = ...`` kills ``got[1]``), and the donating statement's own
assignment targets are applied first (``state, out = self._step(state, …)``
is the canonical correct pattern).  If the call sits in a loop, the scan
wraps around to the loop head — the next iteration's uses see the donated
buffer too.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from tools.reprolint.checks import LintContext, register_check
from tools.reprolint.diagnostics import Diagnostic
from tools.reprolint.jitscope import own_nodes

CHECK = "donation-discipline"


def _donate_positions(call: ast.Call) -> Set[int]:
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return {v.value}
        if isinstance(v, (ast.Tuple, ast.List)):
            return {e.value for e in v.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, int)}
    return set()


@register_check(CHECK)
def check(ctx: LintContext) -> List[Diagnostic]:
    index, scope = ctx.index, ctx.scope
    # 1. donated-attribute records per class
    records: Dict[str, Dict[str, Set[int]]] = {}
    for ci in index.classes.values():
        mod = index.modules[ci.module]
        for fi in index.functions.values():
            if fi.cls != ci.qualname:
                continue
            for node in own_nodes(fi.node):
                if not (isinstance(node, ast.Assign)
                        and len(node.targets) == 1):
                    continue
                t, val = node.targets[0], node.value
                if not (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"
                        and isinstance(val, ast.Call)
                        and scope._is_jit_name(
                            scope.resolve_external(val.func, mod))):
                    continue
                donated = _donate_positions(val)
                if donated:
                    records.setdefault(ci.qualname, {}).setdefault(
                        t.attr, set()).update(donated)

    # 2. call sites: a method sees the donation records of its whole class
    #    family (the jit binding may live in a base or subclass override)
    diags = []
    for ci in index.classes.values():
        family = index.mro(ci) + index.subclasses(ci)
        attrs: Dict[str, Set[int]] = {}
        for c in family:
            for attr, pos in records.get(c.qualname, {}).items():
                attrs.setdefault(attr, set()).update(pos)
        if not attrs:
            continue
        for fi in index.functions.values():
            if fi.cls != ci.qualname:
                continue
            diags.extend(_scan_function(index.modules[fi.module].path,
                                        fi.node, attrs))
    return diags


# --------------------------------------------------------------------------
# Linearized use/kill scan
# --------------------------------------------------------------------------

def _events(body, events: List, loops: List[Tuple[int, int]]) -> None:
    """Flatten statements into ordered ("use", expr-node) / ("kill",
    [targets]) events; record [start, end) event ranges of loops."""
    for stmt in body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        if isinstance(stmt, ast.Assign):
            events.append(("use", stmt.value))
            events.append(("kill", list(stmt.targets)))
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                events.append(("use", stmt.value))
            events.append(("kill", [stmt.target]))
        elif isinstance(stmt, ast.AugAssign):
            events.append(("use", stmt))
            events.append(("kill", [stmt.target]))
        elif isinstance(stmt, ast.If):
            events.append(("use", stmt.test))
            _events(stmt.body, events, loops)
            _events(stmt.orelse, events, loops)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            start = len(events)
            events.append(("use", stmt.iter))
            events.append(("kill", [stmt.target]))
            _events(stmt.body, events, loops)
            loops.append((start, len(events)))
            _events(stmt.orelse, events, loops)
        elif isinstance(stmt, ast.While):
            start = len(events)
            events.append(("use", stmt.test))
            _events(stmt.body, events, loops)
            loops.append((start, len(events)))
            _events(stmt.orelse, events, loops)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                events.append(("use", item.context_expr))
                if item.optional_vars is not None:
                    events.append(("kill", [item.optional_vars]))
            _events(stmt.body, events, loops)
        elif isinstance(stmt, ast.Try):
            _events(stmt.body, events, loops)
            for h in stmt.handlers:
                _events(h.body, events, loops)
            _events(stmt.orelse, events, loops)
            _events(stmt.finalbody, events, loops)
        else:  # Expr, Return, Raise, Assert, Delete, Global, Pass, ...
            events.append(("use", stmt))


def _flat_targets(targets) -> List[str]:
    out = []
    for t in targets:
        if isinstance(t, (ast.Tuple, ast.List)):
            out.extend(_flat_targets(t.elts))
        elif isinstance(t, ast.Starred):
            out.extend(_flat_targets([t.value]))
        elif isinstance(t, (ast.Name, ast.Attribute, ast.Subscript)):
            out.append(ast.unparse(t))
    return out


def _kills(targets, expr: str) -> bool:
    for t in _flat_targets(targets):
        if expr == t or expr.startswith(t + "[") or expr.startswith(t + "."):
            return True
    return False


def _find_use(node: ast.AST, expr: str) -> Optional[ast.AST]:
    """A node inside ``node`` reading ``expr`` (or an element/attr of it)."""
    for sub in ast.walk(node):
        if not isinstance(sub, (ast.Name, ast.Attribute, ast.Subscript)):
            continue
        u = ast.unparse(sub)
        if u == expr or u.startswith(expr + "[") or \
                u.startswith(expr + "."):
            return sub
    return None


def _scan_function(path: str, fn_node: ast.AST,
                   attrs: Dict[str, Set[int]]) -> List[Diagnostic]:
    events: List = []
    loops: List[Tuple[int, int]] = []
    _events(fn_node.body, events, loops)

    diags = []
    for i, (kind, payload) in enumerate(events):
        if kind != "use":
            continue
        for call in ast.walk(payload):
            if not (isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Attribute)
                    and isinstance(call.func.value, ast.Name)
                    and call.func.value.id == "self"
                    and call.func.attr in attrs):
                continue
            donated = [ast.unparse(call.args[p])
                       for p in sorted(attrs[call.func.attr])
                       if p < len(call.args) and isinstance(
                           call.args[p],
                           (ast.Name, ast.Attribute, ast.Subscript))]
            if not donated:
                continue
            # scan order: rest of the function, then (if in a loop) wrap
            # around from the loop head back to this call inclusive
            order = list(range(i + 1, len(events)))
            wrap = [(s, e) for (s, e) in loops if s <= i < e]
            if wrap:
                s = max(wrap, key=lambda se: se[0])[0]  # innermost loop
                order += list(range(s, i + 1))
            live = set(donated)
            for j in order:
                if not live:
                    break
                k, p = events[j]
                if k == "kill":
                    live = {e for e in live if not _kills(p, e)}
                    continue
                for e in sorted(live):
                    hit = _find_use(p, e)
                    if hit is not None:
                        diags.append(Diagnostic(
                            path, getattr(hit, "lineno",
                                          getattr(p, "lineno", 1)),
                            CHECK,
                            f"`{e}` was donated to the jitted "
                            f"`self.{call.func.attr}` "
                            f"(donate_argnums) and is read again before "
                            f"being rebound — the buffer is deleted "
                            f"after the call"))
                        live.discard(e)
    return diags
