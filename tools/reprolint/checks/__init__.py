"""The pluggable check registry (mirrors the cache-policy registry idiom:
one module per check, each registers itself by name; ``load_all`` imports
the built-ins in diagnostic order)."""
from __future__ import annotations

import dataclasses
import importlib
from pathlib import Path
from typing import Callable, Dict, List

from tools.reprolint.diagnostics import Diagnostic
from tools.reprolint.index import RepoIndex
from tools.reprolint.jitscope import JitScope

CHECKS: Dict[str, Callable[["LintContext"], List[Diagnostic]]] = {}

_BUILTINS = ("bare_assert", "host_sync", "tracer_flow", "policy_contract",
             "donation", "kernel_parity", "obs_discipline")


def register_check(name: str):
    """Decorator: register a check function under ``name``.  A check takes
    a LintContext and returns a list of Diagnostics."""
    def deco(fn):
        if name in CHECKS and CHECKS[name] is not fn:
            raise ValueError(f"reprolint check {name!r} already registered")
        fn.check_name = name
        CHECKS[name] = fn
        return fn
    return deco


def load_all() -> None:
    for m in _BUILTINS:
        importlib.import_module(f"tools.reprolint.checks.{m}")


@dataclasses.dataclass
class LintContext:
    index: RepoIndex
    scope: JitScope
    root: Path             # the scan root (package root, e.g. src/)
    tests_dir: Path        # where parity/self tests live (may not exist)
    static_only: bool      # skip checks that import the scanned code
