"""tracer-control-flow: no Python control flow on traced values in the
policy / kernel / serving layers.

``if`` / ``while`` / ``bool()`` on a value derived from a traced array
raises TracerBoolConversionError under jit — or worse, silently bakes one
branch into the compiled program when the value happens to be concrete at
trace time (the classic "my gate never fires" bug).  Data-dependent
branching belongs in ``lax.cond`` / ``lax.while_loop`` / ``jnp.where``.

Scoped to ``core/policies/``, ``kernels/`` and ``serving/`` — the layers
whose code runs under the engines' jit — and within those, to functions
actually reachable from a jit root.  Config-knob branches (``if
fc.use_str:``) stay silent: the taint analysis only marks values derived
from array-annotated parameters and ``jax.*`` call results.
"""
from __future__ import annotations

import ast
from typing import List

from tools.reprolint.checks import LintContext, register_check
from tools.reprolint.diagnostics import Diagnostic
from tools.reprolint.jitscope import own_nodes

PATH_FRAGMENTS = ("core/policies/", "/kernels/", "/serving/")


def _in_scope(path: str) -> bool:
    p = path.replace("\\", "/")
    return any(f in p for f in PATH_FRAGMENTS)


@register_check("tracer-control-flow")
def check(ctx: LintContext) -> List[Diagnostic]:
    diags = []
    for qn in sorted(ctx.scope.reachable):
        fi = ctx.index.functions[qn]
        mod = ctx.index.modules[fi.module]
        if not _in_scope(mod.path):
            continue
        for node in own_nodes(fi.node):
            if isinstance(node, (ast.If, ast.While)) and \
                    ctx.scope.expr_tainted(fi, node.test):
                kw = "if" if isinstance(node, ast.If) else "while"
                diags.append(Diagnostic(
                    mod.path, node.lineno, "tracer-control-flow",
                    f"Python `{kw}` on a traced value in `{fi.name}`; "
                    f"use lax.cond / lax.while_loop / jnp.where"))
            elif isinstance(node, ast.IfExp) and \
                    ctx.scope.expr_tainted(fi, node.test):
                diags.append(Diagnostic(
                    mod.path, node.lineno, "tracer-control-flow",
                    f"conditional expression on a traced value in "
                    f"`{fi.name}`; use jnp.where"))
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Name)
                  and node.func.id == "bool" and node.args
                  and ctx.scope.expr_tainted(fi, node.args[0])):
                diags.append(Diagnostic(
                    mod.path, node.lineno, "tracer-control-flow",
                    f"`bool()` on a traced value in `{fi.name}` raises "
                    f"under jit; use the array directly or lax.cond"))
            elif isinstance(node, ast.comprehension):
                for test in node.ifs:
                    if ctx.scope.expr_tainted(fi, test):
                        diags.append(Diagnostic(
                            mod.path, test.lineno, "tracer-control-flow",
                            f"comprehension filter on a traced value in "
                            f"`{fi.name}`; use jnp.where / boolean "
                            f"masking"))
    return diags
