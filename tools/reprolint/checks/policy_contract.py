"""policy-contract: machine-enforce the CachePolicy plugin contract.

Static half (pure AST):
  * every module under ``core/policies/`` (except ``base.py`` and
    ``__init__.py``) registers exactly one policy class via
    ``@register("name")``;
  * the package ``__init__`` imports the module (registration import order
    IS the ``repro.core.POLICIES`` order; an unimported module is a policy
    that silently does not exist).

Runtime half (imports the scanned package; skipped under ``--static-only``):
  for every policy in the live registry, build the reduced DiT and validate
  the state pytree the policy actually returns against the contract in
  ``core/policies/base.py``:
  * every leaf is a jax.Array (the engines donate buffer-for-buffer —
    a Python scalar or list breaks donation);
  * every leaf carrying the batch dim is placeable by the sharding
    walker's rank rules (``_slot_axis``: batch leading, or axis 1 behind a
    leading L / L+1 layer axis) — anything else would silently replicate a
    per-slot buffer across the mesh;
  * ``state["stats"]`` exists, every vector key is a per-sample ``(B,)``
    float, and the scalar ``steps`` key is present;
  * ``reset_rows`` preserves the treedef and every leaf's shape/dtype
    (the engines feed it back through donated jit buffers);
  * ``snapshot_rows``/``restore_rows`` (the preemption contract) likewise
    preserve the state treedef and every leaf's shape/dtype through a
    restore, and a same-state round trip is the bitwise identity — a
    policy that breaks this silently corrupts preempted requests on
    resume.

The batch size is chosen to collide with no model dimension, so "has the
batch dim" is unambiguous.
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional

from tools.reprolint.checks import LintContext, register_check
from tools.reprolint.diagnostics import Diagnostic

CHECK = "policy-contract"
EXEMPT = ("base", "__init__")


def _policy_modules(ctx: LintContext):
    for mod in ctx.index.modules.values():
        p = mod.path.replace("\\", "/")
        if "core/policies/" not in p:
            continue
        short = mod.module.rsplit(".", 1)[-1]
        if short in EXEMPT or p.endswith("__init__.py"):
            continue
        yield mod, short


def _is_register_deco(dec: ast.AST) -> bool:
    if not isinstance(dec, ast.Call):
        return False
    f = dec.func
    name = f.id if isinstance(f, ast.Name) else (
        f.attr if isinstance(f, ast.Attribute) else None)
    return name == "register"


@register_check(CHECK)
def check(ctx: LintContext) -> List[Diagnostic]:
    diags = []
    for mod, short in _policy_modules(ctx):
        registered = [n for n in mod.tree.body if isinstance(n, ast.ClassDef)
                      and any(_is_register_deco(d) for d in n.decorator_list)]
        if len(registered) != 1:
            line = registered[1].lineno if len(registered) > 1 else 1
            diags.append(Diagnostic(
                mod.path, line, CHECK,
                f"policy module `{short}` must register exactly one policy "
                f"class with @register(...); found {len(registered)}"))
        pkg = ctx.index.modules.get(mod.module.rsplit(".", 1)[0])
        if pkg is not None and not _imported_in(pkg.tree, short):
            diags.append(Diagnostic(
                mod.path, 1, CHECK,
                f"policy module `{short}` is not imported from the "
                f"package __init__ — its @register never runs, so the "
                f"policy does not exist at runtime"))
    if not ctx.static_only and (ctx.root / "repro" / "core"
                                / "policies").is_dir():
        diags.extend(validate_registry(str(ctx.root)))
    return diags


def _imported_in(init_tree: ast.Module, short: str) -> bool:
    for node in ast.walk(init_tree):
        if isinstance(node, ast.ImportFrom):
            if any(a.name == short for a in node.names):
                return True
            if node.module and node.module.rsplit(".", 1)[-1] == short:
                return True
        elif isinstance(node, ast.Import):
            if any(a.name.rsplit(".", 1)[-1] == short for a in node.names):
                return True
    return False


# --------------------------------------------------------------------------
# Runtime validation (also importable directly — the self-tests register a
# deliberately broken policy and call this)
# --------------------------------------------------------------------------

def validate_registry(root: Optional[str] = None) -> List[Diagnostic]:
    """Validate every policy in the live registry against the state-pytree
    contract.  ``root`` is prepended to sys.path so ``repro`` resolves when
    the CLI runs without PYTHONPATH."""
    import sys
    if root and root not in sys.path:
        sys.path.insert(0, root)
    try:
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.configs import get_reduced
        from repro.configs.base import FastCacheConfig
        from repro.core.policies import base as policies_base
        from repro.core.runner import CachedDiT
        from repro.distributed.sharding import _slot_axis
        from repro.models import build_model
    except Exception as e:  # import failure is a finding, not a crash
        return [Diagnostic("<runtime>", 1, CHECK,
                           f"runtime policy validation could not import "
                           f"the scanned package: {type(e).__name__}: {e}")]

    cfg = get_reduced("dit-b2").replace(dtype="float32")
    model = build_model(cfg)
    L = model.cfg.num_layers
    dims = {L, L + 1, model.cfg.d_model, model.cfg.dit.image_size,
            model.cfg.dit.in_channels, getattr(model, "num_tokens", 0)}
    B = next(b for b in (3, 5, 7, 11, 13, 17, 19) if b not in dims)

    diags = []
    for name in tuple(policies_base._REGISTRY):
        cls = policies_base._REGISTRY[name]
        where = _locate(cls)
        try:
            runner = CachedDiT(model, FastCacheConfig(), policy=name)
            state = runner.init_state(B)
        except Exception as e:
            diags.append(Diagnostic(*where, CHECK,
                                    f"policy {name!r}: init_state({B}) "
                                    f"raised {type(e).__name__}: {e}"))
            continue
        leaves = jax.tree_util.tree_leaves_with_path(state)
        for path, leaf in leaves:
            key = jax.tree_util.keystr(path)
            if not isinstance(leaf, jax.Array):
                diags.append(Diagnostic(*where, CHECK,
                             f"policy {name!r}: state leaf {key} is "
                             f"{type(leaf).__name__}, not a jax.Array — "
                             f"the engines donate the state "
                             f"buffer-for-buffer"))
                continue
            if B in leaf.shape and _slot_axis(leaf.shape, B, L) is None:
                diags.append(Diagnostic(*where, CHECK,
                             f"policy {name!r}: state leaf {key} has shape "
                             f"{tuple(leaf.shape)} — the batch dim is not "
                             f"where the sharding walker's rank rules can "
                             f"place it (leading, or axis 1 behind a "
                             f"leading {L}/{L + 1} layer axis); it would "
                             f"silently replicate"))
        stats = state.get("stats") if isinstance(state, dict) else None
        if not isinstance(stats, dict):
            diags.append(Diagnostic(*where, CHECK,
                         f"policy {name!r}: state has no 'stats' dict — "
                         f"the engines accumulate per-request counters "
                         f"from it"))
        else:
            if "steps" not in stats:
                diags.append(Diagnostic(*where, CHECK,
                             f"policy {name!r}: stats is missing the "
                             f"scalar 'steps' counter"))
            for k, v in stats.items():
                if k == "steps":
                    if getattr(v, "ndim", None) != 0:
                        diags.append(Diagnostic(*where, CHECK,
                                     f"policy {name!r}: stats['steps'] "
                                     f"must be a scalar"))
                    continue
                ok = (isinstance(v, jax.Array) and v.shape == (B,)
                      and jnp.issubdtype(v.dtype, jnp.floating))
                if not ok:
                    diags.append(Diagnostic(*where, CHECK,
                                 f"policy {name!r}: stats[{k!r}] must be a "
                                 f"per-sample (B,) float array; got "
                                 f"shape {getattr(v, 'shape', None)} "
                                 f"dtype {getattr(v, 'dtype', None)}"))
        try:
            reset = runner.reset_slot(state, jnp.array([0]))
        except Exception as e:
            diags.append(Diagnostic(*where, CHECK,
                         f"policy {name!r}: reset_rows raised "
                         f"{type(e).__name__}: {e}"))
            continue
        td0 = jax.tree_util.tree_structure(state)
        td1 = jax.tree_util.tree_structure(reset)
        if td0 != td1:
            diags.append(Diagnostic(*where, CHECK,
                         f"policy {name!r}: reset_rows changed the state "
                         f"treedef — the engines feed it back through "
                         f"donated jit buffers"))
        else:
            for (p0, l0), (_, l1) in zip(leaves,
                                         jax.tree_util.tree_leaves_with_path(
                                             reset)):
                if (getattr(l0, "shape", None) != getattr(l1, "shape", None)
                        or getattr(l0, "dtype", None)
                        != getattr(l1, "dtype", None)):
                    diags.append(Diagnostic(*where, CHECK,
                                 f"policy {name!r}: reset_rows changed "
                                 f"leaf {jax.tree_util.keystr(p0)} "
                                 f"shape/dtype"))
        # preemption contract: snapshot_rows/restore_rows must hand the
        # engines a restore that is treedef/shape/dtype-identical to the
        # live state (donated jit buffers again), and restoring a
        # snapshot into the very state it was taken from must be the
        # bitwise identity (replicated leaves keep the live value; row
        # leaves get their own rows written back)
        rows = jnp.array([0, 2])
        try:
            snap = runner.snapshot_slot(state, rows)
        except Exception as e:
            diags.append(Diagnostic(*where, CHECK,
                         f"policy {name!r}: snapshot_rows raised "
                         f"{type(e).__name__}: {e}"))
            continue
        if jax.tree_util.tree_structure(snap) != td0:
            diags.append(Diagnostic(*where, CHECK,
                         f"policy {name!r}: snapshot_rows changed the "
                         f"state treedef — restore_rows consumes the "
                         f"snapshot leaf-for-leaf, and the engines' "
                         f"jitted restore programs are traced against "
                         f"the state treedef"))
            continue
        try:
            restored = runner.restore_slot(state, snap, rows)
        except Exception as e:
            diags.append(Diagnostic(*where, CHECK,
                         f"policy {name!r}: restore_rows raised "
                         f"{type(e).__name__}: {e}"))
            continue
        if jax.tree_util.tree_structure(restored) != td0:
            diags.append(Diagnostic(*where, CHECK,
                         f"policy {name!r}: restore_rows changed the "
                         f"state treedef — the engines feed it back "
                         f"through donated jit buffers"))
            continue
        for (p0, l0), (_, l1) in zip(
                leaves, jax.tree_util.tree_leaves_with_path(restored)):
            if (getattr(l0, "shape", None) != getattr(l1, "shape", None)
                    or getattr(l0, "dtype", None)
                    != getattr(l1, "dtype", None)):
                diags.append(Diagnostic(*where, CHECK,
                             f"policy {name!r}: restore_rows changed "
                             f"leaf {jax.tree_util.keystr(p0)} "
                             f"shape/dtype"))
            elif not np.array_equal(np.asarray(l0), np.asarray(l1)):
                diags.append(Diagnostic(*where, CHECK,
                             f"policy {name!r}: snapshot/restore round "
                             f"trip is not the bitwise identity on leaf "
                             f"{jax.tree_util.keystr(p0)} — preempted "
                             f"requests would resume corrupted"))
    return diags


def _locate(cls):
    """(file, line) of a policy class, repo-relative when possible."""
    import inspect
    try:
        f = inspect.getsourcefile(cls) or "<runtime>"
        line = inspect.getsourcelines(cls)[1]
        rel = os.path.relpath(f)
        if not rel.startswith(".."):
            f = rel
        return f, line
    except (OSError, TypeError):
        return "<runtime>", 1
