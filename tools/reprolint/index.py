"""Repository index: every module under the scan root parsed once, with
import alias tables, functions (including methods and nested defs), classes
(with AST-resolved bases and ``self.<attr>`` type bindings).

Module names are derived **relative to the scan root** — ``src/repro/core/
runner.py`` scanned with root ``src`` indexes as ``repro.core.runner`` —
because ``src/repro`` is a namespace dir with no top-level ``__init__.py``.

Resolution policy throughout reprolint is *precision over recall*: a name we
cannot resolve is skipped, never guessed, so diagnostics stay actionable.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from pathlib import Path
from typing import Dict, List, Optional, Set


@dataclasses.dataclass
class FunctionInfo:
    qualname: str                  # repro.serving.engine.ServingEngine.step
    module: str
    name: str
    node: ast.AST                  # FunctionDef | AsyncFunctionDef
    cls: Optional[str] = None      # qualname of owning class (walks through
                                   # nested defs: a closure inside a method
                                   # still knows its class)
    parent: Optional[str] = None   # qualname of enclosing function
    children: Dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ClassInfo:
    qualname: str
    module: str
    name: str
    node: ast.ClassDef
    bases: List[str] = dataclasses.field(default_factory=list)  # unparsed
    methods: Dict[str, str] = dataclasses.field(default_factory=dict)
    attr_types: Dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ModuleInfo:
    module: str
    path: str                      # path as given (repo-relative)
    tree: ast.Module
    source: str
    imports: Dict[str, str] = dataclasses.field(default_factory=dict)
    top_functions: Set[str] = dataclasses.field(default_factory=set)
    top_classes: Set[str] = dataclasses.field(default_factory=set)


def module_name_for(path: Path, root: Path) -> str:
    rel = path.relative_to(root).with_suffix("")
    parts = list(rel.parts)
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def dotted(node: ast.AST) -> Optional[str]:
    """Unparse a pure Name/Attribute chain; None for anything else."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def ann_dotted(node: ast.AST) -> Optional[str]:
    """Like ``dotted`` but unwraps string annotations (``x: "DiTModel"``)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        try:
            node = ast.parse(node.value, mode="eval").body
        except SyntaxError:
            return None
    return dotted(node)


class RepoIndex:
    def __init__(self, root: Path):
        self.root = Path(root)
        self.modules: Dict[str, ModuleInfo] = {}
        self.functions: Dict[str, FunctionInfo] = {}
        self.classes: Dict[str, ClassInfo] = {}
        self.classes_by_name: Dict[str, List[str]] = {}
        self._build()

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------

    def _build(self) -> None:
        for path in sorted(self.root.rglob("*.py")):
            if "__pycache__" in path.parts:
                continue
            source = path.read_text()
            try:
                tree = ast.parse(source, filename=str(path))
            except SyntaxError:
                continue  # not our job; python itself will complain
            mod = ModuleInfo(module=module_name_for(path, self.root),
                             path=str(path), tree=tree, source=source)
            self.modules[mod.module] = mod
            self._index_module(mod)
        for ci in self.classes.values():
            self.classes_by_name.setdefault(ci.name, []).append(ci.qualname)
        for ci in self.classes.values():
            self._collect_attr_types(ci)
        for ci in self.classes.values():
            self._inherit_attr_types(ci, seen=set())

    def _index_module(self, mod: ModuleInfo) -> None:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    mod.imports[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom):
                base = node.module or ""
                if node.level:  # relative import -> anchor at this package
                    anchor = mod.module.split(".")
                    if not self._is_package(mod):
                        anchor = anchor[:-1]
                    if node.level > 1:
                        anchor = anchor[:len(anchor) - (node.level - 1)]
                    base = ".".join(anchor + ([node.module] if node.module
                                              else []))
                for a in node.names:
                    if a.name == "*":
                        continue
                    mod.imports[a.asname or a.name] = (
                        f"{base}.{a.name}" if base else a.name)
        self._index_scope(mod, mod.tree.body, prefix=mod.module,
                          cls=None, parent=None)

    def _is_package(self, mod: ModuleInfo) -> bool:
        return os.path.basename(mod.path) == "__init__.py"

    def _index_scope(self, mod: ModuleInfo, body, *, prefix: str,
                     cls: Optional[str], parent: Optional[str]) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = f"{prefix}.{node.name}"
                fi = FunctionInfo(qualname=qn, module=mod.module,
                                  name=node.name, node=node, cls=cls,
                                  parent=parent)
                self.functions[qn] = fi
                if parent is None and cls is None:
                    mod.top_functions.add(node.name)
                if parent is not None:
                    self.functions[parent].children[node.name] = qn
                if cls is not None and parent is None:
                    self.classes[cls].methods[node.name] = qn
                self._index_scope(mod, node.body, prefix=qn, cls=cls,
                                  parent=qn)
            elif isinstance(node, ast.ClassDef):
                qn = f"{prefix}.{node.name}"
                ci = ClassInfo(qualname=qn, module=mod.module,
                               name=node.name, node=node,
                               bases=[b for b in map(dotted, node.bases)
                                      if b is not None])
                self.classes[qn] = ci
                if parent is None and cls is None:
                    mod.top_classes.add(node.name)
                self._index_scope(mod, node.body, prefix=qn, cls=qn,
                                  parent=None)
            else:
                # still descend into `if TYPE_CHECKING:` style blocks
                for sub in ast.iter_child_nodes(node):
                    if isinstance(sub, (ast.FunctionDef, ast.ClassDef)):
                        self._index_scope(mod, [sub], prefix=prefix,
                                          cls=cls, parent=parent)

    # ------------------------------------------------------------------
    # Name resolution
    # ------------------------------------------------------------------

    def resolve_dotted(self, mod: ModuleInfo, name: str) -> str:
        """Best-effort absolute dotted path for ``name`` in ``mod``."""
        head, _, rest = name.partition(".")
        target = mod.imports.get(head)
        if target is None:
            if head in mod.top_functions or head in mod.top_classes:
                target = f"{mod.module}.{head}"
            else:
                return name
        return f"{target}.{rest}" if rest else target

    def resolve_class(self, mod: ModuleInfo, name: str
                      ) -> Optional[ClassInfo]:
        """Resolve a (possibly dotted) class reference to a ClassInfo."""
        full = self.resolve_dotted(mod, name)
        if full in self.classes:
            return self.classes[full]
        tail = full.rsplit(".", 1)[-1]
        cands = self.classes_by_name.get(tail, [])
        if len(cands) == 1:
            return self.classes[cands[0]]
        return None

    def mro(self, ci: ClassInfo) -> List[ClassInfo]:
        """The class plus its AST-resolvable ancestors, nearest first."""
        out, seen, stack = [], set(), [ci]
        while stack:
            c = stack.pop(0)
            if c.qualname in seen:
                continue
            seen.add(c.qualname)
            out.append(c)
            mod = self.modules[c.module]
            for b in c.bases:
                bi = self.resolve_class(mod, b)
                if bi is not None:
                    stack.append(bi)
        return out

    def subclasses(self, ci: ClassInfo) -> List[ClassInfo]:
        return [c for c in self.classes.values()
                if c is not ci and any(m.qualname == ci.qualname
                                       for m in self.mro(c))]

    def lookup_method(self, ci: ClassInfo, name: str) -> List[str]:
        """Method qualnames for ``obj.name()`` where obj is a ``ci`` — the
        MRO resolution plus every subclass override (the receiver's dynamic
        type may be any subclass)."""
        out = []
        for c in self.mro(ci):
            if name in c.methods:
                out.append(c.methods[name])
                break
        for c in self.subclasses(ci):
            if name in c.methods:
                out.append(c.methods[name])
        return out

    # ------------------------------------------------------------------
    # self.<attr> type bindings
    # ------------------------------------------------------------------

    def _collect_attr_types(self, ci: ClassInfo) -> None:
        mod = self.modules[ci.module]
        for mname, mqn in ci.methods.items():
            fn = self.functions[mqn].node
            ann = {a.arg: ann_dotted(a.annotation)
                   for a in list(fn.args.args) + list(fn.args.kwonlyargs)
                   if a.annotation is not None}
            for node in ast.walk(fn):
                tgt, val = None, None
                if isinstance(node, ast.Assign) and len(node.targets) == 1:
                    tgt, val = node.targets[0], node.value
                elif isinstance(node, ast.AnnAssign):
                    tgt = node.target
                    if node.annotation is not None:
                        d = ann_dotted(node.annotation)
                        if (d and isinstance(tgt, ast.Attribute)
                                and isinstance(tgt.value, ast.Name)
                                and tgt.value.id == "self"):
                            hit = self.resolve_class(mod, d)
                            if hit:
                                ci.attr_types[tgt.attr] = hit.qualname
                    val = node.value
                if (tgt is None or val is None
                        or not isinstance(tgt, ast.Attribute)
                        or not isinstance(tgt.value, ast.Name)
                        or tgt.value.id != "self"):
                    continue
                hit = None
                if isinstance(val, ast.Call):
                    d = dotted(val.func)
                    if d:
                        hit = self.resolve_class(mod, d)
                elif isinstance(val, ast.Name) and val.id in ann and ann[val.id]:
                    hit = self.resolve_class(mod, ann[val.id])
                if hit is not None:
                    ci.attr_types.setdefault(tgt.attr, hit.qualname)

    def _inherit_attr_types(self, ci: ClassInfo, seen) -> None:
        if ci.qualname in seen:
            return
        seen.add(ci.qualname)
        for base in self.mro(ci)[1:]:
            for k, v in base.attr_types.items():
                ci.attr_types.setdefault(k, v)
