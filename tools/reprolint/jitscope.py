"""Jit-region discovery and tracer-taint analysis.

Three layers, each feeding the next:

1. **Roots** — functions that enter a jit trace: anything passed to
   ``jax.jit`` (as a call argument, through ``functools.partial``, or as a
   decorator), plus the ``CachePolicy`` protocol methods (``init_state`` /
   ``reset_rows`` / ``step``) of every class defined under ``core/policies/``
   — those are jitted by the engines through dynamic dispatch the static
   call graph cannot see.
2. **Reachability** — a call graph over the index (methods resolved through
   ``self``, AST-level MRO, ``self.<attr>`` type bindings and local variable
   types; function *references* passed as call arguments — ``lax.scan(body,
   …)``, ``pl.pallas_call(_kernel, …)`` — count as edges).  Everything
   reachable from a root is "in the jit region".
3. **Taint** — per-function, intra-procedural, monotone fixpoint marking
   names that (may) hold traced arrays: parameters annotated as arrays,
   results of ``jax.*``-family calls, and anything derived from either.
   ``.shape``/``.dtype``/``.ndim``/``.size`` reads and host builtins
   (``len``, ``int(…)`` results, ``isinstance``…) break the chain.
   Nested defs inherit the enclosing function's taint minus shadowed
   parameters (closures over traced values stay traced).

Unresolvable calls are skipped, never guessed — reprolint prefers a missed
edge over a false diagnostic.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from tools.reprolint.index import (FunctionInfo, ModuleInfo, RepoIndex,
                                   ann_dotted, dotted)

POLICY_PATH_FRAGMENT = "core/policies"
POLICY_PROTOCOL_METHODS = ("init_state", "reset_rows", "step")
ARRAY_ANNOTATIONS = ("jax.Array", "jnp.ndarray", "jax.numpy.ndarray")
UNTAINTED_BUILTINS = {"isinstance", "len", "float", "int", "bool", "range",
                      "str", "repr", "type", "print", "hasattr", "getattr",
                      "enumerate", "zip", "id", "format"}
HOST_ATTR_READS = {"shape", "ndim", "dtype", "size"}


def own_nodes(fn_node: ast.AST) -> List[ast.AST]:
    """Every AST node belonging to this scope: stops at nested function /
    class bodies (their decorators still belong here), keeps lambdas and
    comprehensions inline."""
    out: List[ast.AST] = []

    def rec(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            out.append(child)
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                for dec in child.decorator_list:
                    out.append(dec)
                    rec(dec)
                continue
            rec(child)

    rec(fn_node)
    return out


def _target_names(node: ast.AST) -> List[str]:
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in node.elts:
            out.extend(_target_names(e))
        return out
    if isinstance(node, ast.Starred):
        return _target_names(node.value)
    return []


class JitScope:
    def __init__(self, index: RepoIndex):
        self.index = index
        self._local_types: Dict[str, Dict[str, str]] = {}
        self._edges: Dict[str, Set[str]] = {}
        self._taint: Dict[str, Set[str]] = {}
        self.roots: Dict[str, str] = {}      # qualname -> reason
        self._find_roots()
        self.reachable: Set[str] = self._reach()

    # ------------------------------------------------------------------
    # Resolution helpers
    # ------------------------------------------------------------------

    def resolve_external(self, expr: ast.AST, mod: ModuleInfo
                         ) -> Optional[str]:
        """Absolute dotted name for a Name/Attribute chain rooted at an
        import (``jnp.where`` -> ``jax.numpy.where``); None for ``self.``
        chains or non-chains."""
        d = dotted(expr)
        if d is None or d == "self" or d.startswith("self."):
            return None
        return self.index.resolve_dotted(mod, d)

    def local_types(self, fi: FunctionInfo) -> Dict[str, str]:
        """Local var name -> class qualname, from annotated params,
        ``v = ClassName(...)`` and ``v = self.<typed attr>``."""
        if fi.qualname in self._local_types:
            return self._local_types[fi.qualname]
        index = self.index
        mod = index.modules[fi.module]
        out: Dict[str, str] = {}
        args = fi.node.args
        for a in list(args.posonlyargs) + list(args.args) + \
                list(args.kwonlyargs):
            if a.annotation is not None:
                d = ann_dotted(a.annotation)
                if d:
                    hit = index.resolve_class(mod, d)
                    if hit:
                        out[a.arg] = hit.qualname
        cls = index.classes.get(fi.cls) if fi.cls else None
        for node in own_nodes(fi.node):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                continue
            name, val = node.targets[0].id, node.value
            if isinstance(val, ast.Call):
                d = dotted(val.func)
                hit = index.resolve_class(mod, d) if d else None
                if hit:
                    out[name] = hit.qualname
            elif (isinstance(val, ast.Attribute)
                  and isinstance(val.value, ast.Name)
                  and val.value.id == "self" and cls is not None
                  and val.attr in cls.attr_types):
                out[name] = cls.attr_types[val.attr]
        self._local_types[fi.qualname] = out
        return out

    def resolve_callable(self, expr: ast.AST, fi: Optional[FunctionInfo],
                         mod: ModuleInfo) -> Set[str]:
        """Function qualnames ``expr`` may denote as a callee."""
        index = self.index
        if isinstance(expr, ast.Name):
            cur = fi
            while cur is not None:  # nested defs in the enclosing chain
                if expr.id in cur.children:
                    return {cur.children[expr.id]}
                cur = (index.functions[cur.parent]
                       if cur.parent else None)
            if expr.id in mod.top_functions:
                return {f"{mod.module}.{expr.id}"}
            resolved = index.resolve_dotted(mod, expr.id)
            if resolved in index.functions:
                return {resolved}
            if resolved in index.classes:
                init = index.classes[resolved].methods.get("__init__")
                return {init} if init else set()
            return set()
        if not isinstance(expr, ast.Attribute):
            return set()
        d = dotted(expr)
        if d is None:
            return set()
        parts = d.split(".")
        if parts[0] == "self":
            if fi is None or not fi.cls:
                return set()
            ci = index.classes[fi.cls]
            if len(parts) == 2:
                return set(index.lookup_method(ci, parts[1]))
            if len(parts) == 3 and parts[1] in ci.attr_types:
                owner = index.classes[ci.attr_types[parts[1]]]
                return set(index.lookup_method(owner, parts[2]))
            return set()
        if len(parts) == 2 and fi is not None:
            lt = self.local_types(fi)
            if parts[0] in lt:
                owner = index.classes[lt[parts[0]]]
                return set(index.lookup_method(owner, parts[1]))
        resolved = index.resolve_dotted(mod, d)
        if resolved in index.functions:
            return {resolved}
        if resolved in index.classes:
            init = index.classes[resolved].methods.get("__init__")
            return {init} if init else set()
        return set()

    # ------------------------------------------------------------------
    # Roots
    # ------------------------------------------------------------------

    def _unwrap_partial(self, target: Optional[ast.AST], mod: ModuleInfo
                        ) -> Optional[ast.AST]:
        while (isinstance(target, ast.Call)
               and self.resolve_external(target.func, mod)
               in ("functools.partial", "partial")):
            target = target.args[0] if target.args else None
        return target

    def _jit_targets(self, call: ast.Call, fi: Optional[FunctionInfo],
                     mod: ModuleInfo) -> Set[str]:
        """Function qualnames entering the trace via a ``jax.jit(...)``
        call node.  Unwraps ``functools.partial`` (inline or through a
        local alias: ``f = partial(g, ...); jax.jit(f)``) and factory
        calls (``jax.jit(make_step(...))`` roots the nested defs the
        factory returns)."""
        target: Optional[ast.AST] = call.args[0] if call.args else None
        if target is None:
            for kw in call.keywords:
                if kw.arg == "fun":
                    target = kw.value
        target = self._unwrap_partial(target, mod)
        if target is None:
            return set()
        out = self.resolve_callable(target, fi, mod)
        if out:
            return out
        if isinstance(target, ast.Name) and fi is not None:
            # local alias bound to a partial / function reference
            for node in own_nodes(fi.node):
                if not (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and node.targets[0].id == target.id):
                    continue
                val = self._unwrap_partial(node.value, mod)
                if val is None or (isinstance(val, ast.Call)
                                   and self._is_jit_name(
                                       self.resolve_external(val.func,
                                                             mod))):
                    continue  # skip `f = jax.jit(f)` self-rebinds
                if isinstance(val, (ast.Name, ast.Attribute)):
                    out |= self.resolve_callable(val, fi, mod)
                elif isinstance(val, ast.Call):
                    out |= self._factory_returns(val, fi, mod)
            if out:
                return out
        if isinstance(target, ast.Call):
            out |= self._factory_returns(target, fi, mod)
        return out

    def _factory_returns(self, call: ast.Call, fi: Optional[FunctionInfo],
                         mod: ModuleInfo) -> Set[str]:
        """Nested defs returned by a factory whose *result* is jitted:
        ``jax.jit(make_train_step(...))``."""
        out: Set[str] = set()
        for qn in self.resolve_callable(call.func, fi, mod):
            factory = self.index.functions[qn]
            for node in own_nodes(factory.node):
                if isinstance(node, ast.Return) and \
                        isinstance(node.value, ast.Name) and \
                        node.value.id in factory.children:
                    out.add(factory.children[node.value.id])
        return out

    def _is_jit_name(self, resolved: Optional[str]) -> bool:
        return resolved is not None and (
            resolved in ("jax.jit", "jax.pmap")
            or (resolved.startswith("jax.") and resolved.endswith(".jit")))

    def _find_roots(self) -> None:
        index = self.index
        for fi in index.functions.values():
            mod = index.modules[fi.module]
            # decorator roots
            for dec in fi.node.decorator_list:
                if self._is_jit_name(self.resolve_external(dec, mod)):
                    self.roots.setdefault(fi.qualname, "@jit decorator")
                elif isinstance(dec, ast.Call):
                    df = self.resolve_external(dec.func, mod)
                    if self._is_jit_name(df):
                        self.roots.setdefault(fi.qualname, "@jit decorator")
                    elif df in ("functools.partial", "partial") and dec.args \
                            and self._is_jit_name(
                                self.resolve_external(dec.args[0], mod)):
                        self.roots.setdefault(
                            fi.qualname, "@partial(jax.jit) decorator")
            # jax.jit(...) call sites inside this function
            parent_fi = fi
            for node in own_nodes(fi.node):
                if isinstance(node, ast.Call) and self._is_jit_name(
                        self.resolve_external(node.func, mod)):
                    for qn in self._jit_targets(node, parent_fi, mod):
                        self.roots.setdefault(
                            qn, f"passed to jax.jit in {fi.qualname}")
        # module-level jax.jit(...) sites
        for mod in index.modules.values():
            for node in own_nodes(mod.tree):
                if isinstance(node, ast.Call) and self._is_jit_name(
                        self.resolve_external(node.func, mod)):
                    for qn in self._jit_targets(node, None, mod):
                        self.roots.setdefault(
                            qn, f"passed to jax.jit in {mod.module}")
        # CachePolicy protocol methods (engines jit them dynamically)
        for ci in index.classes.values():
            path = index.modules[ci.module].path.replace("\\", "/")
            if POLICY_PATH_FRAGMENT not in path:
                continue
            for m in POLICY_PROTOCOL_METHODS:
                if m in ci.methods:
                    self.roots.setdefault(
                        ci.methods[m], "CachePolicy protocol method")

    # ------------------------------------------------------------------
    # Call graph / reachability
    # ------------------------------------------------------------------

    def edges(self, qualname: str) -> Set[str]:
        if qualname in self._edges:
            return self._edges[qualname]
        fi = self.index.functions[qualname]
        mod = self.index.modules[fi.module]
        out: Set[str] = set()
        for node in own_nodes(fi.node):
            if not isinstance(node, ast.Call):
                continue
            out |= self.resolve_callable(node.func, fi, mod)
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, (ast.Name, ast.Attribute)):
                    out |= self.resolve_callable(arg, fi, mod)
                elif isinstance(arg, ast.Call) and self.resolve_external(
                        arg.func, mod) in ("functools.partial", "partial"):
                    if arg.args and isinstance(arg.args[0],
                                               (ast.Name, ast.Attribute)):
                        out |= self.resolve_callable(arg.args[0], fi, mod)
        self._edges[qualname] = out
        return out

    def _reach(self) -> Set[str]:
        seen: Set[str] = set()
        stack = [r for r in self.roots if r in self.index.functions]
        while stack:
            qn = stack.pop()
            if qn in seen:
                continue
            seen.add(qn)
            for nxt in self.edges(qn):
                if nxt in self.index.functions and nxt not in seen:
                    stack.append(nxt)
        return seen

    def in_jit_region(self, qualname: str) -> bool:
        return qualname in self.reachable

    # ------------------------------------------------------------------
    # Taint
    # ------------------------------------------------------------------

    def taint(self, qualname: str) -> Set[str]:
        if qualname in self._taint:
            return self._taint[qualname]
        fi = self.index.functions[qualname]
        mod = self.index.modules[fi.module]
        args = fi.node.args
        params = [a.arg for a in list(args.posonlyargs) + list(args.args)
                  + list(args.kwonlyargs)]
        if args.vararg:
            params.append(args.vararg.arg)
        if args.kwarg:
            params.append(args.kwarg.arg)
        tainted: Set[str] = set()
        for a in list(args.posonlyargs) + list(args.args) + \
                list(args.kwonlyargs):
            if a.annotation is not None:
                ann = ast.unparse(a.annotation)
                if any(t in ann for t in ARRAY_ANNOTATIONS):
                    tainted.add(a.arg)
        if fi.parent:  # closures over the enclosing function's traced vars
            tainted |= self.taint(fi.parent) - set(params)
        self._taint[qualname] = tainted  # publish early (recursion guard)

        nodes = own_nodes(fi.node)
        for _ in range(20):
            before = len(tainted)
            for node in nodes:
                if isinstance(node, ast.Assign):
                    if self._expr_tainted(node.value, tainted, mod):
                        for t in node.targets:
                            tainted.update(_target_names(t))
                elif isinstance(node, ast.AnnAssign):
                    ann = ast.unparse(node.annotation)
                    if (node.value is not None
                            and self._expr_tainted(node.value, tainted, mod)
                            ) or any(t in ann for t in ARRAY_ANNOTATIONS):
                        tainted.update(_target_names(node.target))
                elif isinstance(node, ast.AugAssign):
                    if self._expr_tainted(node.value, tainted, mod) or \
                            self._expr_tainted(node.target, tainted, mod):
                        tainted.update(_target_names(node.target))
                elif isinstance(node, ast.For):
                    if self._expr_tainted(node.iter, tainted, mod):
                        tainted.update(_target_names(node.target))
                elif isinstance(node, ast.withitem):
                    if node.optional_vars is not None and \
                            self._expr_tainted(node.context_expr, tainted,
                                               mod):
                        tainted.update(_target_names(node.optional_vars))
                elif isinstance(node, ast.NamedExpr):
                    if self._expr_tainted(node.value, tainted, mod):
                        tainted.update(_target_names(node.target))
                elif isinstance(node, ast.comprehension):
                    if self._expr_tainted(node.iter, tainted, mod):
                        tainted.update(_target_names(node.target))
            if len(tainted) == before:
                break
        self._taint[qualname] = tainted
        return tainted

    def expr_tainted(self, fi: FunctionInfo, expr: ast.AST) -> bool:
        return self._expr_tainted(expr, self.taint(fi.qualname),
                                  self.index.modules[fi.module])

    def _expr_tainted(self, expr: ast.AST, tainted: Set[str],
                      mod: ModuleInfo) -> bool:
        if isinstance(expr, ast.Name):
            return expr.id in tainted
        if isinstance(expr, ast.Constant):
            return False
        if isinstance(expr, ast.Attribute):
            if expr.attr in HOST_ATTR_READS:
                return False
            return self._expr_tainted(expr.value, tainted, mod)
        if isinstance(expr, ast.Subscript):
            return (self._expr_tainted(expr.value, tainted, mod)
                    or self._expr_tainted(expr.slice, tainted, mod))
        if isinstance(expr, ast.Call):
            resolved = self.resolve_external(expr.func, mod)
            if resolved is not None:
                if resolved.split(".")[0] == "jax":
                    return True
                if resolved in UNTAINTED_BUILTINS:
                    return False
            if isinstance(expr.func, ast.Attribute) and self._expr_tainted(
                    expr.func.value, tainted, mod):
                return True
            return any(self._expr_tainted(a, tainted, mod)
                       for a in expr.args) or \
                any(self._expr_tainted(kw.value, tainted, mod)
                    for kw in expr.keywords)
        if isinstance(expr, ast.Lambda):
            return False
        if isinstance(expr, (ast.BinOp, ast.UnaryOp, ast.BoolOp,
                             ast.Compare, ast.IfExp, ast.Tuple, ast.List,
                             ast.Set, ast.Dict, ast.Starred, ast.NamedExpr,
                             ast.FormattedValue, ast.JoinedStr,
                             ast.keyword)):
            return any(self._expr_tainted(c, tainted, mod)
                       for c in ast.iter_child_nodes(expr)
                       if isinstance(c, ast.expr))
        if isinstance(expr, (ast.ListComp, ast.SetComp, ast.DictComp,
                             ast.GeneratorExp)):
            return any(self._expr_tainted(c, tainted, mod)
                       for g in expr.generators
                       for c in [g.iter] + list(g.ifs)) or any(
                self._expr_tainted(c, tainted, mod)
                for c in ast.iter_child_nodes(expr)
                if isinstance(c, ast.expr))
        return False
