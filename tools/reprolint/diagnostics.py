"""Diagnostic records and the inline suppression (escape-hatch) parser.

A diagnostic is one finding: (file, line, check name, message).  A finding
is suppressed by an inline comment on the flagged line::

    assert x  # reprolint: disable=no-bare-assert
    y = float(stat)  # reprolint: disable=host-sync-in-jit,tracer-control-flow

``disable=all`` silences every check on that line.  Suppressions are
per-line by design — there is no file- or block-level escape hatch, so a
waiver is always visible next to the code it waives.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Set

_DISABLE_RE = re.compile(r"#\s*reprolint:\s*disable=([\w,\-]+)")


@dataclasses.dataclass(frozen=True, order=True)
class Diagnostic:
    file: str          # path as given on the command line (repo-relative)
    line: int          # 1-indexed
    check: str         # check name, e.g. "no-bare-assert"
    message: str

    def __str__(self) -> str:
        return f"{self.file}:{self.line}: [{self.check}] {self.message}"


def parse_suppressions(source: str) -> Dict[int, Set[str]]:
    """Map of 1-indexed line number -> set of check names disabled there."""
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _DISABLE_RE.search(text)
        if m:
            out[i] = {c.strip() for c in m.group(1).split(",") if c.strip()}
    return out


def apply_suppressions(diags: List[Diagnostic],
                       per_file: Dict[str, Dict[int, Set[str]]]
                       ) -> List[Diagnostic]:
    kept = []
    for d in diags:
        disabled = per_file.get(d.file, {}).get(d.line, set())
        if d.check in disabled or "all" in disabled:
            continue
        kept.append(d)
    return sorted(kept)
