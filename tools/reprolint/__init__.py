"""reprolint — repo-specific static analysis for the FastCache serving
stack.

Run as ``python -m tools.reprolint src/`` (or ``make lint``).  Six checks,
each its own module under ``checks/`` (registered like cache policies):

  no-bare-assert       library code raises, never asserts
  host-sync-in-jit     no float()/.item()/np.* on traced values in the
                       jit region
  tracer-control-flow  no Python if/while/bool() on traced values in the
                       policy/kernel/serving layers
  policy-contract      every policy module registers exactly one policy,
                       is imported, and its live state pytree obeys the
                       sharding/stats/donation contract
  donation-discipline  buffers donated to jitted calls are rebound before
                       reuse
  kernel-parity        every Pallas kernel has a ref.py twin and a parity
                       test

Suppress a single finding with ``# reprolint: disable=<check>`` on the
flagged line.
"""
from __future__ import annotations

from pathlib import Path
from typing import List, Optional, Sequence

from tools.reprolint.diagnostics import (Diagnostic, apply_suppressions,
                                         parse_suppressions)
from tools.reprolint.index import RepoIndex
from tools.reprolint.jitscope import JitScope


def run_checks(root, *, checks: Optional[Sequence[str]] = None,
               static_only: bool = False,
               tests_dir=None) -> List[Diagnostic]:
    """Run reprolint over the package root; returns surviving diagnostics
    (suppressions already applied), sorted by file/line."""
    from tools.reprolint.checks import CHECKS, LintContext, load_all
    load_all()
    root = Path(root)
    if tests_dir is None:
        tests_dir = root.resolve().parent / "tests"
    index = RepoIndex(root)
    scope = JitScope(index)
    ctx = LintContext(index=index, scope=scope, root=root,
                      tests_dir=Path(tests_dir), static_only=static_only)
    selected = list(checks) if checks else sorted(CHECKS)
    unknown = [c for c in selected if c not in CHECKS]
    if unknown:
        raise ValueError(f"unknown reprolint check(s) {unknown}; "
                         f"available: {sorted(CHECKS)}")
    diags: List[Diagnostic] = []
    for name in selected:
        diags.extend(CHECKS[name](ctx))
    per_file = {m.path: parse_suppressions(m.source)
                for m in index.modules.values()}
    return apply_suppressions(diags, per_file)
