from repro.distributed.sharding import (  # noqa: F401
    ShardingCtx, constrain, current_ctx, make_rules, param_shardings,
    spec_for, use_sharding,
)
