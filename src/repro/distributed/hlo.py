"""Extract collective-communication bytes from compiled HLO text.

``cost_analysis`` has FLOPs and HBM bytes but not collective traffic, so the
roofline's third term is parsed from ``compiled.as_text()``: sum the result
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.  Ops inside while-loop bodies (scan-over-layers) are
multiplied by the loop trip count, recovered from the loop condition's
compare-against-constant; if that fails, ``default_trip`` (the model's scan
length) is used.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_WHILE_RE = re.compile(r"while\(")
_BODY_RE = re.compile(r"body=\s*%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=\s*%?([\w.\-]+)")
def shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(text: str) -> Dict[str, str]:
    """Top-level HLO computations: a header is an unindented line starting
    with ``ENTRY`` or ``%name (...)`` and ending with '{'; the body runs to
    the matching unindented '}'. (Op lines contain balanced braces like
    ``{1,0}`` / ``dimensions={0}`` so brace-depth tracking stays correct.)"""
    comps: Dict[str, list] = {}
    cur = None
    depth = 0
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if not line or line[0].isspace():
                continue
            if not stripped.endswith("{"):
                continue
            if not (stripped.startswith("%") or stripped.startswith("ENTRY")
                    or stripped.startswith("HloModule")):
                continue
            if stripped.startswith("HloModule"):
                continue
            name = stripped.split()[0].lstrip("%")
            if name == "ENTRY":
                name = stripped.split()[1].lstrip("%")
            cur = name
            comps[cur] = [line]
            depth = line.count("{") - line.count("}")
            if depth <= 0:
                cur = None
        else:
            comps[cur].append(line)
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                cur = None
    return {k: "\n".join(v) for k, v in comps.items()}


def _trip_count(cond_text: str) -> int | None:
    # loop bound usually appears as a compare against an s32/u32 constant
    consts = [int(c) for c in
              re.findall(r"[su]\d+\[\]\s+constant\((\d+)\)", cond_text)]
    if consts:
        return max(consts)
    return None


def collective_bytes(hlo_text: str, default_trip: int = 1
                     ) -> Tuple[Dict[str, float], Dict[str, float]]:
    """Returns (per-op-kind bytes, diagnostics).  Bytes are trip-count
    weighted; `static` in diagnostics is the unweighted sum."""
    comps = _split_computations(hlo_text)

    # map body computation -> trip count
    trips: Dict[str, int] = {}
    for name, body in comps.items():
        for line in body.splitlines():
            if _WHILE_RE.search(line):
                bm = _BODY_RE.search(line)
                cm = _COND_RE.search(line)
                if bm:
                    t = None
                    if cm and cm.group(1) in comps:
                        t = _trip_count(comps[cm.group(1)])
                    trips[bm.group(1)] = t if t else default_trip

    out: Dict[str, float] = {}
    static: Dict[str, float] = {}
    for name, body in comps.items():
        mult = trips.get(name, 1)
        # nested whiles: multiply through (rare; one level handled)
        for line in body.splitlines():
            m = _COLL_RE.search(line)
            if not m:
                continue
            if "-done(" in line:
                continue  # avoid double counting async start/done pairs
            b = shape_bytes(m.group(1))
            kind = m.group(2)
            out[kind] = out.get(kind, 0.0) + b * mult
            static[kind] = static.get(kind, 0.0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    static["total"] = sum(v for k, v in static.items() if k != "total")
    return out, {"static": static, "trip_counts": trips}
