"""Logical-axis sharding rules with divisibility fallback.

Models annotate every parameter dim and key activations with *logical* axis
names.  A rule table maps logical names to mesh axes; ``spec_for`` drops a mesh
axis when the dim size is not divisible by the mesh-axis extent (e.g. hubert's
vocab=504 on a 16-way axis) or when the axis is already consumed by another
dim of the same array.

Rule tables are built per (step kind, shape) by ``make_rules`` — e.g.
``long_500k`` moves the ``data`` axis from batch (which is 1) to the KV-cache
sequence dim.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]


# --------------------------------------------------------------------------
# Rule tables
# --------------------------------------------------------------------------

def make_rules(kind: str = "train", *, long_context: bool = False,
               seq_shard: bool = False,
               attn_seq_shard: bool = False) -> Dict[str, Axes]:
    """Logical-axis -> mesh-axes mapping.

    Weight dims:  embed / ffn / heads / vocab / expert / expert_embed ...
    Activations:  act_batch / act_seq / act_kv_seq / act_embed / act_vocab ...
    """
    rules: Dict[str, Axes] = {
        # ---- weights: FSDP over `data`, tensor/expert-parallel over `model`
        "embed": ("data",),
        "ffn": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": None,
        "vocab": ("model",),
        "expert": ("model",),
        "expert_embed": ("data",),
        "inner": ("model",),        # SSM inner/channel dims
        "state": None,
        "layers": None,
        "null": None,
        # ---- activations
        "act_batch": ("pod", "data"),
        "act_seq": None,
        "act_kv_seq": None,
        "act_embed": None,
        "act_heads": ("model",),
        "act_ffn": ("model",),
        "act_inner": ("model",),
        "act_vocab": ("model",),
        "act_expert": ("model",),
        # perf knob: shard attention internals (q/logits) over `model` on
        # the query-seq dim — bounds per-chip logits when heads don't divide
        # the model axis (e.g. qwen3-14b's 40 heads on a 16-way axis)
        "act_attn_seq": ("model",) if attn_seq_shard else None,
    }
    if seq_shard:
        # sequence parallelism on the residual stream (perf knob)
        rules["act_seq"] = ("model",)
        rules["act_ffn"] = None
    if kind == "decode":
        # batch shards over data; spread the KV cache over `model` so the
        # per-device cache fits HBM (attention reductions over the sharded
        # seq dim lower to all-reduces)
        rules["act_kv_seq"] = ("model",)
    if long_context:
        # batch==1: move `data` (and `model`) onto the KV/sequence dim
        rules["act_batch"] = ("pod",)
        rules["act_kv_seq"] = ("data", "model")
        if kind != "decode":
            rules["act_seq"] = ("data",)
    return rules


# --------------------------------------------------------------------------
# Context
# --------------------------------------------------------------------------

class ShardingCtx:
    def __init__(self, mesh: Mesh, rules: Dict[str, Axes]):
        self.mesh = mesh
        self.rules = rules


_TLS = threading.local()


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_TLS, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Dict[str, Axes]):
    prev = current_ctx()
    _TLS.ctx = ShardingCtx(mesh, rules)
    try:
        yield _TLS.ctx
    finally:
        _TLS.ctx = prev


# --------------------------------------------------------------------------
# Spec construction
# --------------------------------------------------------------------------

def _as_tuple(a: Axes) -> Tuple[str, ...]:
    if a is None:
        return ()
    if isinstance(a, str):
        return (a,)
    return tuple(a)


def spec_for(shape: Sequence[int], logical_axes: Sequence[Optional[str]],
             ctx: Optional[ShardingCtx] = None) -> P:
    """PartitionSpec for `shape` given per-dim logical axis names.

    Drops mesh axes that (a) don't exist in the mesh, (b) don't divide the dim
    size, or (c) were already used by an earlier dim.
    """
    ctx = ctx or current_ctx()
    if ctx is None:
        return P(*([None] * len(shape)))
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    mesh_shape = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    used: set = set()
    out = []
    for size, name in zip(shape, logical_axes):
        mesh_axes = _as_tuple(ctx.rules.get(name)) if name else ()
        mesh_axes = tuple(a for a in mesh_axes
                          if a in mesh_shape and a not in used)
        # all-or-nothing per requested group, trimmed greedily
        picked: Tuple[str, ...] = ()
        extent = 1
        for a in mesh_axes:
            if size % (extent * mesh_shape[a]) == 0:
                picked += (a,)
                extent *= mesh_shape[a]
        used.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(picked)
    return P(*out)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside a ctx."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = spec_for(x.shape, logical_axes, ctx)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def param_shardings(defs, ctx: Optional[ShardingCtx] = None):
    """Pytree of NamedShardings matching a pytree of ParamDef."""
    from repro.models.params import ParamDef  # local to avoid cycle
    ctx = ctx or current_ctx()
    assert ctx is not None, "param_shardings requires an active sharding ctx"

    def one(d: ParamDef):
        return NamedSharding(ctx.mesh, spec_for(d.shape, d.axes, ctx))

    return jax.tree.map(one, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))
