"""Logical-axis sharding rules with divisibility fallback.

Models annotate every parameter dim and key activations with *logical* axis
names.  A rule table maps logical names to mesh axes; ``spec_for`` drops a mesh
axis when the dim size is not divisible by the mesh-axis extent (e.g. hubert's
vocab=504 on a 16-way axis) or when the axis is already consumed by another
dim of the same array.

Rule tables are built per (step kind, shape) by ``make_rules`` — e.g.
``long_500k`` moves the ``data`` axis from batch (which is 1) to the KV-cache
sequence dim.  ``kind="serve"`` is the diffusion-serving rule set: the slot
batch (and every per-slot row of the cache-policy state — cache payloads,
sigma trackers, stat accumulators) shards over ``data`` while DiT weights
stay tensor-parallel over ``model``; ``serve_state_shardings`` turns any
policy's serving-state pytree into the matching NamedSharding tree by
walking the OPAQUE pytree with rank/leading-axis rules (``_slot_axis``) —
no state keys are named, so new cache policies shard without edits here.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]


# --------------------------------------------------------------------------
# Rule tables
# --------------------------------------------------------------------------

def make_rules(kind: str = "train", *, long_context: bool = False,
               seq_shard: bool = False,
               attn_seq_shard: bool = False) -> Dict[str, Axes]:
    """Logical-axis -> mesh-axes mapping.

    Weight dims:  embed / ffn / heads / vocab / expert / expert_embed ...
    Activations:  act_batch / act_seq / act_kv_seq / act_embed / act_vocab ...
    """
    rules: Dict[str, Axes] = {
        # ---- weights: FSDP over `data`, tensor/expert-parallel over `model`
        "embed": ("data",),
        "ffn": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": None,
        "vocab": ("model",),
        "expert": ("model",),
        "expert_embed": ("data",),
        "inner": ("model",),        # SSM inner/channel dims
        "state": None,
        "layers": None,
        "null": None,
        # serving-slot batch rows (engine state); mapped under kind="serve"
        "slot": None,
        # ---- activations
        "act_batch": ("pod", "data"),
        "act_seq": None,
        "act_kv_seq": None,
        "act_embed": None,
        "act_heads": ("model",),
        "act_ffn": ("model",),
        "act_inner": ("model",),
        "act_vocab": ("model",),
        "act_expert": ("model",),
        # perf knob: shard attention internals (q/logits) over `model` on
        # the query-seq dim — bounds per-chip logits when heads don't divide
        # the model axis (e.g. qwen3-14b's 40 heads on a 16-way axis)
        "act_attn_seq": ("model",) if attn_seq_shard else None,
    }
    if seq_shard:
        # sequence parallelism on the residual stream (perf knob)
        rules["act_seq"] = ("model",)
        rules["act_ffn"] = None
    if kind == "serve":
        # diffusion serving: the engine's slot batch — latents plus every
        # per-slot row of the FastCache state (cache payloads, chi^2 sigma
        # trackers, policy counters, stat accumulators) — shards over
        # `data`; weights stay tensor-parallel over `model`.  Serving meshes
        # are single-pod, so the batch axis is plain ("data",).
        rules["slot"] = ("data",)
        rules["act_batch"] = ("data",)
        # inference replicates weights over `data` (no optimizer state, so
        # FSDP buys nothing and costs an all-gather per step).  This is
        # also a correctness matter: batch-over-data activations against
        # data-sharded weight dims in one serving program led GSPMD to
        # double-count the patch-embedding product on (data>1, model>1)
        # meshes — weights touch `model` only.
        rules["embed"] = None
        rules["expert_embed"] = None
    if kind == "decode":
        # batch shards over data; spread the KV cache over `model` so the
        # per-device cache fits HBM (attention reductions over the sharded
        # seq dim lower to all-reduces)
        rules["act_kv_seq"] = ("model",)
    if long_context:
        # batch==1: move `data` (and `model`) onto the KV/sequence dim
        rules["act_batch"] = ("pod",)
        rules["act_kv_seq"] = ("data", "model")
        if kind != "decode":
            rules["act_seq"] = ("data",)
    return rules


# --------------------------------------------------------------------------
# Context
# --------------------------------------------------------------------------

class ShardingCtx:
    def __init__(self, mesh: Mesh, rules: Dict[str, Axes]):
        self.mesh = mesh
        self.rules = rules


_TLS = threading.local()


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_TLS, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Dict[str, Axes]):
    prev = current_ctx()
    _TLS.ctx = ShardingCtx(mesh, rules)
    try:
        yield _TLS.ctx
    finally:
        _TLS.ctx = prev


# --------------------------------------------------------------------------
# Spec construction
# --------------------------------------------------------------------------

def _as_tuple(a: Axes) -> Tuple[str, ...]:
    if a is None:
        return ()
    if isinstance(a, str):
        return (a,)
    return tuple(a)


def spec_for(shape: Sequence[int], logical_axes: Sequence[Optional[str]],
             ctx: Optional[ShardingCtx] = None) -> P:
    """PartitionSpec for `shape` given per-dim logical axis names.

    Drops mesh axes that (a) don't exist in the mesh, (b) don't divide the dim
    size, or (c) were already used by an earlier dim.
    """
    ctx = ctx or current_ctx()
    if ctx is None:
        return P(*([None] * len(shape)))
    if len(shape) != len(logical_axes):
        raise ValueError(f"spec_for: shape {tuple(shape)} has {len(shape)} "
                         f"dims but logical_axes {tuple(logical_axes)} "
                         f"names {len(logical_axes)}")
    mesh_shape = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    used: set = set()
    out = []
    for size, name in zip(shape, logical_axes):
        mesh_axes = _as_tuple(ctx.rules.get(name)) if name else ()
        mesh_axes = tuple(a for a in mesh_axes
                          if a in mesh_shape and a not in used)
        # all-or-nothing per requested group, trimmed greedily
        picked: Tuple[str, ...] = ()
        extent = 1
        for a in mesh_axes:
            if size % (extent * mesh_shape[a]) == 0:
                picked += (a,)
                extent *= mesh_shape[a]
        used.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(picked)
    return P(*out)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside a ctx."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = spec_for(x.shape, logical_axes, ctx)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def _require_ctx(ctx: Optional[ShardingCtx], who: str) -> ShardingCtx:
    if ctx is None:
        raise ValueError(f"{who} requires an active sharding ctx "
                         "(use_sharding(mesh, rules) or an explicit ctx=)")
    return ctx


def _slot_axis(shape: Tuple[int, ...], batch: int,
               layers: Optional[int]) -> Optional[int]:
    """Which dim of a state leaf is the sample/slot batch dim, by the
    rank/leading-axis contract of ``core/policies/base.py``: the slot dim
    is the leading axis, except for layer-stacked trackers — a leading
    axis of extent ``layers`` or ``layers + 1`` followed by the batch
    extent puts the slot dim on axis 1.  Leaves without a batch-extent
    dim (scalars, schedule constants) replicate.  The layer rule is
    checked FIRST so (L, B) trackers resolve correctly even when
    ``L == batch``."""
    if (layers is not None and len(shape) >= 2
            and shape[0] in (layers, layers + 1) and shape[1] == batch):
        return 1
    if len(shape) >= 1 and shape[0] == batch:
        return 0
    return None


def serve_state_specs(state, ctx: Optional[ShardingCtx] = None, *,
                      batch: int, layers: Optional[int] = None):
    """Pytree of PartitionSpecs matching any cache policy's serving-state
    pytree (``CachedDiT.init_state(batch)``), under the ``kind="serve"``
    rules: slot rows over ``data``, everything else replicated (with the
    usual divisibility fallback).

    The walker names no state keys — it derives each leaf's spec from its
    rank and dim extents alone (``_slot_axis``), so a newly registered
    policy's state shards correctly without touching this module.
    ``batch`` is the state's sample-row count (the engine's slot rows,
    CFG pairs included); ``layers`` enables the layer-stacked rule and
    should be the model's block count."""
    ctx = ctx or current_ctx()
    ctx = _require_ctx(ctx, "serve_state_specs")

    def one(leaf):
        axis = _slot_axis(leaf.shape, batch, layers)
        logical = [None] * leaf.ndim
        if axis is not None:
            logical[axis] = "slot"
        return spec_for(leaf.shape, logical, ctx)

    return jax.tree.map(one, state)


def serve_state_shardings(state, ctx: Optional[ShardingCtx] = None, *,
                          batch: int, layers: Optional[int] = None):
    """NamedSharding tree for any cache policy's serving-state pytree."""
    ctx = ctx or current_ctx()
    ctx = _require_ctx(ctx, "serve_state_shardings")
    return jax.tree.map(lambda spec: NamedSharding(ctx.mesh, spec),
                        serve_state_specs(state, ctx, batch=batch,
                                          layers=layers),
                        is_leaf=lambda x: isinstance(x, P))


# Logical axes of the diffusion engine's per-slot sampling-plan tables
# (``DiffusionServingEngine.plan``): the (S, max_steps) ts/ts_prev timestep
# tables and the (S,) guidance vector all carry their slot dim on "slot",
# so under the kind="serve" rules a slot's plan rows live with the rest of
# that slot's state on the same `data` shard.
_SERVE_PLAN_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "ts": ("slot", None),
    "ts_prev": ("slot", None),
    "guidance": ("slot",),
}


def serve_plan_specs(plan, ctx: Optional[ShardingCtx] = None):
    """PartitionSpecs for the engine's sampling-plan tables, keyed like the
    ``plan`` dict (ts / ts_prev / guidance): slot rows over ``data``."""
    ctx = ctx or current_ctx()
    ctx = _require_ctx(ctx, "serve_plan_specs")
    return {k: spec_for(v.shape, _SERVE_PLAN_AXES[k], ctx)
            for k, v in plan.items()}


def serve_plan_shardings(plan, ctx: Optional[ShardingCtx] = None):
    """NamedSharding dict for the engine's sampling-plan tables."""
    ctx = ctx or current_ctx()
    ctx = _require_ctx(ctx, "serve_plan_shardings")
    return {k: NamedSharding(ctx.mesh, spec)
            for k, spec in serve_plan_specs(plan, ctx).items()}


def serve_snapshot_specs(snap, ctx: Optional[ShardingCtx] = None):
    """PartitionSpecs for a preemption snapshot — the pytree of one slot's
    rows (cache-policy state rows, the slot's latents, its plan-table rows
    and request-scoped accumulators) that the engines' ``_snapshot_impl``
    extracts when a request is preempted: fully REPLICATED, every leaf.

    Replication is deliberate, not a fallback: a snapshot must be
    restorable into ANY slot of the engine (re-admission after requeue
    rarely lands in the donor slot), and under a ``data``-sharded slot
    batch different slots live on different mesh positions.  A snapshot
    that kept its donor slot's shard would force a reshard inside the
    restore program whenever the target slot lives elsewhere — replicating
    the (single-slot-sized, tiny next to the resident batch) snapshot
    instead makes ``_restore`` a plain scatter for every target slot, one
    executable for all of them.  Works on concrete arrays and on the
    ``jax.eval_shape`` structs the engines derive the snapshot layout
    from."""
    ctx = ctx or current_ctx()
    ctx = _require_ctx(ctx, "serve_snapshot_specs")
    return jax.tree.map(lambda v: P(*([None] * v.ndim)), snap)


def serve_snapshot_shardings(snap, ctx: Optional[ShardingCtx] = None):
    """NamedSharding tree for a preemption snapshot (see
    ``serve_snapshot_specs``: everything replicated)."""
    ctx = ctx or current_ctx()
    ctx = _require_ctx(ctx, "serve_snapshot_shardings")
    return jax.tree.map(lambda spec: NamedSharding(ctx.mesh, spec),
                        serve_snapshot_specs(snap, ctx),
                        is_leaf=lambda x: isinstance(x, P))


def serve_metrics_specs(metrics, ctx: Optional[ShardingCtx] = None):
    """PartitionSpecs for the obs device-metrics pytree
    (``repro.obs.metrics.init_device_metrics``): the ``per_slot`` group's
    (S,) leaves shard over ``slot`` — they live with the rest of that
    slot's state on the same ``data`` shard — while counters and histogram
    bins replicate (they are whole-batch reductions; per-device partials
    would need a collective at every read).  The audit plane's extra
    leaves need no rule of their own: its per-slot accumulators land in
    ``per_slot`` and shard with the slot rows, and the small ``audit``
    group (per-layer error sums) replicates through the else branch like
    the counters.

    This is a dedicated walker rather than ``serve_state_specs`` on
    purpose: metrics shapes are structural (a histogram's bucket-count
    extent is set by its spec, not by the batch), so the rank/extent
    heuristics of ``_slot_axis`` could collide — e.g. a 4-slot engine and
    a 3-bucket histogram's 4-bin count vector are indistinguishable by
    shape alone."""
    ctx = ctx or current_ctx()
    ctx = _require_ctx(ctx, "serve_metrics_specs")
    out = {}
    for group, leaves in metrics.items():
        if group == "per_slot":
            out[group] = {k: spec_for(v.shape, ("slot",), ctx)
                          for k, v in leaves.items()}
        else:
            out[group] = jax.tree.map(
                lambda v: P(*([None] * v.ndim)), leaves)
    return out


def serve_metrics_shardings(metrics, ctx: Optional[ShardingCtx] = None):
    """NamedSharding tree for the obs device-metrics pytree."""
    ctx = ctx or current_ctx()
    ctx = _require_ctx(ctx, "serve_metrics_shardings")
    return jax.tree.map(lambda spec: NamedSharding(ctx.mesh, spec),
                        serve_metrics_specs(metrics, ctx),
                        is_leaf=lambda x: isinstance(x, P))


def param_shardings(defs, ctx: Optional[ShardingCtx] = None):
    """Pytree of NamedShardings matching a pytree of ParamDef."""
    from repro.models.params import ParamDef  # local to avoid cycle
    ctx = ctx or current_ctx()
    ctx = _require_ctx(ctx, "param_shardings")

    def one(d: ParamDef):
        return NamedSharding(ctx.mesh, spec_for(d.shape, d.axes, ctx))

    return jax.tree.map(one, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))
