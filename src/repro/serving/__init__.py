from repro.serving.diffusion_engine import DiffusionServingEngine  # noqa: F401
from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.scheduler import (DiffusionRequest,  # noqa: F401
                                     RequestQueue, poisson_trace)
from repro.serving.sharded_engine import (ShardedDiffusionEngine,  # noqa: F401
                                          make_serving_mesh)
