from repro.serving.diffusion_engine import DiffusionServingEngine  # noqa: F401
from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.scheduler import (SCHED_POLICIES,  # noqa: F401
                                     DiffusionRequest, RequestQueue,
                                     SamplingPlan, piecewise_rate,
                                     poisson_trace, summarize_by_class,
                                     summarize_by_steps)
from repro.serving.sharded_engine import (ShardedDiffusionEngine,  # noqa: F401
                                          make_serving_mesh)
from repro.serving.slo import (AdmissionController,  # noqa: F401
                               CompletionPredictor, DegradationController,
                               ReplicaRouter, ShedLevel, SLOScheduler)
