"""Batched serving engine: slot-based continuous batching over a fixed-size
decode batch, with optional FastCache decode gating.

The engine owns a KV cache sized (max_batch, window) and a slot table; new
requests prefill into free slots (per-request prefill, batched decode), decode
steps run the whole batch, finished sequences free their slots.  This is the
serving pattern the decode shapes (decode_32k / long_500k) lower: one
``serve_step`` = one batched decode step.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FastCacheConfig
from repro.core.decode_runner import CachedDecoder
from repro.models.transformer import TransformerModel
from repro.obs import metrics as obs_metrics
from repro.obs.metrics import MetricsCollector

F32 = jnp.float32


@dataclasses.dataclass(eq=False)
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: TransformerModel, params, *, max_batch: int,
                 window: int, eos_id: Optional[int] = None,
                 fastcache: Optional[FastCacheConfig] = None,
                 greedy: bool = True,
                 collector: Optional[MetricsCollector] = None):
        self.model = model
        self.params = params
        # AR decode fetches the sampled token every step by design, so its
        # metrics are host-plane only: plain Python counters on values the
        # loop already materializes (no extra device work or syncs)
        self.collector = collector
        self.max_batch = max_batch
        self.window = window
        self.eos_id = eos_id
        self.greedy = greedy
        self.cache = model.init_cache(max_batch, window)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.slot_tokens = np.zeros((max_batch,), np.int32)
        self.decoder = None
        if fastcache is not None and fastcache.enabled:
            self.decoder = CachedDecoder(model, fastcache)
            self.fc_state = self.decoder.init_state(max_batch)
            # headline counters accumulate only ACTIVE slots' decisions —
            # idle slots re-feed their stale token, trivially skip every
            # block, and would otherwise inflate the cache ratio
            self.active_blocks_skipped = 0.0
            self.active_blocks_computed = 0.0

        self._prefill = jax.jit(self._prefill_impl)
        if self.decoder is None:
            self._decode = jax.jit(self._decode_impl)
        else:
            self._decode = jax.jit(self._decode_fc_impl)

    # -- jitted bodies -------------------------------------------------

    def _prefill_impl(self, params, tokens, cache, slot):
        """Prefill ONE request (batch 1) and splice its cache into `slot`."""
        logits, new_cache = self.model.prefill(params, {"tokens": tokens},
                                               self.window)

        def splice(full, one):
            return full.at[:, slot].set(one[:, 0])

        # cache leaves: blocks/<pos>/<leaf>: (n_super, B, ...) ; step: (B,)
        spliced = jax.tree.map(
            lambda full, one: (full.at[slot].set(one[0]) if full.ndim == 1
                               else splice(full, one)),
            cache, new_cache)
        return logits[0], spliced

    def _decode_impl(self, params, tokens, cache):
        return self.model.decode_step(params, tokens, cache)

    def _decode_fc_impl(self, params, tokens, cache, fc_state):
        return self.decoder.decode_step(params, tokens, cache, fc_state)

    # -- host orchestration --------------------------------------------

    def add_request(self, req: Request) -> bool:
        for s in range(self.max_batch):
            if self.slots[s] is None:
                logits, self.cache = self._prefill(
                    self.params, jnp.asarray(req.prompt)[None], self.cache,
                    s)
                if self.decoder is not None:
                    # per-slot gating: re-arm only this slot's trackers — the
                    # other slots' caches stay valid across the admission
                    self.fc_state = self.decoder.reset_slot(self.fc_state, s)
                nxt = int(jnp.argmax(logits)) if self.greedy else int(
                    jax.random.categorical(jax.random.PRNGKey(req.rid),
                                           logits))
                req.generated.append(nxt)
                self.slots[s] = req
                self.slot_tokens[s] = nxt
                if self.collector is not None:
                    self.collector.inc(obs_metrics.ADMISSIONS)
                    self.collector.inc(obs_metrics.PREFILLS)
                return True
        return False

    def step(self) -> None:
        """One batched decode step for all active slots."""
        tokens = jnp.asarray(self.slot_tokens)
        n_active = sum(1 for r in self.slots if r is not None and not r.done)
        if self.decoder is None:
            logits, self.cache = self._decode(self.params, tokens, self.cache)
        else:
            active = np.array([r is not None and not r.done
                               for r in self.slots])
            before = {k: np.asarray(v)
                      for k, v in self.fc_state["stats"].items()
                      if k != "steps"}
            logits, self.cache, self.fc_state = self._decode(
                self.params, tokens, self.cache, self.fc_state)
            after = self.fc_state["stats"]
            d_skipped = float(
                (np.asarray(after["blocks_skipped"])
                 - before["blocks_skipped"])[active].sum())
            d_computed = float(
                (np.asarray(after["blocks_computed"])
                 - before["blocks_computed"])[active].sum())
            self.active_blocks_skipped += d_skipped
            self.active_blocks_computed += d_computed
            if self.collector is not None:
                self.collector.inc(obs_metrics.BLOCKS_SKIPPED, d_skipped)
                self.collector.inc(obs_metrics.BLOCKS_COMPUTED, d_computed)
        if self.collector is not None:
            self.collector.inc(obs_metrics.SERVE_STEPS)
            self.collector.inc(obs_metrics.ACTIVE_SLOT_STEPS, n_active)
            self.collector.inc(obs_metrics.DECODE_TOKENS, n_active)
            self.collector.observe(obs_metrics.ACTIVE_SLOTS, n_active)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for s, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            tok = int(nxt[s])
            req.generated.append(tok)
            self.slot_tokens[s] = tok
            if ((self.eos_id is not None and tok == self.eos_id)
                    or len(req.generated) >= req.max_new_tokens):
                req.done = True
                self.slots[s] = None
                if self.collector is not None:
                    self.collector.inc(obs_metrics.REQUESTS_FINISHED)
                    self.collector.observe(obs_metrics.REQUEST_LATENCY,
                                           len(req.generated))

    def run(self, requests: List[Request], max_steps: int = 1024
            ) -> List[Request]:
        pending = list(requests)
        finished: List[Request] = []
        active: List[Request] = []
        steps = 0
        while (pending or any(self.slots)) and steps < max_steps:
            while pending and self.add_request(pending[0]):
                active.append(pending.pop(0))
            self.step()
            steps += 1
            for r in active:
                if r.done and r not in finished:
                    finished.append(r)
        if self.collector is not None:
            self.collector.harvest(at_step=steps)
        return finished + [r for r in active if r not in finished]

    def cache_stats(self) -> Dict[str, float]:
        """Engine-lifetime cache counters.  The headline numbers count only
        decisions made while a slot had a live request (idle slots skip
        trivially); the raw per-slot (batch,) accumulators — which do
        include idle periods — are reported under per_slot_*."""
        if self.decoder is None:
            return {}
        s = self.fc_state["stats"]
        skipped = self.active_blocks_skipped
        tot = self.active_blocks_computed + skipped
        return {"blocks_skipped": skipped,
                "block_cache_ratio": skipped / tot if tot else 0.0,
                "per_slot_blocks_skipped": [
                    float(v) for v in jnp.asarray(s["blocks_skipped"])],
                "per_slot_blocks_computed": [
                    float(v) for v in jnp.asarray(s["blocks_computed"])]}
