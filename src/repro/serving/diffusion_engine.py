"""Continuous-batching serving engine for DiT sampling with per-slot
FastCache state — the diffusion twin of ``serving/engine.py``'s slot pattern.

The engine owns a fixed batch of ``max_slots`` generation slots.  Each slot
holds one request: its class label, its own **sampling plan** (DDIM step
budget + guidance scale), its own DDIM step index, its CFG pair (cond row
``s`` + uncond row ``S + s`` of the doubled model batch) and its per-slot
cache state inside the shared ``CachedDiT`` state (gate variance trackers,
cache payloads, policy counters — all (batch,)-indexed).  One jitted
``serve_step`` advances every active slot one denoising step; finished
slots emit latents and free immediately; queued requests are admitted into
free slots mid-flight.

**Heterogeneous plans.**  The denoising schedule is per-slot state, not
engine config: the engine keeps device-resident ``(S, max_steps)``
``ts``/``ts_prev`` plan tables plus a per-slot ``(S,)`` guidance vector,
and admission writes the request's plan rows inside the same fused
``_admit`` call that resets the slot's cache state and seeds its latents.
One batch therefore mixes 20-step and 50-step jobs at different guidance
scales; CFG rows are materialized by default, with ``guidance == 1.0``
expressed per-sample by the blend weights (bitwise-equal to an unguided
solo run — see ``sampler.denoise_step``).  Finish detection is per-slot:
slot ``s`` completes after its own ``slot_budget[s]`` steps.

**Static no-CFG fast path.**  ``cfg_rows=False`` opts a
guidance==1.0-only deployment out of the uncond half entirely: slots are
single state rows, the model batch is S instead of 2S (the pre-plan-table
cost for homogeneous unguided traffic), and requests carrying any other
guidance scale are rejected at admission.  Latents stay bitwise-equal to
the default engine at guidance 1.0 (the scalar-1.0 path in
``denoise_step`` statically skips CFG).

**Policy-agnostic state.**  The engine never names cache-state keys: the
policy's state is an opaque pytree (``CachedDiT.init_state``), slot resets
go through ``reset_slot``, and the per-request counters it accumulates are
whatever ``(batch,)`` stat keys the policy's ``stats`` block carries — so
a newly registered cache policy serves without edits here.

Safety of mid-flight admission rests on two properties of ``CachedDiT``:
every cache decision is per-sample (one slot's state never influences a
batchmate's outputs), and a mixed warm/cold batch warms the cold sample up
with a full forward while warm samples keep their gated path — so a request
admitted at engine step k reproduces its solo run from step 0 *under its
own plan*, and resident requests are untouched by the admission.

Headline cache counters accumulate only ACTIVE slots' decisions (idle slots
re-feed frozen latents, trivially skip, and would inflate the ratio) —
matching the ``serving/engine.py`` convention.  A second, request-scoped
per-slot accumulator is zeroed at admission and harvested into
``req.cache`` at completion, so workload analyses (e.g. cache ratio by step
budget) never need a per-step host sync.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runner import CachedDiT
from repro.diffusion import sampler
from repro.diffusion import schedule as sch
from repro.obs import audit as obs_audit
from repro.obs import metrics as obs_metrics
from repro.obs.metrics import MetricsCollector
from repro.obs.tracing import TraceRecorder
from repro.serving.scheduler import (DiffusionRequest, RequestQueue,
                                     SamplingPlan)

F32 = jnp.float32


class DiffusionServingEngine:
    def __init__(self, runner: CachedDiT, params, *, max_slots: int,
                 num_steps: int = 50, guidance_scale: float = 4.0,
                 num_train_steps: int = 1000,
                 max_steps: Optional[int] = None,
                 cfg_rows: bool = True,
                 collector: Optional[MetricsCollector] = None,
                 tracer: Optional[TraceRecorder] = None,
                 enable_metrics: bool = True,
                 audit_fraction: float = 0.0,
                 audit_seed: int = 0):
        # the bitwise admission-invariance contract needs per-sample gating:
        # global mode reduces the chi^2 statistic over the whole batch, so
        # an admission would silently change residents' gate decisions
        if runner.gate_mode != "per_sample":
            raise ValueError(
                "DiffusionServingEngine requires FastCacheConfig("
                f"gate_mode='per_sample'); got {runner.gate_mode!r}")
        # static no-CFG fast path: a deployment that will only ever serve
        # guidance==1.0 opts out of the uncond half entirely — single-row
        # slots, model batch S instead of 2S (requests asking for any other
        # guidance are rejected at resolve_plan)
        if not cfg_rows and guidance_scale != 1.0:
            raise ValueError(
                "cfg_rows=False is the guidance==1.0-only fast path; got "
                f"default guidance_scale={guidance_scale}")
        self.cfg_rows = cfg_rows
        self.rows_per_slot = 2 if cfg_rows else 1
        self.runner = runner
        self.params = params
        self.S = max_slots
        # (num_steps, guidance_scale) is the DEFAULT plan, applied to
        # requests that don't carry their own; max_steps is the plan-table
        # width — the largest per-request step budget this engine admits
        self.num_steps = num_steps
        self.guidance_scale = guidance_scale
        self.default_plan = SamplingPlan(num_steps, guidance_scale)
        self.max_steps = max_steps if max_steps is not None else num_steps
        if self.max_steps < num_steps:
            raise ValueError(f"max_steps={self.max_steps} < default "
                             f"num_steps={num_steps}")
        self.num_train_steps = num_train_steps
        cfg = runner.model.cfg
        self.img = cfg.dit.image_size
        self.ch = cfg.dit.in_channels

        self.sched = sch.linear_schedule(num_train_steps)
        # per-slot plan tables: row s is slot s's padded DDIM schedule (a
        # plan's rows land here inside the fused _admit call); every slot
        # starts on the default plan so idle rows still hold valid indices
        ts_row, prev_row = self.default_plan.rows(self.max_steps,
                                                  num_train_steps)
        self.plan = {
            "ts": jnp.tile(jnp.asarray(ts_row)[None], (max_slots, 1)),
            "ts_prev": jnp.tile(jnp.asarray(prev_row)[None], (max_slots, 1)),
            "guidance": jnp.full((max_slots,), guidance_scale, F32),
        }

        # CFG rows are materialized by default (guidance==1.0 is a
        # per-sample blend weight), so the state batch is fixed at 2S and
        # slots never resize when a different-guidance request lands; the
        # cfg_rows=False fast path drops the uncond half (state batch S)
        self.state = runner.init_state(self.rows_per_slot * max_slots)
        # per-slot counters the engine accumulates are whatever (batch,)
        # stat keys the POLICY's state carries — the engine names none
        self._acc_keys = tuple(k for k, v in self.state["stats"].items()
                               if getattr(v, "ndim", 0) == 1)
        # shadow-compute audit plane (obs/audit.py): on a deterministic
        # seeded fraction of serve steps, the jitted step also runs the
        # full uncached forward and accumulates cached-vs-true error into
        # the metrics pytree + the per-request slot accumulators.  The
        # fraction only picks which host-computed booleans are True — the
        # traced program is identical for every step, so audit-on steady
        # state stays compile-free.
        if not 0.0 <= audit_fraction <= 1.0:
            raise ValueError(f"audit_fraction must be in [0, 1], got "
                             f"{audit_fraction}")
        if audit_fraction > 0.0 and not enable_metrics:
            raise ValueError("audit_fraction > 0 needs the metrics plane; "
                             "enable_metrics=False has nowhere to "
                             "accumulate audit error")
        self.audit_fraction = float(audit_fraction)
        self.audit_seed = int(audit_seed)
        self._audit_on = audit_fraction > 0.0
        self._audit_bound = runner.audit_bound() if self._audit_on else None
        self.x = jnp.zeros((max_slots, self.img, self.img, self.ch), F32)
        self.slots: List[Optional[DiffusionRequest]] = [None] * max_slots
        self.slot_step = np.full((max_slots,), -1, np.int32)
        self.slot_budget = np.full((max_slots,), num_steps, np.int32)
        self.slot_label = np.zeros((max_slots,), np.int32)
        self.clock = 0                      # engine steps taken
        self.model_steps = 0                # steps that actually ran the DiT
        # active-slot-only counters (PR 1 convention), accumulated on-device
        # inside serve_step so the host never syncs per step; slot_acc is
        # the request-scoped view (zeroed at admission, harvested on finish)
        self.acc = self._zero_acc()
        self.slot_acc = self._zero_slot_acc()
        # device-resident metrics plane (obs): counters/histograms updated
        # with pure jnp inside the jitted step (donated like the state) and
        # harvested by the collector only at run end / window close — the
        # zero-sync rule.  enable_metrics=False traces the step without any
        # metric ops ({} is a static-empty pytree), for A/B overhead runs.
        self.collector = collector
        self.tracer = tracer
        self._metrics_on = enable_metrics
        audit_layers = (runner.L + 1) if self._audit_on else None
        self.metrics = (obs_metrics.init_device_metrics(
            max_slots, audit_layers=audit_layers,
            token_metrics=runner.reducer is not None)
            if enable_metrics else {})
        if collector is not None and self._audit_on:
            collector.set_audit_context(bound=self._audit_bound,
                                        fraction=self.audit_fraction)

        self._place_and_compile()

    def _place_and_compile(self) -> None:
        """Jit the engine's device entry points.  State, latents, plan
        tables and the stat accumulators are DONATED: they live in device
        buffers that are aliased step-over-step and never round-trip host
        memory (asserted in tests via buffer deletion + a device-to-host
        transfer guard).  ``ShardedDiffusionEngine`` overrides this to add
        mesh placement and explicit in/out shardings."""
        self._step = jax.jit(self._serve_step_impl,
                             donate_argnums=(1, 2, 7, 8, 9))
        self._reset = jax.jit(self.runner.reset_slot, donate_argnums=(0,))
        self._admit = jax.jit(self._admit_impl, donate_argnums=(0, 1, 2, 3))
        # preemption pair (serving/slo/): _snapshot extracts one slot's rows
        # into fresh buffers (NOT donated — the live state keeps serving),
        # _restore scatters a snapshot back with the same donation set as
        # _admit.  Both take the slot index as a traced scalar, so one
        # executable serves every slot.
        self._snapshot = jax.jit(self._snapshot_impl)
        self._restore = jax.jit(self._restore_impl,
                                donate_argnums=(0, 1, 2, 3))

    def _zero_acc(self) -> Dict[str, jax.Array]:
        return {k: jnp.zeros((), F32) for k in self._acc_keys}

    def _zero_slot_acc(self) -> Dict[str, jax.Array]:
        # with the audit plane on, the per-request error budget rides the
        # same accumulator: zeroed at admission, harvested into req.cache
        keys = self._acc_keys + (obs_audit.AUDIT_ACC_KEYS
                                 if self._audit_on else ())
        return {k: jnp.zeros((self.S,), F32) for k in keys}

    # -- jitted body ----------------------------------------------------

    def _serve_step_impl(self, params, state, x, plan, step_idx, labels,
                         active, acc, slot_acc, metrics, audit_flag):
        """Advance all slots one denoising step.  ``step_idx`` (S,) is each
        slot's position in ITS OWN plan row of the ``(S, max_steps)``
        tables; idle slots (active=False) run through the model as padding
        but their latents are frozen and their cache decisions are excluded
        from the ``acc`` headline counters.  ``audit_flag`` is the
        host-computed () boolean from the audit schedule — traced, so one
        executable serves audited and plain steps alike (always False when
        the audit plane is off; the cond below is then statically dead)."""
        idx = jnp.clip(step_idx, 0, self.max_steps - 1)
        t = jnp.take_along_axis(plan["ts"], idx[:, None], axis=1)[:, 0]
        t_prev = jnp.take_along_axis(plan["ts_prev"], idx[:, None],
                                     axis=1)[:, 0]
        before = state["stats"]
        guidance = plan["guidance"] if self.cfg_rows else 1.0
        # cfg_rows=False is the static no-CFG fast path: a scalar 1.0
        # statically disables guidance inside denoise_step, so the model
        # batch is S (no uncond half) instead of 2S
        if self._audit_on:  # static: the audit plane also needs the eps
            x_new, state, eps = sampler.denoise_step(
                self.runner, params, self.sched, state, x, t, t_prev,
                labels, guidance_scale=guidance, return_eps=True)
        else:
            x_new, state = sampler.denoise_step(
                self.runner, params, self.sched, state, x, t, t_prev,
                labels, guidance_scale=guidance)
        x_new = jnp.where(active[:, None, None, None], x_new, x)
        act_rows = (jnp.concatenate([active, active]) if self.cfg_rows
                    else active)
        delta = {k: (state["stats"][k] - before[k]) * act_rows
                 for k in acc}
        acc = {k: acc[k] + jnp.sum(delta[k]) for k in acc}
        fold = ((lambda d: d[:self.S] + d[self.S:]) if self.cfg_rows
                else (lambda d: d))
        slot_acc = {**slot_acc,
                    **{k: slot_acc[k] + fold(delta[k]) for k in delta}}
        if self._metrics_on:  # static: off traces a metrics-free step
            metrics = self._update_metrics(metrics, active, delta)
        if self._audit_on:  # static: off is a plain cached-only step
            metrics, slot_acc = obs_audit.apply_audit(
                self.runner, params, self.sched, state, x, t, t_prev,
                labels, guidance, active, eps, self.cfg_rows,
                self._audit_bound, metrics, slot_acc, audit_flag)
        return x_new, state, acc, slot_acc, metrics

    def _update_metrics(self, metrics, active, delta):
        """Pure-jnp device-metrics updates folded into the jitted step —
        a handful of fused scalar ops against the full DiT forward.  Keys
        the policy's stats block does not carry are simply not counted."""
        act_f = active.astype(F32)
        n_act = jnp.sum(act_f)
        metrics = obs_metrics.inc(metrics, obs_metrics.SERVE_STEPS, 1.0)
        metrics = obs_metrics.inc(metrics, obs_metrics.ACTIVE_SLOT_STEPS,
                                  n_act)
        for name, key in ((obs_metrics.BLOCKS_COMPUTED, "blocks_computed"),
                          (obs_metrics.BLOCKS_SKIPPED, "blocks_skipped"),
                          (obs_metrics.STEP_REUSES, "steps_reused")):
            if key in delta:
                metrics = obs_metrics.inc(metrics, name,
                                          jnp.sum(delta[key]))
        metrics = obs_metrics.observe(metrics, obs_metrics.ACTIVE_SLOTS,
                                      n_act)
        if "steps_reused" in delta:
            rows = float(self.rows_per_slot)
            frac = jnp.sum(delta["steps_reused"]) / jnp.maximum(
                n_act * rows, 1.0)
            metrics = obs_metrics.observe(metrics,
                                          obs_metrics.SKIP_FRACTION, frac)
        if "tokens_merged" in delta:
            # token-compression stage on (runner.reducer): stats carry the
            # per-row kept/merged token counts; per-slot we accumulate the
            # realized kept/(kept+merged) ratio (idle slots contribute 0)
            fold = ((lambda d: d[:self.S] + d[self.S:]) if self.cfg_rows
                    else (lambda d: d))
            kept, merged = fold(delta["tokens_kept"]), fold(
                delta["tokens_merged"])
            metrics = obs_metrics.inc(metrics, obs_metrics.TOKENS_KEPT,
                                      jnp.sum(delta["tokens_kept"]))
            metrics = obs_metrics.inc(metrics, obs_metrics.TOKENS_MERGED,
                                      jnp.sum(delta["tokens_merged"]))
            ratio = kept / jnp.maximum(kept + merged, 1.0)
            metrics = obs_metrics.slot_add(
                metrics, obs_metrics.SLOT_MERGE_RATIO, ratio)
        return obs_metrics.slot_add(metrics,
                                    obs_metrics.SLOT_ACTIVE_STEPS, act_f)

    def _admit_impl(self, state, x, plan, slot_acc, rows, slot, noise,
                    ts_row, ts_prev_row, guid):
        """Admission writes for one slot, fused into a single donated call:
        reset the slot's gate/cache rows, seed its latents, land its plan
        rows (timestep table rows + guidance scale) and zero its
        request-scoped counters.  Runs as one device program so mid-flight
        admission costs one dispatch and no state copy."""
        state = self.runner.reset_slot(state, rows)
        x = x.at[slot].set(noise)
        plan = {
            "ts": plan["ts"].at[slot].set(ts_row),
            "ts_prev": plan["ts_prev"].at[slot].set(ts_prev_row),
            "guidance": plan["guidance"].at[slot].set(guid),
        }
        slot_acc = {k: v.at[slot].set(0.0) for k, v in slot_acc.items()}
        return state, x, plan, slot_acc

    def _snapshot_impl(self, state, x, plan, slot_acc, rows, slot):
        """Preemption checkpoint for one slot, extracted device-side in a
        single dispatch: the slot's rows of the policy state pytree
        (``snapshot_slot`` — includes ``tokred`` rows when the merge stage
        is on), its latents, its plan-table rows and its request-scoped
        accumulators.  Everything a re-admission needs to resume the
        request bitwise — crucially the ``slot_acc`` row rides along so
        the request's cache counters survive the requeue instead of being
        re-zeroed by ``_admit``."""
        return {
            "state": self.runner.snapshot_slot(state, rows),
            "x": jnp.take(x, slot, axis=0),
            "ts": jnp.take(plan["ts"], slot, axis=0),
            "ts_prev": jnp.take(plan["ts_prev"], slot, axis=0),
            "guidance": jnp.take(plan["guidance"], slot, axis=0),
            "slot_acc": {k: jnp.take(v, slot, axis=0)
                         for k, v in slot_acc.items()},
        }

    def _restore_impl(self, state, x, plan, slot_acc, snap, rows, slot):
        """The donated mirror of ``_admit_impl`` for resumed requests:
        scatter a ``_snapshot_impl`` checkpoint into (possibly different)
        slot ``slot`` — restore the policy-state rows bitwise, land the
        half-denoised latents, the plan rows and the preserved counter
        row.  One device program, bitwise-invisible to resident slots."""
        state = self.runner.restore_slot(state, snap["state"], rows)
        x = x.at[slot].set(snap["x"])
        plan = {
            "ts": plan["ts"].at[slot].set(snap["ts"]),
            "ts_prev": plan["ts_prev"].at[slot].set(snap["ts_prev"]),
            "guidance": plan["guidance"].at[slot].set(snap["guidance"]),
        }
        slot_acc = {k: v.at[slot].set(snap["slot_acc"][k])
                    for k, v in slot_acc.items()}
        return state, x, plan, slot_acc

    # -- host orchestration ---------------------------------------------

    def _slot_rows(self, s: int) -> jnp.ndarray:
        """State rows owned by slot s (the CFG cond/uncond pair, or the
        single cond row on the cfg_rows=False fast path)."""
        if self.cfg_rows:
            return jnp.array([s, self.S + s], jnp.int32)
        return jnp.array([s], jnp.int32)

    def request_noise(self, req: DiffusionRequest) -> jax.Array:
        """The request's deterministic initial latents, (img, img, ch) —
        shared with solo replays (``sample(..., x_init=noise[None])``)."""
        return jax.random.normal(jax.random.PRNGKey(req.seed),
                                 (self.img, self.img, self.ch), F32)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.S) if self.slots[s] is None]

    def reset_clock(self) -> None:
        """Rewind the step clock and headline counters (e.g. after a warm-up
        trace, so a timed trace's absolute arrival steps line up).  Requires
        an idle engine; per-slot raw accumulators keep their history."""
        if any(r is not None for r in self.slots):
            raise ValueError("reset_clock requires an idle engine; slots "
                             f"{[s for s, r in enumerate(self.slots) if r is not None]} "
                             "still hold requests")
        self.clock = 0
        self.model_steps = 0
        self.acc = self._zero_acc()

    def resolve_plan(self, req: DiffusionRequest) -> SamplingPlan:
        """The request's concrete sampling plan: its own
        ``num_steps``/``guidance_scale`` where set, the engine defaults
        otherwise.  The resolved values are written back onto the request
        so a finished request records the exact plan it ran under (solo
        replays read them)."""
        n = req.num_steps if req.num_steps is not None else self.num_steps
        g = (req.guidance_scale if req.guidance_scale is not None
             else self.guidance_scale)
        if n > self.max_steps:
            raise ValueError(
                f"request rid={req.rid} wants num_steps={n} but this "
                f"engine's plan tables are max_steps={self.max_steps} "
                f"wide; construct the engine with max_steps>={n}")
        if not self.cfg_rows and g != 1.0:
            raise ValueError(
                f"request rid={req.rid} wants guidance_scale={g} but this "
                f"engine runs the cfg_rows=False no-CFG fast path "
                f"(guidance==1.0 only; no uncond rows are materialized)")
        req.num_steps, req.guidance_scale = n, float(g)
        return SamplingPlan(n, float(g))

    def _staged_noise(self, req: DiffusionRequest) -> jax.Array:
        """Initial latents staged for an admission write.  The sharded
        engine overrides this to land the noise via ``jax.device_put`` with
        the slot's shard spec (overlapping the in-flight step)."""
        return self.request_noise(req)

    def _staged_plan(self, ts_row: np.ndarray, ts_prev_row: np.ndarray
                     ) -> Tuple[jax.Array, jax.Array]:
        """Plan-table rows staged for an admission write; the sharded
        engine lands them via the same per-slot ``device_put`` mechanism as
        the noise."""
        return jnp.asarray(ts_row), jnp.asarray(ts_prev_row)

    def add_request(self, req: DiffusionRequest) -> bool:
        """Admit one request into a free slot (mid-flight is fine): seed its
        latents, land its plan rows and fully reset the slot's gate/cache
        state — one donated device call, bitwise-invisible to resident
        slots.  A request carrying a preemption snapshot resumes instead:
        its checkpointed rows are scattered into the slot bitwise (any free
        slot, not just the donor), its step index picks up at
        ``steps_done``, and its cache accumulators carry over."""
        free = self.free_slots()
        if not free:
            return False
        s = free[0]
        if req.snapshot is not None:
            return self._resume_request(req, s)
        plan = self.resolve_plan(req)
        ts_row, prev_row = plan.rows(self.max_steps, self.num_train_steps)
        self.state, self.x, self.plan, self.slot_acc = self._admit(
            self.state, self.x, self.plan, self.slot_acc,
            self._slot_rows(s), jnp.asarray(s, jnp.int32),
            self._staged_noise(req), *self._staged_plan(ts_row, prev_row),
            jnp.asarray(plan.guidance_scale, F32))
        self.slots[s] = req
        self.slot_step[s] = 0
        self.slot_budget[s] = plan.num_steps
        self.slot_label[s] = req.label
        req.admit_step = self.clock
        req.queue_wait_steps = max(self.clock - req.arrival_step, 0)
        if self.collector is not None:
            self.collector.inc(obs_metrics.ADMISSIONS)
            self.collector.observe(obs_metrics.QUEUE_WAIT,
                                   req.queue_wait_steps)
        if self.tracer is not None:
            self.tracer.admit(req.rid, s, label=req.label,
                              num_steps=plan.num_steps,
                              engine_step=self.clock)
        return True

    def _resume_request(self, req: DiffusionRequest, s: int) -> bool:
        """Re-admit a preempted request from its device-side snapshot into
        free slot ``s``.  The snapshot is consumed; the request's plan was
        resolved at first admission, so no re-resolution (and no shedding
        re-scaling) happens here — the resumed run must replay the original
        plan bitwise."""
        snap, req.snapshot = req.snapshot, None
        self.state, self.x, self.plan, self.slot_acc = self._restore(
            self.state, self.x, self.plan, self.slot_acc, snap,
            self._slot_rows(s), jnp.asarray(s, jnp.int32))
        self.slots[s] = req
        self.slot_step[s] = req.steps_done
        self.slot_budget[s] = req.num_steps
        self.slot_label[s] = req.label
        if self.collector is not None:
            self.collector.inc(obs_metrics.RESUMES)
        if self.tracer is not None:
            self.tracer.admit(req.rid, s, label=req.label,
                              num_steps=req.num_steps,
                              engine_step=self.clock)
        return True

    def preempt(self, s: int) -> DiffusionRequest:
        """Checkpoint slot ``s``'s in-flight request out of the engine: a
        device-side row snapshot (policy-state rows incl. ``tokred``,
        latents, plan rows, request-scoped accumulators) lands on the
        request, the slot frees immediately, and the caller requeues the
        request for later ``add_request`` re-admission — which resumes it
        bitwise.  No host round-trip: the snapshot stays in device
        buffers."""
        req = self.slots[s]
        if req is None:
            raise ValueError(f"preempt: slot {s} holds no request")
        req.snapshot = self._snapshot(self.state, self.x, self.plan,
                                      self.slot_acc, self._slot_rows(s),
                                      jnp.asarray(s, jnp.int32))
        req.steps_done = int(self.slot_step[s])
        req.preemptions += 1
        self.slots[s] = None
        self.slot_step[s] = -1
        # same convention as completion-free: a freed slot never carries
        # stale gate/cache state
        self.state = self._reset(self.state, self._slot_rows(s))
        if self.collector is not None:
            self.collector.inc(obs_metrics.PREEMPTIONS)
        if self.tracer is not None:
            self.tracer.finish(req.rid, engine_step=self.clock)
        return req

    def step(self) -> List[DiffusionRequest]:
        """One engine step: advance all active slots one denoising step.
        Returns the requests that finished on this step (slots freed) —
        each after its OWN plan's step budget."""
        active = np.array([r is not None for r in self.slots])
        self.clock += 1
        if not active.any():            # idle tick: time passes, no compute
            return []
        # the audit schedule is a host-side hash of the model-step counter:
        # the jit only ever sees the resulting traced () boolean, so the
        # sampled schedule never recompiles (and is False forever when the
        # audit plane is off)
        audit_now = self._audit_on and obs_audit.audit_mask(
            self.model_steps, self.audit_fraction, self.audit_seed)
        aflag = jnp.asarray(audit_now)
        if self.tracer is not None:
            with self.tracer.step_begin(self.clock,
                                        active=int(active.sum())):
                (self.x, self.state, self.acc, self.slot_acc,
                 self.metrics) = self._step(
                    self.params, self.state, self.x, self.plan,
                    jnp.asarray(np.where(active,
                                         self.slot_step, 0).astype(np.int32)),
                    jnp.asarray(self.slot_label), jnp.asarray(active),
                    self.acc, self.slot_acc, self.metrics, aflag)
            self.tracer.snapshot_slots(self.clock, active, self.slot_acc)
        else:
            (self.x, self.state, self.acc, self.slot_acc,
             self.metrics) = self._step(
                self.params, self.state, self.x, self.plan,
                jnp.asarray(np.where(active,
                                     self.slot_step, 0).astype(np.int32)),
                jnp.asarray(self.slot_label), jnp.asarray(active), self.acc,
                self.slot_acc, self.metrics, aflag)
        self.model_steps += 1

        finished: List[DiffusionRequest] = []
        done_slots = []
        for s in np.flatnonzero(active):
            self.slot_step[s] += 1
            if self.slot_step[s] >= self.slot_budget[s]:
                done_slots.append(int(s))
        if done_slots:
            self._harvest(done_slots)
            for s in done_slots:
                req = self.slots[s]
                req.finish_step = self.clock
                req.done = True
                if req.cache is not None:
                    # control-plane accounting rides the harvested counters
                    # (plain host floats — the sharded engine's deferred
                    # materialization passes them through unchanged)
                    req.cache["queue_wait_steps"] = float(
                        max(req.queue_wait_steps, 0))
                    req.cache["preemptions"] = float(req.preemptions)
                if self.collector is not None:
                    self.collector.inc(obs_metrics.REQUESTS_FINISHED)
                    self.collector.observe(obs_metrics.REQUEST_LATENCY,
                                           req.finish_step - req.arrival_step)
                    if (req.deadline_step is not None
                            and req.finish_step > req.deadline_step):
                        self.collector.inc(obs_metrics.DEADLINE_MISSES)
                if self.tracer is not None:
                    self.tracer.finish(req.rid, engine_step=self.clock)
                finished.append(req)
                # free immediately: reset on free as well as on admission,
                # so a freed slot never carries stale gate/cache state
                self.slots[s] = None
                self.slot_step[s] = -1
                # (the reset leaves the padding row cold, so the next step
                # pays one mixed warm-up; a stale-cache-free slot table is
                # worth that once-per-completion cost)
                self.state = self._reset(self.state, self._slot_rows(s))
        return finished

    def _harvest(self, done_slots: List[int]) -> None:
        """Fill ``req.latents`` and ``req.cache`` (the request-scoped cache
        counters) for finished slots.  Synchronous by default (one blocking
        device->host fetch per completion step); the async sharded engine
        overrides this with deferred device-side copies so the dispatch
        loop never blocks on the in-flight step."""
        x_host = np.asarray(self.x)
        acc_host = {k: np.asarray(v) for k, v in self.slot_acc.items()}
        for s in done_slots:
            req = self.slots[s]
            req.latents = x_host[s].copy()
            req.cache = {k: float(v[s]) for k, v in acc_host.items()}

    def run(self, requests: Union[List[DiffusionRequest], RequestQueue],
            *, lockstep: bool = False, sched_policy: str = "fifo",
            max_engine_steps: int = 100_000) -> List[DiffusionRequest]:
        """Drive a whole trace.  ``lockstep=False`` (continuous batching)
        admits arrived requests into free slots every step; ``lockstep=True``
        is the fixed-batch baseline — a new wave is admitted only once every
        slot is free (the classic ``sample()``-per-batch serving pattern).
        ``sched_policy`` ("fifo" or "sjf") picks the admission order among
        arrived requests when ``requests`` is a plain list; pass a
        ``RequestQueue`` to control the policy yourself."""
        queue = (requests if isinstance(requests, RequestQueue)
                 else RequestQueue(list(requests), policy=sched_policy))
        finished: List[DiffusionRequest] = []
        window = (self.collector.window_steps
                  if self.collector is not None else None)
        while (queue or any(r is not None for r in self.slots)):
            if self.clock >= max_engine_steps:
                break
            if not lockstep or all(r is None for r in self.slots):
                while (len(self.free_slots())
                       and queue.peek_arrived(self.clock)):
                    self.add_request(queue.pop_arrived(self.clock))
            finished.extend(self.step())
            if window and self.clock % window == 0:
                # periodic window close: a sanctioned sync point (the only
                # one besides run end) — fetches the small metrics pytree
                self.harvest_metrics()
        if self.collector is not None:
            self.harvest_metrics()      # run end: the standing sync point
        self.finalize_requests(finished)
        return finished

    def finalize_requests(self, finished: List[DiffusionRequest]) -> None:
        """End-of-drive hook for whoever owns the loop (``run`` here, the
        SLO control plane's ``SLOScheduler.run``/``ReplicaRouter.run``
        otherwise): materialize anything a finished request still holds as
        device references.  No-op for this engine (``_harvest`` is already
        synchronous); the async sharded engine overrides it with its
        single end-of-run sync."""

    # -- stats ----------------------------------------------------------

    def harvest_metrics(self) -> Optional[Dict]:
        """Materialize the device metrics pytree into the collector — THE
        metrics sync point.  Called at run end and at periodic window
        closes; never from the per-step path (reprolint's obs-discipline
        check proves harvest is unreachable from any jit region)."""
        if self.collector is None:
            return None
        return self.collector.harvest(self.metrics or None,
                                      at_step=self.clock)

    def cache_stats(self) -> Dict:
        """Engine-lifetime cache counters under the active-slots-only
        convention; raw per-slot (batch,) accumulators — which include idle
        padding steps — under per_slot_*.  Tolerant of any policy's stats
        pytree: counters a policy does not carry report 0.0."""
        def acc(k):
            return float(self.acc.get(k, 0.0))

        def per_slot(k):
            v = self.state["stats"].get(k)
            rows = self.rows_per_slot * self.S
            return ([0.0] * rows if v is None
                    else [float(x) for x in np.asarray(v)])

        skipped, computed = acc("blocks_skipped"), acc("blocks_computed")
        tot = computed + skipped
        return {
            "policy": self.runner.policy,
            "engine_steps": self.clock,
            "model_steps": self.model_steps,
            "blocks_skipped": skipped,
            "blocks_computed": computed,
            "block_cache_ratio": skipped / tot if tot else 0.0,
            "steps_reused": acc("steps_reused"),
            "per_slot_blocks_skipped": per_slot("blocks_skipped"),
            "per_slot_blocks_computed": per_slot("blocks_computed"),
        }
