"""Continuous-batching serving engine for DiT sampling with per-slot
FastCache state — the diffusion twin of ``serving/engine.py``'s slot pattern.

The engine owns a fixed batch of ``max_slots`` generation slots.  Each slot
holds one request: its class label, its own DDIM step index, its CFG pair
(cond row ``s`` + uncond row ``S + s`` of the doubled model batch) and its
per-slot cache state inside the shared ``CachedDiT`` state (gate variance
trackers, cache payloads, policy counters — all (batch,)-indexed).  One
jitted ``serve_step`` advances every active slot one denoising step over a
per-sample timestep vector (slots sit at *different* schedule positions);
finished slots emit latents and free immediately; queued requests are
admitted into free slots mid-flight.

Safety of mid-flight admission rests on two properties of ``CachedDiT``:
every cache decision is per-sample (one slot's state never influences a
batchmate's outputs), and a mixed warm/cold batch warms the cold sample up
with a full forward while warm samples keep their gated path — so a request
admitted at engine step k reproduces its solo run from step 0, and resident
requests are untouched by the admission.

Headline cache counters accumulate only ACTIVE slots' decisions (idle slots
re-feed frozen latents, trivially skip, and would inflate the ratio) —
matching the ``serving/engine.py`` convention.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runner import CachedDiT
from repro.diffusion import sampler
from repro.diffusion import schedule as sch
from repro.serving.scheduler import DiffusionRequest, RequestQueue

F32 = jnp.float32


class DiffusionServingEngine:
    def __init__(self, runner: CachedDiT, params, *, max_slots: int,
                 num_steps: int = 50, guidance_scale: float = 4.0,
                 num_train_steps: int = 1000):
        # the bitwise admission-invariance contract needs per-sample gating:
        # global mode reduces the chi^2 statistic over the whole batch, so
        # an admission would silently change residents' gate decisions
        assert runner.gate_mode == "per_sample", (
            "DiffusionServingEngine requires FastCacheConfig("
            f"gate_mode='per_sample'); got {runner.gate_mode!r}")
        self.runner = runner
        self.params = params
        self.S = max_slots
        self.num_steps = num_steps
        self.num_train_steps = num_train_steps
        self.guidance_scale = guidance_scale
        self.use_cfg = guidance_scale != 1.0
        cfg = runner.model.cfg
        self.img = cfg.dit.image_size
        self.ch = cfg.dit.in_channels

        self.sched = sch.linear_schedule(num_train_steps)
        ts = sch.ddim_timesteps(num_train_steps, num_steps)
        self.ts = ts
        self.ts_prev = jnp.concatenate([ts[1:], jnp.array([-1], jnp.int32)])

        eff = 2 * max_slots if self.use_cfg else max_slots
        self.state = runner.init_state(eff)
        self.x = jnp.zeros((max_slots, self.img, self.img, self.ch), F32)
        self.slots: List[Optional[DiffusionRequest]] = [None] * max_slots
        self.slot_step = np.full((max_slots,), -1, np.int32)
        self.slot_label = np.zeros((max_slots,), np.int32)
        self.clock = 0                      # engine steps taken
        self.model_steps = 0                # steps that actually ran the DiT
        # active-slot-only counters (PR 1 convention), accumulated on-device
        # inside serve_step so the host never syncs per step
        self.acc = self._zero_acc()

        self._place_and_compile()

    def _place_and_compile(self) -> None:
        """Jit the engine's device entry points.  State, latents and the
        stat accumulators are DONATED: the cache state lives in device
        buffers that are aliased step-over-step and never round-trip host
        memory (asserted in tests via buffer deletion + a device-to-host
        transfer guard).  ``ShardedDiffusionEngine`` overrides this to add
        mesh placement and explicit in/out shardings."""
        self._step = jax.jit(self._serve_step_impl,
                             donate_argnums=(1, 2, 6))
        self._reset = jax.jit(self.runner.reset_slot, donate_argnums=(0,))
        self._admit = jax.jit(self._admit_impl, donate_argnums=(0, 1))

    @staticmethod
    def _zero_acc() -> Dict[str, jax.Array]:
        return {k: jnp.zeros((), F32)
                for k in ("blocks_skipped", "blocks_computed",
                          "steps_reused")}

    # -- jitted body ----------------------------------------------------

    def _serve_step_impl(self, params, state, x, step_idx, labels, active,
                         acc):
        """Advance all slots one denoising step.  ``step_idx`` (S,) is each
        slot's DDIM schedule position; idle slots (active=False) run through
        the model as padding but their latents are frozen and their cache
        decisions are excluded from the ``acc`` headline counters."""
        idx = jnp.clip(step_idx, 0, self.num_steps - 1)
        t = self.ts[idx]
        t_prev = self.ts_prev[idx]
        before = state["stats"]
        x_new, state = sampler.denoise_step(
            self.runner, params, self.sched, state, x, t, t_prev, labels,
            guidance_scale=self.guidance_scale)
        x_new = jnp.where(active[:, None, None, None], x_new, x)
        act_rows = (jnp.concatenate([active, active]) if self.use_cfg
                    else active)
        acc = {k: acc[k] + jnp.sum((state["stats"][k] - before[k])
                                   * act_rows) for k in acc}
        return x_new, state, acc

    def _admit_impl(self, state, x, rows, slot, noise):
        """Admission writes for one slot, fused into a single donated call:
        reset the slot's gate/cache rows and seed its latents.  Runs as one
        device program so mid-flight admission costs one dispatch and no
        state copy."""
        state = self.runner.reset_slot(state, rows)
        x = x.at[slot].set(noise)
        return state, x

    # -- host orchestration ---------------------------------------------

    def _slot_rows(self, s: int) -> jnp.ndarray:
        """State rows owned by slot s (the CFG cond/uncond pair)."""
        rows = [s, self.S + s] if self.use_cfg else [s]
        return jnp.array(rows, jnp.int32)

    def request_noise(self, req: DiffusionRequest) -> jax.Array:
        """The request's deterministic initial latents, (img, img, ch) —
        shared with solo replays (``sample(..., x_init=noise[None])``)."""
        return jax.random.normal(jax.random.PRNGKey(req.seed),
                                 (self.img, self.img, self.ch), F32)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.S) if self.slots[s] is None]

    def reset_clock(self) -> None:
        """Rewind the step clock and headline counters (e.g. after a warm-up
        trace, so a timed trace's absolute arrival steps line up).  Requires
        an idle engine; per-slot raw accumulators keep their history."""
        assert all(r is None for r in self.slots), "engine not idle"
        self.clock = 0
        self.model_steps = 0
        self.acc = self._zero_acc()

    def _staged_noise(self, req: DiffusionRequest) -> jax.Array:
        """Initial latents staged for an admission write.  The sharded
        engine overrides this to land the noise via ``jax.device_put`` with
        the slot's shard spec (overlapping the in-flight step)."""
        return self.request_noise(req)

    def add_request(self, req: DiffusionRequest) -> bool:
        """Admit one request into a free slot (mid-flight is fine): seed its
        latents and fully reset the slot's gate/cache state — one donated
        device call, bitwise-invisible to resident slots."""
        free = self.free_slots()
        if not free:
            return False
        s = free[0]
        self.state, self.x = self._admit(
            self.state, self.x, self._slot_rows(s),
            jnp.asarray(s, jnp.int32), self._staged_noise(req))
        self.slots[s] = req
        self.slot_step[s] = 0
        self.slot_label[s] = req.label
        req.admit_step = self.clock
        return True

    def step(self) -> List[DiffusionRequest]:
        """One engine step: advance all active slots one denoising step.
        Returns the requests that finished on this step (slots freed)."""
        active = np.array([r is not None for r in self.slots])
        self.clock += 1
        if not active.any():            # idle tick: time passes, no compute
            return []
        self.x, self.state, self.acc = self._step(
            self.params, self.state, self.x,
            jnp.asarray(np.where(active, self.slot_step, 0).astype(np.int32)),
            jnp.asarray(self.slot_label), jnp.asarray(active), self.acc)
        self.model_steps += 1

        finished: List[DiffusionRequest] = []
        done_slots = []
        for s in np.flatnonzero(active):
            self.slot_step[s] += 1
            if self.slot_step[s] >= self.num_steps:
                done_slots.append(int(s))
        if done_slots:
            self._harvest(done_slots)
            for s in done_slots:
                req = self.slots[s]
                req.finish_step = self.clock
                req.done = True
                finished.append(req)
                # free immediately: reset on free as well as on admission,
                # so a freed slot never carries stale gate/cache state
                self.slots[s] = None
                self.slot_step[s] = -1
                # (the reset leaves the padding row cold, so the next step
                # pays one mixed warm-up; a stale-cache-free slot table is
                # worth that once-per-completion cost)
                self.state = self._reset(self.state, self._slot_rows(s))
        return finished

    def _harvest(self, done_slots: List[int]) -> None:
        """Fill ``req.latents`` for finished slots.  Synchronous by default
        (one blocking device->host fetch per completion step); the async
        sharded engine overrides this with a deferred device-side copy so
        the dispatch loop never blocks on the in-flight step."""
        x_host = np.asarray(self.x)
        for s in done_slots:
            self.slots[s].latents = x_host[s].copy()

    def run(self, requests: Union[List[DiffusionRequest], RequestQueue],
            *, lockstep: bool = False, max_steps: int = 100_000
            ) -> List[DiffusionRequest]:
        """Drive a whole trace.  ``lockstep=False`` (continuous batching)
        admits arrived requests into free slots every step; ``lockstep=True``
        is the fixed-batch baseline — a new wave is admitted only once every
        slot is free (the classic ``sample()``-per-batch serving pattern)."""
        queue = (requests if isinstance(requests, RequestQueue)
                 else RequestQueue(list(requests)))
        finished: List[DiffusionRequest] = []
        while (queue or any(r is not None for r in self.slots)):
            if self.clock >= max_steps:
                break
            if not lockstep or all(r is None for r in self.slots):
                while (len(self.free_slots())
                       and queue.peek_arrived(self.clock)):
                    self.add_request(queue.pop_arrived(self.clock))
            finished.extend(self.step())
        return finished

    # -- stats ----------------------------------------------------------

    def cache_stats(self) -> Dict:
        """Engine-lifetime cache counters under the active-slots-only
        convention; raw per-slot (batch,) accumulators — which include idle
        padding steps — under per_slot_*."""
        skipped = float(self.acc["blocks_skipped"])
        computed = float(self.acc["blocks_computed"])
        tot = computed + skipped
        s = self.state["stats"]
        return {
            "policy": self.runner.policy,
            "engine_steps": self.clock,
            "model_steps": self.model_steps,
            "blocks_skipped": skipped,
            "blocks_computed": computed,
            "block_cache_ratio": skipped / tot if tot else 0.0,
            "steps_reused": float(self.acc["steps_reused"]),
            "per_slot_blocks_skipped": [
                float(v) for v in np.asarray(s["blocks_skipped"])],
            "per_slot_blocks_computed": [
                float(v) for v in np.asarray(s["blocks_computed"])],
        }
