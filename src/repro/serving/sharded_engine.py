"""Mesh-parallel diffusion serving: the multi-device runtime layer over
``DiffusionServingEngine``.

``ShardedDiffusionEngine`` places the slot batch on a ``(data, model)``
mesh:

- **slots over data** — the latent batch (S, H, W, C) and every per-slot
  row of the FastCache state (cache payloads, chi^2 sigma trackers, policy
  counters, stat accumulators) shard over the ``data`` axis via the
  ``kind="serve"`` rule set in ``distributed/sharding.py``
  (``serve_state_shardings``);
- **weights over model** — DiT params shard tensor-parallel through the
  same ``param_shardings`` tables the training launcher uses;
- the jitted ``serve_step`` takes **donated** state buffers with explicit
  in/out shardings, so cache state is aliased device-resident step over
  step and never round-trips host memory.

On top sits an **async dispatch loop**: JAX dispatch is already
asynchronous, so the host races ahead of the accelerator as long as nothing
forces a sync.  The two host syncs of the single-device engine are removed:

- *admission*: queue pops, slot assignment and noise generation happen on
  the host while step k is in flight; the noise lands through a per-slot
  ``jax.device_put`` with the slot's shard spec (the x-spec minus the slot
  axis, i.e. the layout of one resident row), and the fused
  ``reset+seed`` admission program is enqueued *behind* step k — double
  buffering: the device always has step k+1's work queued before step k
  retires, and mid-flight admission stays bitwise-invisible to resident
  samples (``CachedDiT._fastcache_mixed_step`` warms the cold rows);
- *completion*: finished slots' latents are captured as device-side row
  copies (enqueued, not fetched); the single blocking device->host
  transfer happens once per ``run()`` after the trace drains.

Because admission decisions depend only on host bookkeeping (slot
occupancy and per-slot step counters), the async loop schedules the exact
same (request, slot, step) trace as the synchronous engine — the sharded
engine is bitwise-identical to ``DiffusionServingEngine`` per policy,
which ``tests/test_sharded_serving.py`` asserts on an 8-virtual-device CPU
mesh (``make test-sharded``).

**Numerics self-check.**  SPMD partitioning is a compiler transform, and a
wrong partition is *silent* — during bring-up on this jax/XLA version the
CPU backend was caught both double-counting a matmul product (weight dims
sharded over ``data`` against batch-over-``data`` activations) and
NaN-ing the serve_step outright on any ``model > 1`` mesh, while every
``model == 1`` topology is bitwise-exact.  The engine therefore runs a
startup self-check whenever the model axis is wider than one device (or
``numerics_check=True``): two synthetic serve_steps on the mesh, compared
leaf-by-leaf against a single-device reference, raising ``RuntimeError``
on NaN or out-of-tolerance drift instead of serving garbage.  Real-TPU
validation of the tensor-parallel path is tracked in ROADMAP.md.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.runner import CachedDiT
from repro.distributed.sharding import (ShardingCtx, make_rules,
                                        param_shardings,
                                        serve_metrics_shardings,
                                        serve_plan_shardings,
                                        serve_snapshot_shardings,
                                        serve_state_shardings, spec_for,
                                        use_sharding)
from repro.serving.diffusion_engine import DiffusionServingEngine
from repro.serving.scheduler import DiffusionRequest, RequestQueue


def make_serving_mesh(data: Optional[int] = None, model: int = 1) -> Mesh:
    """A ``(data, model)`` serving mesh over the available devices.
    ``data`` defaults to ``device_count // model``."""
    n = jax.device_count()
    if data is None:
        data = max(1, n // model)
    if data * model > n:
        raise ValueError(f"mesh ({data}, {model}) needs {data * model} "
                        f"devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"))


class ShardedDiffusionEngine(DiffusionServingEngine):
    """``DiffusionServingEngine`` on a ``(data, model)`` mesh with an async
    host-admission dispatch loop.  Host orchestration (slots, queue,
    lockstep baseline, stats conventions) is inherited unchanged — the
    subsystem replaces the device runtime underneath it."""

    def __init__(self, runner: CachedDiT, params, *, max_slots: int,
                 mesh: Optional[Mesh] = None, num_steps: int = 50,
                 guidance_scale: float = 4.0, num_train_steps: int = 1000,
                 max_steps: Optional[int] = None,
                 async_admission: bool = True,
                 numerics_check: Optional[bool] = None,
                 cfg_rows: bool = True, collector=None, tracer=None,
                 enable_metrics: bool = True, audit_fraction: float = 0.0,
                 audit_seed: int = 0):
        self.mesh = mesh if mesh is not None else make_serving_mesh()
        self.rules = make_rules("serve")
        self._ctx = ShardingCtx(self.mesh, self.rules)
        self.async_admission = async_admission
        super().__init__(runner, params, max_slots=max_slots,
                         num_steps=num_steps, guidance_scale=guidance_scale,
                         num_train_steps=num_train_steps,
                         max_steps=max_steps, cfg_rows=cfg_rows,
                         collector=collector, tracer=tracer,
                         enable_metrics=enable_metrics,
                         audit_fraction=audit_fraction,
                         audit_seed=audit_seed)
        # default: self-check exactly the regime where the partitioner has
        # been caught miscompiling (a model axis wider than one device);
        # model==1 topologies are covered bitwise by the parity tests
        if numerics_check is None:
            numerics_check = self.topology()["model"] > 1
        if numerics_check:
            self._verify_step_numerics()

    # -- placement + compilation ----------------------------------------

    def _place_and_compile(self) -> None:
        mesh, rules, ctx = self.mesh, self.rules, self._ctx
        rep = NamedSharding(mesh, P())
        # pre-placement params, kept for the numerics self-check's
        # single-device reference engine (a reference, not a copy)
        self._unplaced_params = self.params

        # shardings: weights via the model's ParamDef tree, state via the
        # kind="serve" cache-state tables, latents + sampling-plan tables
        # slot-major over `data`
        self._params_sh = param_shardings(self.runner.model.param_defs(),
                                          ctx)
        # the state walker is policy-agnostic: it derives slot axes from
        # leaf ranks/extents (batch = this engine's state rows, CFG pairs
        # included), never from state keys
        self._state_sh = serve_state_shardings(
            self.state, ctx, batch=self.rows_per_slot * self.S,
            layers=self.runner.L)
        self._plan_sh = serve_plan_shardings(self.plan, ctx)
        self._slot_acc_sh = {
            k: NamedSharding(mesh, spec_for((self.S,), ("slot",), ctx))
            for k in self.slot_acc}
        x_spec = spec_for(self.x.shape, ("slot", None, None, None), ctx)
        self._x_sh = NamedSharding(mesh, x_spec)
        # one slot's row = the x spec minus the slot axis: admission noise
        # lands with this spec so the staged write matches the resident
        # layout (no resharding inside the admission program)
        self._slot_row_sh = NamedSharding(mesh, P(*x_spec[1:]))
        # one slot's plan row likewise: the ts-table spec minus the slot
        # axis — admission plan rows land through the same per-slot
        # device_put mechanism as the noise
        self._plan_row_sh = NamedSharding(
            mesh, P(*self._plan_sh["ts"].spec[1:]))
        self._acc_sh = {k: rep for k in self.acc}
        # metrics plane: per-slot leaves ride the slot shard, counters and
        # histogram bins replicate (serve_metrics_shardings documents why
        # this is a dedicated walker, not the state walker)
        self._metrics_sh = serve_metrics_shardings(self.metrics, ctx)

        self.params = jax.device_put(self.params, self._params_sh)
        self.state = jax.device_put(self.state, self._state_sh)
        self.plan = jax.device_put(self.plan, self._plan_sh)
        self.x = jax.device_put(self.x, self._x_sh)
        self.acc = jax.device_put(self.acc, self._acc_sh)
        self.slot_acc = jax.device_put(self.slot_acc, self._slot_acc_sh)
        self.metrics = jax.device_put(self.metrics, self._metrics_sh)
        # schedule constants ride along replicated so the jitted programs
        # never see mixed device commitments
        self.sched = jax.device_put(self.sched, rep)

        # trace under the serve sharding ctx so `constrain` calls in the
        # model blocks and the fastcache scan carry bind to this mesh
        def step_fn(params, state, x, plan, step_idx, labels, active, acc,
                    slot_acc, metrics, audit_flag):
            with use_sharding(mesh, rules):
                return self._serve_step_impl(params, state, x, plan,
                                             step_idx, labels, active, acc,
                                             slot_acc, metrics, audit_flag)

        def reset_fn(state, rows):
            with use_sharding(mesh, rules):
                return self.runner.reset_slot(state, rows)

        def admit_fn(state, x, plan, slot_acc, rows, slot, noise, ts_row,
                     ts_prev_row, guid):
            with use_sharding(mesh, rules):
                return self._admit_impl(state, x, plan, slot_acc, rows,
                                        slot, noise, ts_row, ts_prev_row,
                                        guid)

        self._step = jax.jit(
            step_fn,
            in_shardings=(self._params_sh, self._state_sh, self._x_sh,
                          self._plan_sh, rep, rep, rep, self._acc_sh,
                          self._slot_acc_sh, self._metrics_sh, rep),
            out_shardings=(self._x_sh, self._state_sh, self._acc_sh,
                           self._slot_acc_sh, self._metrics_sh),
            donate_argnums=(1, 2, 7, 8, 9))
        self._reset = jax.jit(
            reset_fn, in_shardings=(self._state_sh, rep),
            out_shardings=self._state_sh, donate_argnums=(0,))
        self._admit = jax.jit(
            admit_fn,
            in_shardings=(self._state_sh, self._x_sh, self._plan_sh,
                          self._slot_acc_sh, rep, rep, self._slot_row_sh,
                          self._plan_row_sh, self._plan_row_sh, rep),
            out_shardings=(self._state_sh, self._x_sh, self._plan_sh,
                           self._slot_acc_sh),
            donate_argnums=(0, 1, 2, 3))

        # preemption pair (serving/slo/): snapshots come out fully
        # REPLICATED (serve_snapshot_shardings — a snapshot must be
        # restorable into any slot, and under a data-sharded slot batch
        # different slots live on different mesh positions; replicating
        # the single-slot-sized checkpoint makes _restore a plain scatter
        # for every target slot).  The layout is derived structurally via
        # eval_shape so any policy's state snapshot places without edits.
        def snapshot_fn(state, x, plan, slot_acc, rows, slot):
            with use_sharding(mesh, rules):
                return self._snapshot_impl(state, x, plan, slot_acc, rows,
                                           slot)

        def restore_fn(state, x, plan, slot_acc, snap, rows, slot):
            with use_sharding(mesh, rules):
                return self._restore_impl(state, x, plan, slot_acc, snap,
                                          rows, slot)

        snap_struct = jax.eval_shape(
            self._snapshot_impl, self.state, self.x, self.plan,
            self.slot_acc, jnp.zeros((self.rows_per_slot,), jnp.int32),
            jnp.zeros((), jnp.int32))
        self._snap_sh = serve_snapshot_shardings(snap_struct, ctx)
        self._snapshot = jax.jit(
            snapshot_fn,
            in_shardings=(self._state_sh, self._x_sh, self._plan_sh,
                          self._slot_acc_sh, rep, rep),
            out_shardings=self._snap_sh)
        self._restore = jax.jit(
            restore_fn,
            in_shardings=(self._state_sh, self._x_sh, self._plan_sh,
                          self._slot_acc_sh, self._snap_sh, rep, rep),
            out_shardings=(self._state_sh, self._x_sh, self._plan_sh,
                           self._slot_acc_sh),
            donate_argnums=(0, 1, 2, 3))

    # -- async admission / harvest --------------------------------------

    def _staged_noise(self, req: DiffusionRequest) -> jax.Array:
        # per-slot device_put with the slot's shard spec: the transfer is
        # staged while the current step is in flight, and the admission
        # program consumes it without resharding
        return jax.device_put(self.request_noise(req), self._slot_row_sh)

    def _staged_plan(self, ts_row, ts_prev_row):
        # plan rows land through the same per-slot device_put mechanism as
        # the admission noise: staged with one slot's table-row spec while
        # the in-flight step runs, consumed by _admit without resharding
        return (jax.device_put(jnp.asarray(ts_row), self._plan_row_sh),
                jax.device_put(jnp.asarray(ts_prev_row), self._plan_row_sh))

    def _harvest(self, done_slots: List[int]) -> None:
        if not self.async_admission:
            return super()._harvest(done_slots)
        # deferred: enqueue device-side row copies (the donated next step
        # cannot clobber them — the runtime orders the copy before reuse)
        # and materialize once after the trace drains
        for s in done_slots:
            self.slots[s].latents = self.x[s]
            self.slots[s].cache = {k: v[s]
                                   for k, v in self.slot_acc.items()}

    def finalize_requests(self, finished: List[DiffusionRequest]) -> None:
        # the drive loop's single sync point (run end — both engine.run
        # and the SLO control plane's loops call it): fetch all deferred
        # latents and request-scoped cache counters
        if not self.async_admission:
            return
        for r in finished:
            if isinstance(r.latents, jax.Array):
                r.latents = np.asarray(r.latents).copy()
            if r.cache is not None:
                r.cache = {k: float(np.asarray(v))
                           for k, v in r.cache.items()}

    # -- numerics self-check --------------------------------------------

    def _verify_step_numerics(self, *, rtol: float = 1e-2,
                              atol: float = 1e-2) -> None:
        """Run two synthetic serve_steps through the compiled SPMD program
        and compare every output leaf against a single-device reference
        engine.  A silently mis-partitioned program (double-counted
        reductions, NaNs — both observed on model>1 CPU meshes during
        bring-up) fails loudly here instead of corrupting served requests.
        Tolerances allow legitimate reduction-order drift from tensor
        parallelism; int/bool leaves must match exactly."""
        ref_eng = DiffusionServingEngine(
            self.runner, self._unplaced_params, max_slots=self.S,
            num_steps=self.num_steps, guidance_scale=self.guidance_scale,
            num_train_steps=self.num_train_steps, max_steps=self.max_steps,
            cfg_rows=self.cfg_rows, enable_metrics=bool(self.metrics),
            audit_fraction=self.audit_fraction, audit_seed=self.audit_seed)
        # with the audit plane on, force the flag True so the self-check
        # also exercises the shadow-forward branch under SPMD partitioning
        aflag = jnp.asarray(self._audit_on)
        eff = self.rows_per_slot * self.S    # state rows (CFG pairs or not)
        x0 = jax.random.normal(jax.random.PRNGKey(0), self.x.shape,
                               jnp.float32)
        labels = jnp.zeros((self.S,), jnp.int32)
        active = jnp.ones((self.S,), bool)
        ref = (ref_eng.params, self.runner.init_state(eff), x0)
        got = (self.params,
               jax.device_put(self.runner.init_state(eff), self._state_sh),
               jax.device_put(x0, self._x_sh))
        ref_acc, ref_sacc = self._zero_acc(), ref_eng._zero_slot_acc()
        got_acc = jax.device_put(self._zero_acc(), self._acc_sh)
        got_sacc = jax.device_put(self._zero_slot_acc(), self._slot_acc_sh)
        ref_m = ref_eng.metrics
        got_m = jax.device_put(
            jax.tree.map(jnp.zeros_like, self.metrics), self._metrics_sh)
        flat = getattr(jax.tree, "flatten_with_path", None) \
            or jax.tree_util.tree_flatten_with_path
        for step in range(2):
            idx = jnp.full((self.S,), step, jnp.int32)
            rx, rs, ref_acc, ref_sacc, ref_m = ref_eng._step(
                ref[0], ref[1], ref[2], ref_eng.plan, idx, labels, active,
                ref_acc, ref_sacc, ref_m, aflag)
            gx, gs, got_acc, got_sacc, got_m = self._step(
                got[0], got[1], got[2], self.plan, idx, labels, active,
                got_acc, got_sacc, got_m, aflag)
            ref, got = (ref_eng.params, rs, rx), (self.params, gs, gx)
            for (path, a), b in zip(
                    flat((rx, rs, ref_acc, ref_sacc, ref_m))[0],
                    jax.tree.leaves((gx, gs, got_acc, got_sacc, got_m))):
                name = jax.tree_util.keystr(path)
                a, b = np.asarray(a), np.asarray(b)
                if np.issubdtype(a.dtype, np.floating):
                    bad = (not np.isfinite(b).all()
                           or not np.allclose(a, b, rtol=rtol, atol=atol))
                    diff = np.abs(a - b)
                    maxdiff = (float(np.nanmax(diff))
                               if np.isfinite(diff).any() else float("nan"))
                    detail = (f"max|diff|={maxdiff:.3e}"
                              f" nan={bool(np.isnan(b).any())}")
                else:
                    bad = not np.array_equal(a, b)
                    detail = "integer/bool mismatch"
                if bad:
                    topo = self.topology()
                    raise RuntimeError(
                        f"ShardedDiffusionEngine numerics self-check "
                        f"failed on mesh (data={topo['data']}, "
                        f"model={topo['model']}) at step {step}, leaf "
                        f"{name}: {detail}.  The SPMD partitioner "
                        f"miscompiled the serve_step on this backend "
                        f"(known for model>1 on this jax/XLA CPU "
                        f"version — see ROADMAP.md).  Use a model=1 "
                        f"topology here, or pass numerics_check=False "
                        f"to override.")

    # -- reporting ------------------------------------------------------

    def topology(self) -> Dict[str, int]:
        shape = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        return {"data": shape.get("data", 1), "model": shape.get("model", 1),
                "devices": int(self.mesh.devices.size)}
