"""Request scheduling for the diffusion serving engine: a FIFO admission
queue gated on arrival time, plus Poisson arrival-trace generation for
benchmarks.

Time is measured in *engine steps* (one ``serve_step`` = one clock tick):
arrival traces, admission decisions and request latencies all live on that
discrete clock, which makes lockstep-vs-continuous comparisons exact and
hardware-independent (wall-clock throughput is reported separately by the
benchmark from the measured per-step time).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass(eq=False)
class DiffusionRequest:
    """One image-generation request.  ``seed`` determines the initial noise
    (so an engine run can be replayed solo for parity checks); ``label`` is
    the class condition."""
    rid: int
    label: int
    seed: int = 0
    arrival_step: int = 0
    # filled by the engine
    latents: Optional[np.ndarray] = None
    admit_step: int = -1
    finish_step: int = -1
    done: bool = False

    @property
    def latency_steps(self) -> int:
        """Queueing + service latency on the engine-step clock."""
        return (self.finish_step - self.arrival_step
                if self.finish_step >= 0 else -1)


class RequestQueue:
    """FIFO queue gated on arrival time: ``pop_arrived(now)`` hands out the
    oldest request whose arrival_step has passed, preserving submission
    order (no request overtakes an earlier arrival)."""

    def __init__(self, requests: Optional[List[DiffusionRequest]] = None):
        self._q: List[DiffusionRequest] = sorted(
            requests or [], key=lambda r: (r.arrival_step, r.rid))

    def push(self, req: DiffusionRequest) -> None:
        self._q.append(req)
        self._q.sort(key=lambda r: (r.arrival_step, r.rid))

    def peek_arrived(self, now: int) -> Optional[DiffusionRequest]:
        if self._q and self._q[0].arrival_step <= now:
            return self._q[0]
        return None

    def pop_arrived(self, now: int) -> Optional[DiffusionRequest]:
        if self._q and self._q[0].arrival_step <= now:
            return self._q.pop(0)
        return None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


def poisson_trace(num_requests: int, rate: float, *,
                  seed: Optional[int] = None, key=None,
                  num_classes: int = 10) -> List[DiffusionRequest]:
    """Poisson arrival process: exponential inter-arrival times with mean
    ``1 / rate`` (requests per engine step), floored onto the step clock.

    Exactly one of ``seed`` (an int) or ``key`` (a ``jax.random`` PRNG key)
    is required — there is deliberately no default, so every call site pins
    its trace explicitly and benchmark runs replay the identical request
    stream across topologies (single-device vs sharded sweeps).  Labels and
    per-request noise seeds are drawn deterministically from it."""
    if (seed is None) == (key is None):
        raise TypeError(
            "poisson_trace: pass exactly one of seed= (int) or key= "
            "(jax.random PRNG key)")
    if key is not None:
        import jax
        seed = int(jax.random.randint(key, (), 0,
                                      np.iinfo(np.int32).max))
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=1.0 / max(rate, 1e-9), size=num_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    return [DiffusionRequest(rid=i,
                             label=int(rng.integers(0, num_classes)),
                             seed=int(1000 + i),
                             arrival_step=int(arrivals[i]))
            for i in range(num_requests)]
