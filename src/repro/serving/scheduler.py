"""Request scheduling for the diffusion serving engine: per-request
``SamplingPlan``s (heterogeneous DDIM step counts + guidance scales), an
arrival-gated queue with pluggable scheduling policies (FIFO,
shortest-job-first, and earliest-deadline-first under strict priority
classes), plus Poisson arrival-trace generation — optionally
rate-modulated (bursty/diurnal) with priority and deadline mixes — for
benchmarks and the SLO control plane (``serving/slo/``).

Time is measured in *engine steps* (one ``serve_step`` = one clock tick):
arrival traces, admission decisions and request latencies all live on that
discrete clock, which makes lockstep-vs-continuous comparisons exact and
hardware-independent (wall-clock throughput is reported separately by the
benchmark from the measured per-step time).

A ``SamplingPlan`` is the request's *denoising schedule*: its DDIM step
budget and guidance scale, from which the per-slot ``(t, t_prev)`` timestep
rows of the engine's ``(S, max_steps)`` plan tables are derived.  Plans are
per-request state, not engine config — one engine batch mixes 20-step and
50-step jobs at different guidance scales, and each finished request still
replays bitwise against a solo ``sample()`` run under its own plan.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

SCHED_POLICIES = ("fifo", "sjf", "edf")


@dataclasses.dataclass(frozen=True)
class SamplingPlan:
    """One request's denoising schedule: DDIM step budget + CFG guidance.

    ``rows(max_steps, num_train_steps)`` derives the padded per-slot
    timestep rows the serving engines keep device-resident: entry ``i`` of
    the ``ts`` row is exactly the ``t`` that ``diffusion.sampler.sample()``
    uses on its ``i``-th step under the same ``num_steps`` (and ``ts_prev``
    likewise, ``-1`` marking the final x0-prediction step), so engine
    requests stay bitwise-replayable solo."""
    num_steps: int
    guidance_scale: float = 4.0

    def __post_init__(self):
        if self.num_steps < 1:
            raise ValueError(f"SamplingPlan needs num_steps >= 1, got "
                             f"{self.num_steps}")

    def rows(self, max_steps: int,
             num_train_steps: int = 1000) -> Tuple[np.ndarray, np.ndarray]:
        """Padded ``(ts, ts_prev)`` rows, each ``(max_steps,)`` int32.
        Positions past ``num_steps`` are padding (``t=0, t_prev=-1``) that
        an active slot never reads — the engine clips its step index to the
        slot's budget.

        When ``num_steps`` divides ``num_train_steps`` the last executed
        step has ``t_prev = -1`` (the x0-prediction step).  For
        non-divisor budgets ``ddim_timesteps`` yields more than
        ``num_steps`` entries and — exactly like ``sample()``'s
        ``range(num_steps)`` loop, which this row layout must replay
        bitwise — the final entries past the budget are truncated, so the
        last executed step ends at a small positive timestep instead of an
        explicit x0 prediction."""
        if self.num_steps > max_steps:
            raise ValueError(
                f"plan has num_steps={self.num_steps} > the engine's "
                f"max_steps={max_steps} table width")
        # same arithmetic as diffusion.schedule.ddim_timesteps (numpy here
        # so queue/trace code never imports jax)
        stride = num_train_steps // self.num_steps
        ts_full = np.arange(num_train_steps - 1, -1, -stride, dtype=np.int32)
        prev_full = np.append(ts_full[1:], np.int32(-1))
        ts = np.zeros((max_steps,), np.int32)
        prev = np.full((max_steps,), -1, np.int32)
        ts[:self.num_steps] = ts_full[:self.num_steps]
        prev[:self.num_steps] = prev_full[:self.num_steps]
        return ts, prev


@dataclasses.dataclass(eq=False)
class DiffusionRequest:
    """One image-generation request.  ``seed`` determines the initial noise
    (so an engine run can be replayed solo for parity checks); ``label`` is
    the class condition.  ``num_steps``/``guidance_scale`` are the request's
    sampling plan — ``None`` means "use the engine's default", and the
    engine writes the resolved values back at admission so a finished
    request always records the exact plan it ran under."""
    rid: int
    label: int
    seed: int = 0
    arrival_step: int = 0
    # sampling plan (None = engine default, resolved at admission)
    num_steps: Optional[int] = None
    guidance_scale: Optional[float] = None
    # SLO metadata (serving/slo/): scheduling class (0 = highest priority;
    # the queue serves classes strictly in order) and an absolute deadline
    # on the engine-step clock (None = best-effort, never rejected by the
    # deadline admission test)
    priority: int = 0
    deadline_step: Optional[int] = None
    # filled by the engine
    latents: Optional[np.ndarray] = None
    cache: Optional[Dict] = None      # request-scoped cache counters
    admit_step: int = -1
    finish_step: int = -1
    done: bool = False
    # filled by the control plane: first-admission queue wait (engine
    # steps), why admission refused the request (None = admitted), how
    # often it was preempted, and — across a preempt/requeue cycle — the
    # denoising progress + device-side row snapshot the engine resumes
    # from (consumed at re-admission)
    queue_wait_steps: int = -1
    reject_reason: Optional[str] = None
    preemptions: int = 0
    steps_done: int = 0
    snapshot: Optional[Dict] = dataclasses.field(default=None, repr=False)

    @property
    def latency_steps(self) -> int:
        """Queueing + service latency on the engine-step clock."""
        return (self.finish_step - self.arrival_step
                if self.finish_step >= 0 else -1)


def _arrival_key(req: DiffusionRequest) -> Tuple[int, int]:
    return (req.arrival_step, req.rid)


class RequestQueue:
    """Arrival-gated admission queue with a pluggable scheduling policy.

    Requests become *eligible* once their ``arrival_step`` has passed; among
    eligible requests the policy picks the next one to hand out:

    - ``"fifo"`` (default): oldest ``(arrival_step, rid)`` first — no
      request overtakes an earlier arrival;
    - ``"sjf"``: shortest job first — smallest ``num_steps`` budget among
      the eligible requests (requests without an explicit plan sort as
      longest), ties broken deterministically by ``(arrival_step, rid)``;
    - ``"edf"``: earliest deadline first — smallest ``deadline_step``
      (best-effort requests without one sort last), ties broken by
      ``(arrival_step, rid)``.

    Priority classes are strict and orthogonal to the policy: eligible
    requests are kept in one ready heap *per* ``req.priority``, and
    ``peek/pop_arrived`` always serve the lowest-numbered non-empty class
    — the policy only orders requests *within* a class.  Requests default
    to class 0, so single-class workloads behave exactly as before.

    Internally: not-yet-arrived requests live in a list kept sorted
    *descending* by ``(arrival_step, rid)`` (``push`` is a single
    ``bisect.insort``, and draining the next arrival is an O(1) pop from
    the tail — no full re-sort per insert); arrived requests move to a
    policy-keyed ready heap, so ``pop_arrived`` is O(1) for the common
    already-drained FIFO case and O(log n) otherwise."""

    def __init__(self, requests: Optional[List[DiffusionRequest]] = None,
                 *, policy: str = "fifo"):
        if policy not in SCHED_POLICIES:
            raise ValueError(f"unknown scheduling policy {policy!r}; "
                             f"expected one of {SCHED_POLICIES}")
        self.policy = policy
        self._pending: List[DiffusionRequest] = sorted(
            requests or [], key=_arrival_key, reverse=True)
        # heap entries are (key..., seq, req): the monotonic seq breaks any
        # residual tie (e.g. a retry sharing its original's (arrival, rid))
        # before comparison ever reaches the non-orderable request object
        self._ready: Dict[int, List[Tuple]] = {}
        self._seq = 0

    def _ready_key(self, req: DiffusionRequest) -> Tuple:
        if self.policy == "sjf":
            steps = (req.num_steps if req.num_steps is not None
                     else float("inf"))
            return (steps, req.arrival_step, req.rid)
        if self.policy == "edf":
            deadline = (req.deadline_step if req.deadline_step is not None
                        else float("inf"))
            return (deadline, req.arrival_step, req.rid)
        return (req.arrival_step, req.rid)

    def push(self, req: DiffusionRequest) -> None:
        # descending order = ascending order of the negated key
        bisect.insort(self._pending, req,
                      key=lambda r: (-r.arrival_step, -r.rid))

    def _drain(self, now: int) -> None:
        while self._pending and self._pending[-1].arrival_step <= now:
            req = self._pending.pop()
            heapq.heappush(self._ready.setdefault(req.priority, []),
                           self._ready_key(req) + (self._seq, req))
            self._seq += 1

    def _first_class(self) -> Optional[int]:
        ready = [c for c, heap in self._ready.items() if heap]
        return min(ready) if ready else None

    def peek_arrived(self, now: int) -> Optional[DiffusionRequest]:
        self._drain(now)
        cls = self._first_class()
        return self._ready[cls][0][-1] if cls is not None else None

    def pop_arrived(self, now: int) -> Optional[DiffusionRequest]:
        self._drain(now)
        cls = self._first_class()
        return (heapq.heappop(self._ready[cls])[-1]
                if cls is not None else None)

    def ready_depth(self, now: int) -> int:
        """How many eligible requests are waiting right now — the queue
        pressure signal the degradation controller watches."""
        self._drain(now)
        return sum(len(heap) for heap in self._ready.values())

    def depth_by_class(self, now: int) -> Dict[int, int]:
        """Eligible-request count per priority class (non-empty classes
        only), for the per-class queue-depth gauges."""
        self._drain(now)
        return {cls: len(heap)
                for cls, heap in sorted(self._ready.items()) if heap}

    def __len__(self) -> int:
        return (len(self._pending)
                + sum(len(heap) for heap in self._ready.values()))

    def __bool__(self) -> bool:
        return bool(self._pending) or any(self._ready.values())


def _safe_percentile(values: np.ndarray, q: float,
                     default: float = -1.0) -> float:
    """``np.percentile`` that reports ``default`` on an empty array
    instead of raising — summaries over truncated traces (a run cut off
    by ``max_engine_steps``, a group whose every request was dropped
    unfinished) must degrade to a sentinel, not crash the report."""
    if values.size == 0:
        return default
    return float(np.percentile(values, q))


def summarize_by_steps(done: List[DiffusionRequest]) -> Dict[str, Dict]:
    """Group finished requests by their resolved step budget: request
    count and p50/p95 latency per budget, plus the cache ratio aggregated
    from the requests' request-scoped counters when every request in the
    group carries them (``req.cache``).  Shared by the serving launcher's
    summary and the heterogeneous-workload benchmark.

    Robust to truncated traces and admission rejections: unfinished
    requests (no ``finish_step``) and requests with an unresolved plan
    (``num_steps`` still ``None`` — e.g. rejected before admission ever
    resolved it) are excluded from the latency percentiles, and the cache
    aggregation reads counters tolerantly (``.get``) from the requests
    that carry them — a group holding never-admitted requests reports
    counts with ``-1.0`` percentiles rather than tripping
    ``np.percentile`` on an empty array or ``KeyError`` on an empty cache
    dict.  Rejected requests without a plan land in a ``"rejected"``
    group so the trace total is conserved."""
    out: Dict[str, Dict] = {}
    budgets = sorted({r.num_steps for r in done
                      if r.num_steps is not None})
    for n in budgets:
        grp = [r for r in done if r.num_steps == n]
        out[str(n)] = _summarize_group(grp)
    unplanned = [r for r in done if r.num_steps is None]
    if unplanned:
        out["rejected"] = _summarize_group(unplanned)
    return out


def _summarize_group(grp: List[DiffusionRequest]) -> Dict:
    """Count/latency/cache row for one request group (a step budget in
    ``summarize_by_steps``, a priority class in ``summarize_by_class``)."""
    lats = np.array([r.latency_steps for r in grp
                     if r.latency_steps >= 0], np.float64)
    row = {"requests": len(grp),
           "finished": int(lats.size),
           "latency_steps_p50": _safe_percentile(lats, 50),
           "latency_steps_p95": _safe_percentile(lats, 95)}
    rejected = sum(1 for r in grp if r.reject_reason is not None)
    if rejected:
        row["rejected"] = rejected
    cached = [r for r in grp if r.cache]
    if cached:
        skipped = sum(r.cache.get("blocks_skipped", 0.0) for r in cached)
        computed = sum(r.cache.get("blocks_computed", 0.0) for r in cached)
        tot = skipped + computed
        row["cache_ratio"] = skipped / tot if tot else 0.0
        row["steps_reused"] = sum(r.cache.get("steps_reused", 0.0)
                                  for r in cached)
    return row


def summarize_by_class(done: List[DiffusionRequest]) -> Dict[str, Dict]:
    """Group requests by priority class: the per-class SLO report the
    control plane and the overload benchmark read.  Beyond the shared
    count/latency/cache row this adds queue-wait percentiles, preemption
    totals, deadline hit/miss counts (among finished requests that carry
    a deadline) and a breakdown of admission-rejection reasons.  Tolerant
    of rejected (never-admitted) requests in every field."""
    out: Dict[str, Dict] = {}
    for cls in sorted({r.priority for r in done}):
        grp = [r for r in done if r.priority == cls]
        row = _summarize_group(grp)
        waits = np.array([r.queue_wait_steps for r in grp
                          if r.queue_wait_steps >= 0], np.float64)
        row["queue_wait_p50"] = _safe_percentile(waits, 50)
        row["queue_wait_p95"] = _safe_percentile(waits, 95)
        row["preemptions"] = int(sum(r.preemptions for r in grp))
        with_deadline = [r for r in grp
                         if r.deadline_step is not None
                         and r.finish_step >= 0]
        if with_deadline:
            met = sum(1 for r in with_deadline
                      if r.finish_step <= r.deadline_step)
            row["deadline_met"] = met
            row["deadline_missed"] = len(with_deadline) - met
        reasons: Dict[str, int] = {}
        for r in grp:
            if r.reject_reason is not None:
                reasons[r.reject_reason] = reasons.get(r.reject_reason,
                                                       0) + 1
        if reasons:
            row["reject_reasons"] = reasons
        out[str(cls)] = row
    return out


def piecewise_rate(segments: Sequence[Tuple[float, float]]
                   ) -> Callable[[float], float]:
    """``[(until_step, rate), ...] -> rate_fn`` for ``poisson_trace``:
    the arrival rate is ``rate`` while ``t < until_step`` of the first
    matching segment; past the last boundary the final segment's rate
    holds forever.  The standard way to write a bursty or diurnal trace —
    e.g. ``piecewise_rate([(20, 0.1), (60, 2.0), (1e9, 0.1)])`` is a
    burst between steps 20 and 60."""
    segs = sorted((float(until), float(r)) for until, r in segments)
    if not segs:
        raise ValueError("piecewise_rate: need at least one segment")

    def rate_fn(t: float) -> float:
        for until, r in segs:
            if t < until:
                return r
        return segs[-1][1]

    return rate_fn


def poisson_trace(num_requests: int, rate: float, *,
                  seed: Optional[int] = None, key=None,
                  num_classes: int,
                  steps_mix: Optional[Sequence[int]] = None,
                  guidance_mix: Optional[Sequence[float]] = None,
                  rate_fn: Optional[Callable[[float], float]] = None,
                  priority_mix: Optional[Sequence[int]] = None,
                  deadline_slack_mix: Optional[Sequence[int]] = None
                  ) -> List[DiffusionRequest]:
    """Poisson arrival process: exponential inter-arrival times with mean
    ``1 / rate`` (requests per engine step), floored onto the step clock.

    Exactly one of ``seed`` (an int) or ``key`` (a ``jax.random`` PRNG key)
    is required — there is deliberately no default, so every call site pins
    its trace explicitly and benchmark runs replay the identical request
    stream across topologies (single-device vs sharded sweeps).  Labels and
    per-request noise seeds are drawn deterministically from it.

    ``num_classes`` is required and must come from the model config at the
    call site (no hard-coded default — an out-of-range label would index
    past the class-embedding table).  ``steps_mix``/``guidance_mix`` make
    the trace heterogeneous: each request's plan is drawn uniformly from
    the mix (``None`` leaves the plan fields unset, i.e. engine defaults).

    ``rate_fn`` switches the process to a rate-modulated (inhomogeneous)
    Poisson stream — bursty or diurnal load: each inter-arrival gap is a
    unit exponential scaled by ``1 / rate_fn(t)`` at the current arrival
    time (``piecewise_rate`` builds the common step-function case), and
    the positional ``rate`` is ignored.  ``priority_mix`` draws each
    request's scheduling class uniformly from the mix;
    ``deadline_slack_mix`` draws a *relative* slack (engine steps) and
    stores the absolute ``deadline_step = arrival_step + slack``.

    Determinism is layered: for any fixed kwarg set the trace is a pure
    function of the seed, and the new knobs only consume random draws when
    passed — a legacy call (no ``rate_fn``/mixes) replays its historical
    stream bitwise."""
    if (seed is None) == (key is None):
        raise TypeError(
            "poisson_trace: pass exactly one of seed= (int) or key= "
            "(jax.random PRNG key)")
    if key is not None:
        import jax
        seed = int(jax.random.randint(key, (), 0,
                                      np.iinfo(np.int32).max))
    rng = np.random.default_rng(seed)
    if rate_fn is None:
        gaps = rng.exponential(scale=1.0 / max(rate, 1e-9),
                               size=num_requests)
        arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    else:
        # time-rescaled inhomogeneous process: unit-exponential gaps
        # stretched by the instantaneous rate at the running arrival time
        t = 0.0
        arrivals = np.empty((num_requests,), np.int64)
        for i in range(num_requests):
            t += rng.exponential() / max(float(rate_fn(t)), 1e-9)
            arrivals[i] = int(np.floor(t))
    out = []
    for i in range(num_requests):
        label = int(rng.integers(0, num_classes))
        num_steps = (int(rng.choice(np.asarray(steps_mix)))
                     if steps_mix else None)
        guidance = (float(rng.choice(np.asarray(guidance_mix)))
                    if guidance_mix else None)
        priority = (int(rng.choice(np.asarray(priority_mix)))
                    if priority_mix is not None else 0)
        deadline = None
        if deadline_slack_mix is not None:
            slack = int(rng.choice(np.asarray(deadline_slack_mix)))
            deadline = int(arrivals[i]) + slack
        out.append(DiffusionRequest(
            rid=i, label=label, seed=int(1000 + i),
            arrival_step=int(arrivals[i]), num_steps=num_steps,
            guidance_scale=guidance, priority=priority,
            deadline_step=deadline))
    return out
