"""``SLOScheduler``: the per-engine control-plane tick loop.

One ``tick()`` is: observe queue pressure (degradation controller +
per-class depth gauges) -> preempt for priority (a waiting
higher-priority request evicts the lowest-priority resident with the
most remaining work, via the engine's device-side snapshot/requeue) ->
deadline-aware admission (``AdmissionController``) -> one engine step
(timed, feeding the predictor's ``model_step_ms`` EMA).

Everything above the engine call is host bookkeeping; with an empty
queue a tick degenerates to exactly ``engine.step()``, which is why
steady state with the control plane enabled stays compile- and
transfer-free (pinned in ``tests/test_serving_invariants.py``).
"""
from __future__ import annotations

import time
from typing import List, Optional, Union

from repro.serving.scheduler import DiffusionRequest, RequestQueue
from repro.serving.slo.admission import AdmissionController
from repro.serving.slo.controller import DegradationController


class SLOScheduler:
    """Drive one engine (single-device or sharded) under the SLO control
    plane.  ``run()`` is the drop-in replacement for ``engine.run()``;
    ``tick()`` is the composable unit the ``ReplicaRouter`` drives."""

    def __init__(self, engine, *, sched_policy: str = "edf",
                 admission: Optional[AdmissionController] = None,
                 controller: Optional[DegradationController] = None,
                 preempt: bool = True, preempt_min_remaining: int = 2,
                 collector=None):
        self.engine = engine
        self.sched_policy = sched_policy
        self.collector = (collector if collector is not None
                          else engine.collector)
        self.admission = (admission if admission is not None
                          else AdmissionController(
                              engine, collector=self.collector))
        self.controller = controller
        self.preempt_enabled = preempt
        # never evict a resident about to finish: the snapshot/requeue
        # round trip would cost more slot-steps than it frees
        self.preempt_min_remaining = int(preempt_min_remaining)

    @property
    def rejected(self) -> List[DiffusionRequest]:
        return self.admission.rejected

    # -- preemption policy ----------------------------------------------

    def _maybe_preempt(self, queue: RequestQueue) -> None:
        """Evict a low-priority resident when a strictly-higher-priority
        request waits with no free slot.  Victim choice: numerically
        largest priority among residents below the head's class, most
        remaining work as tie-break (the cheapest progress to set aside).
        The victim requeues with its device-side snapshot and resumes
        bitwise later; resumed requests themselves never trigger another
        preemption (they wait for a natural free slot, so two requests
        can't ping-pong evicting each other)."""
        eng = self.engine
        if not self.preempt_enabled or eng.free_slots():
            return
        head = queue.peek_arrived(eng.clock)
        if head is None or head.snapshot is not None:
            return
        victims = []
        for s in range(eng.S):
            req = eng.slots[s]
            if req is None or req.priority <= head.priority:
                continue
            remaining = int(eng.slot_budget[s]) - int(eng.slot_step[s])
            if remaining < self.preempt_min_remaining:
                continue
            victims.append((req.priority, remaining, s))
        if not victims:
            return
        _, _, s = max(victims)
        queue.push(eng.preempt(s))

    # -- tick / run ------------------------------------------------------

    def tick(self, queue: RequestQueue) -> List[DiffusionRequest]:
        """One control-plane tick + one engine step.  Returns the
        requests that finished on this step."""
        eng = self.engine
        if self.controller is not None:
            self.controller.observe(queue.ready_depth(eng.clock))
        if self.collector is not None:
            for cls, depth in queue.depth_by_class(eng.clock).items():
                self.collector.set_gauge(f"queue_depth_class_{cls}",
                                         float(depth))
        self._maybe_preempt(queue)
        self.admission.admit_ready(queue, shed=self.controller)
        t0 = time.perf_counter()
        finished = eng.step()
        self.admission.predictor.observe_step_ms(
            (time.perf_counter() - t0) * 1e3)
        return finished

    def run(self, requests: Union[List[DiffusionRequest], RequestQueue],
            *, max_engine_steps: int = 100_000
            ) -> List[DiffusionRequest]:
        """Drive a whole trace under the control plane.  Returns finished
        requests; admission-rejected ones accumulate on ``.rejected``
        (never admitted, so they carry ``reject_reason`` but no latents).
        """
        eng = self.engine
        queue = (requests if isinstance(requests, RequestQueue)
                 else RequestQueue(list(requests),
                                   policy=self.sched_policy))
        finished: List[DiffusionRequest] = []
        window = (self.collector.window_steps
                  if self.collector is not None else None)
        while (queue or self.admission.pending_deferred
               or any(r is not None for r in eng.slots)):
            if eng.clock >= max_engine_steps:
                break
            finished.extend(self.tick(queue))
            if window and eng.clock % window == 0:
                eng.harvest_metrics()
        if self.collector is not None:
            eng.harvest_metrics()
        eng.finalize_requests(finished)
        return finished
