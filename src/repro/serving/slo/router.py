"""``ReplicaRouter``: one host driving N engine replicas.

Dispatch is join-shortest-queue on *outstanding work* (remaining steps of
every resident plus an estimate for the queued line — a better load
signal than request counts when plans are heterogeneous), with optional
priority-class affinity: a class pinned to a replica goes there unless
that replica is loaded beyond ``affinity_slack`` times the best choice —
soft affinity, so a hot replica sheds its pinned class before its latency
collapses.

Each replica is a full ``SLOScheduler`` (own queue, admission controller,
optional degradation controller), and the router drives them in lockstep
ticks — every engine's step clock advances together, so latencies across
replicas stay on one comparable clock.  Preempted requests requeue on
their OWN replica's queue (inside that replica's ``tick``), never across
replicas: a preemption snapshot is a pytree of device buffers placed for
its engine's mesh, and the router treats it as pinned there.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

from repro.serving.scheduler import DiffusionRequest, RequestQueue
from repro.serving.slo.plane import SLOScheduler


class ReplicaRouter:
    def __init__(self, schedulers: Sequence[SLOScheduler], *,
                 affinity: Optional[Dict[int, int]] = None,
                 affinity_slack: float = 2.0):
        if not schedulers:
            raise ValueError("ReplicaRouter needs >= 1 SLOScheduler")
        self.scheds = list(schedulers)
        for i, sched in enumerate(self.scheds):
            if not isinstance(sched, SLOScheduler):
                raise TypeError(f"replica {i}: expected an SLOScheduler, "
                                f"got {type(sched).__name__} — wrap the "
                                f"engine first")
        self.queues = [RequestQueue(policy=s.sched_policy)
                       for s in self.scheds]
        self.affinity = dict(affinity or {})
        for cls, idx in self.affinity.items():
            if not 0 <= idx < len(self.scheds):
                raise ValueError(f"affinity: class {cls} -> replica {idx} "
                                 f"out of range ({len(self.scheds)} "
                                 f"replicas)")
        if affinity_slack < 1.0:
            raise ValueError(f"affinity_slack must be >= 1.0, got "
                             f"{affinity_slack}")
        self.affinity_slack = float(affinity_slack)
        self.dispatched: Dict[int, int] = {}    # rid -> replica index

    # -- load signal + dispatch -----------------------------------------

    def load(self, i: int) -> int:
        """Outstanding work (engine steps) on replica ``i``: remaining
        steps of every resident plus the queued line estimated at each
        request's plan (engine default when unset)."""
        sched = self.scheds[i]
        eng = sched.engine
        inflight = sum(int(eng.slot_budget[s]) - int(eng.slot_step[s])
                       for s in range(eng.S) if eng.slots[s] is not None)
        queued = len(self.queues[i]) * eng.num_steps
        return inflight + queued

    def dispatch(self, req: DiffusionRequest) -> int:
        """Route one request: its class's affinity replica if that stays
        within ``affinity_slack`` of the least-loaded one, else
        join-shortest-queue (deterministic index tie-break)."""
        loads = [self.load(i) for i in range(len(self.scheds))]
        best = min(range(len(loads)), key=lambda i: (loads[i], i))
        pinned = self.affinity.get(req.priority)
        if pinned is not None:
            # +default_steps keeps the comparison meaningful at zero load
            budget = self.affinity_slack * (
                loads[best] + self.scheds[best].engine.num_steps)
            if loads[pinned] <= budget:
                best = pinned
        self.queues[best].push(req)
        self.dispatched[req.rid] = best
        return best

    # -- drive -----------------------------------------------------------

    @property
    def rejected(self) -> List[DiffusionRequest]:
        out: List[DiffusionRequest] = []
        for sched in self.scheds:
            out.extend(sched.rejected)
        return out

    def _busy(self) -> bool:
        if any(self.queues):
            return True
        for sched in self.scheds:
            if sched.admission.pending_deferred:
                return True
            if any(r is not None for r in sched.engine.slots):
                return True
        return False

    def run(self, requests: Union[List[DiffusionRequest], RequestQueue],
            *, max_engine_steps: int = 100_000
            ) -> List[DiffusionRequest]:
        """Drive a whole trace across the replica fleet.  Requests are
        dispatched when they arrive on the global clock (= every engine's
        step clock; the replicas tick in lockstep), then each replica runs
        its own control-plane tick.  Returns all finished requests,
        interleaved in completion order."""
        if isinstance(requests, RequestQueue):
            raise TypeError("ReplicaRouter.run takes the raw request list "
                            "— per-replica queues are router-owned (pass "
                            "the list; the router dispatches arrivals)")
        pending = sorted(requests,
                         key=lambda r: (r.arrival_step, r.rid),
                         reverse=True)
        finished: List[DiffusionRequest] = []
        clock = 0
        while pending or self._busy():
            if clock >= max_engine_steps:
                break
            while pending and pending[-1].arrival_step <= clock:
                self.dispatch(pending.pop())
            for sched, queue in zip(self.scheds, self.queues):
                finished.extend(sched.tick(queue))
            clock += 1
        for sched in self.scheds:
            if sched.collector is not None:
                sched.engine.harvest_metrics()
            sched.engine.finalize_requests(finished)
        return finished
