"""SLO-aware serving control plane, layered over the diffusion engines.

Host-side only — every decision here (admission, preemption, shedding,
routing) is made from host bookkeeping between engine steps, and the only
device work it triggers goes through the engines' existing jitted entry
points (``_admit``, ``_reset``) plus the preemption pair
(``_snapshot``/``_restore``).  Steady state with the plane enabled is
therefore exactly as compile- and transfer-free as without it, which
``tests/test_serving_invariants.py`` pins.

The pieces compose bottom-up:

- ``admission``: ``CompletionPredictor`` (finish-step prediction from the
  per-slot plan tables + a measured ``model_step_ms`` EMA) and
  ``AdmissionController`` (reject/defer requests whose predicted
  completion misses their deadline);
- ``controller``: ``ShedLevel`` ladders + ``DegradationController``
  (graceful degradation under sustained queue pressure: shrink step
  budgets per priority class; the chi^2 ``alpha`` knob on each level
  documents the cache-threshold half, applied per-engine at construction
  since gate thresholds are trace-time constants);
- ``plane``: ``SLOScheduler`` — the per-engine tick loop (observe
  pressure -> shed -> preempt for priority -> admit -> step);
- ``router``: ``ReplicaRouter`` — join-shortest-queue + class affinity
  across N engine instances.
"""
from repro.serving.slo.admission import (AdmissionController,  # noqa: F401
                                         CompletionPredictor,
                                         REASON_EXPIRED,
                                         REASON_UNATTAINABLE)
from repro.serving.slo.controller import (DEFAULT_SHED_LEVELS,  # noqa: F401
                                          DegradationController, ShedLevel)
from repro.serving.slo.plane import SLOScheduler  # noqa: F401
from repro.serving.slo.router import ReplicaRouter  # noqa: F401
