"""Deadline-aware admission control.

``CompletionPredictor`` turns the engine's host bookkeeping (per-slot step
counters and budgets — the host shadow of the device plan tables) into a
finish-step prediction: a min-heap of per-slot completion horizons,
greedily assigning work the way the engine's free-slot admission loop
does.  Predictions live on the engine-step clock; a measured
``model_step_ms`` EMA (fed by ``SLOScheduler`` from wall-clock step
timings) converts them to milliseconds for wall-clock SLO reporting.

``AdmissionController`` sits between the ``RequestQueue`` and
``add_request``: free slots are filled in queue order, and the waiting
line behind them is triaged — a request whose predicted completion
*behind the queued-ahead work* misses its ``deadline_step`` is refused
now (rejected, or deferred a few steps in the hope the queue drains)
instead of queueing fruitlessly.  A deadline that cannot be met even
starting NOW on an idle slot is rejected as ``"deadline_expired"``.
Best-effort requests (no deadline) are never refused.  Rejection is
recorded on the request (``reject_reason``) and in
``admission_rejections_total``, so a rejected request is a first-class
outcome the summaries account for, not a silently dropped one.
"""
from __future__ import annotations

import heapq
from typing import List, Optional, Sequence

from repro.obs import metrics as obs_metrics
from repro.serving.scheduler import DiffusionRequest, RequestQueue

REASON_UNATTAINABLE = "deadline_unattainable"
REASON_EXPIRED = "deadline_expired"


class CompletionPredictor:
    """Finish-step prediction from host slot bookkeeping.

    The prediction model matches the engine's actual scheduling: every
    busy slot frees after its remaining budget (``slot_budget -
    slot_step``), free slots are available now, and queued-ahead work is
    assigned greedily to the earliest-freeing slot — exactly what the
    admission loop will do.  Preempted requests predict with their
    *residual* steps (``num_steps - steps_done``), so a resumed request
    is cheaper to place than a fresh one of the same plan."""

    def __init__(self, engine, *, step_ms_alpha: float = 0.2):
        if not 0.0 < step_ms_alpha <= 1.0:
            raise ValueError(f"step_ms_alpha must be in (0, 1], got "
                             f"{step_ms_alpha}")
        self.engine = engine
        self.model_step_ms: Optional[float] = None
        self._alpha = step_ms_alpha

    def observe_step_ms(self, ms: float) -> None:
        """Fold one measured wall-clock engine-step time into the EMA."""
        if self.model_step_ms is None:
            self.model_step_ms = float(ms)
        else:
            self.model_step_ms += self._alpha * (float(ms)
                                                 - self.model_step_ms)

    def remaining_steps(self, req: DiffusionRequest) -> int:
        """Denoising steps the request still needs (plan resolved against
        the engine default; residual for preempted requests)."""
        n = (req.num_steps if req.num_steps is not None
             else self.engine.num_steps)
        return max(int(n) - int(req.steps_done), 0)

    def slot_horizons(self) -> List[int]:
        """Steps until each slot frees (0 for free slots)."""
        eng = self.engine
        return [0 if eng.slots[s] is None
                else max(int(eng.slot_budget[s]) - int(eng.slot_step[s]), 0)
                for s in range(eng.S)]

    def predict_finish_step(self, steps_needed: int,
                            queued_ahead: Sequence[int] = ()) -> int:
        """Absolute engine step at which a request needing
        ``steps_needed`` more steps would finish, admitted behind
        ``queued_ahead`` (step budgets that will grab slots first)."""
        horizons = self.slot_horizons()
        heapq.heapify(horizons)
        for ahead in queued_ahead:
            free_at = heapq.heappop(horizons)
            heapq.heappush(horizons, free_at + int(ahead))
        return self.engine.clock + horizons[0] + int(steps_needed)

    def predict_finish_ms(self, steps_needed: int,
                          queued_ahead: Sequence[int] = ()
                          ) -> Optional[float]:
        """Wall-clock view of ``predict_finish_step`` via the measured
        ``model_step_ms`` EMA (None until a step has been timed)."""
        if self.model_step_ms is None:
            return None
        steps = (self.predict_finish_step(steps_needed, queued_ahead)
                 - self.engine.clock)
        return steps * self.model_step_ms


class AdmissionController:
    """Deadline-aware admission: fill free slots in queue order, then
    triage the waiting line against the deadline predictor.

    ``on_miss="reject"`` refuses predicted misses immediately with
    ``reason="deadline_unattainable"``; ``on_miss="defer"`` parks the
    request for ``defer_steps`` engine steps (at most ``max_defers``
    times, in a controller-owned retry heap — the request's
    ``arrival_step``, and with it latency accounting, is never touched)
    before re-triaging.  Either way, a deadline unreachable even starting
    NOW on an idle slot is rejected as ``"deadline_expired"``.  Resumed
    (preempted) requests are re-admitted without a fresh deadline test:
    their slot investment is already sunk and their residual is by
    construction shorter than the original plan.

    ``lookahead`` bounds the triage scan per tick (default ``4 * slots``
    at construction): under a deep queue the head of the line is triaged
    every tick, the far tail only as it surfaces."""

    def __init__(self, engine, *, on_miss: str = "reject",
                 defer_steps: int = 4, max_defers: int = 8,
                 lookahead: Optional[int] = None, collector=None):
        if on_miss not in ("reject", "defer"):
            raise ValueError(f"on_miss must be 'reject' or 'defer', got "
                             f"{on_miss!r}")
        if defer_steps < 1:
            raise ValueError(f"defer_steps must be >= 1, got {defer_steps}")
        self.engine = engine
        self.on_miss = on_miss
        self.defer_steps = int(defer_steps)
        self.max_defers = int(max_defers)
        self.lookahead = (int(lookahead) if lookahead is not None
                          else 4 * engine.S)
        self.collector = collector
        self.predictor = CompletionPredictor(engine)
        self.rejected: List[DiffusionRequest] = []
        self._defers = {}
        self._deferred = []     # (retry_step, seq, req) heap
        self._defer_seq = 0

    @property
    def pending_deferred(self) -> int:
        """Requests parked in the defer heap (still owed a retry)."""
        return len(self._deferred)

    def _reject(self, req: DiffusionRequest, reason: str) -> None:
        req.reject_reason = reason
        self.rejected.append(req)
        if self.collector is not None:
            self.collector.inc(obs_metrics.REJECTIONS)

    def _defer(self, req: DiffusionRequest) -> None:
        self._defers[req.rid] = self._defers.get(req.rid, 0) + 1
        heapq.heappush(self._deferred,
                       (self.engine.clock + self.defer_steps,
                        self._defer_seq, req))
        self._defer_seq += 1

    def _requeue_deferred(self, queue: RequestQueue) -> None:
        while self._deferred and self._deferred[0][0] <= self.engine.clock:
            queue.push(heapq.heappop(self._deferred)[-1])

    def _miss(self, req: DiffusionRequest) -> None:
        """A predicted (not yet arithmetically certain) deadline miss:
        defer if the policy and budget allow, reject otherwise."""
        if (self.on_miss == "defer"
                and self._defers.get(req.rid, 0) < self.max_defers):
            self._defer(req)
        else:
            self._reject(req, REASON_UNATTAINABLE)

    def admit_ready(self, queue: RequestQueue, *, shed=None
                    ) -> List[DiffusionRequest]:
        """Fill free slots from the queue (priority classes first, then
        the queue's policy), then triage the waiting line.  ``shed`` is an
        optional ``DegradationController`` applied to fresh requests
        before their deadline test — a shrunk step budget can turn an
        unattainable deadline into an attainable one, which is the
        point."""
        eng = self.engine
        self._requeue_deferred(queue)
        admitted: List[DiffusionRequest] = []
        # phase 1: fill free slots
        while eng.free_slots():
            req = queue.peek_arrived(eng.clock)
            if req is None:
                break
            queue.pop_arrived(eng.clock)
            if req.snapshot is not None:
                eng.add_request(req)
                admitted.append(req)
                continue
            if shed is not None:
                shed.scale_request(req, default_steps=eng.num_steps)
            steps = self.predictor.remaining_steps(req)
            if (req.deadline_step is not None
                    and eng.clock + steps > req.deadline_step):
                self._reject(req, REASON_EXPIRED)
                continue
            eng.add_request(req)
            admitted.append(req)
        # phase 2: triage the line behind the (now full) slots — predict
        # each waiting request's completion behind the work queued ahead
        # of it and refuse the ones that already cannot make it
        kept: List[DiffusionRequest] = []
        ahead: List[int] = []
        scanned = 0
        while scanned < self.lookahead:
            req = queue.pop_arrived(eng.clock)
            if req is None:
                break
            scanned += 1
            steps = self.predictor.remaining_steps(req)
            if req.snapshot is not None or req.deadline_step is None:
                kept.append(req)
                ahead.append(steps)
                continue
            if shed is not None:
                shed.scale_request(req, default_steps=eng.num_steps)
                steps = self.predictor.remaining_steps(req)
            if eng.clock + steps > req.deadline_step:
                self._reject(req, REASON_EXPIRED)
                continue
            if self.predictor.predict_finish_step(steps,
                                                  ahead) > req.deadline_step:
                self._miss(req)
                continue
            kept.append(req)
            ahead.append(steps)
        for req in kept:
            queue.push(req)
        return admitted
