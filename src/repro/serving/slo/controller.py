"""Graceful degradation: shed-level ladders under queue pressure.

``DegradationController`` watches the ready-queue depth each control-plane
tick and walks a ladder of ``ShedLevel``s with watermark+patience
hysteresis: sustained depth at or above ``high_watermark`` escalates one
level, sustained depth at or below ``low_watermark`` de-escalates, and
anything in between resets both streaks — so a single bursty tick never
flips the level back and forth.

Each level carries three knobs:

- ``steps_scale`` — multiply admitted requests' step budgets (the live,
  zero-recompile knob: step budgets are per-slot *plan state*, so a
  shrunk budget is just a different plan row landed by the same
  ``_admit`` executable).  Applied per priority class: classes below
  ``min_priority`` are protected and keep their full budget.
- ``alpha`` — the chi^2 gate significance for the cache-skip threshold
  (``core/chi2.py``: SMALLER alpha -> higher threshold -> more skips ->
  larger bounded error).
- ``capacity_scale`` — multiply fastcache's STR motion capacity
  (``FastCacheConfig.motion_capacity``): a smaller motion stream routes
  more tokens through the learnable-linear static bypass every step —
  less MXU work per model step, more approximation error — which moves
  the cache ratio even at scales where the chi^2 stat sits far above any
  reachable threshold.

``alpha`` and ``capacity_scale`` are *trace-time constants* baked into
the jitted step (the motion capacity is a gather SHAPE), so those two are
applied per-engine at construction
(``FastCacheConfig(alpha=..., motion_capacity=...)``), not flipped live —
``benchmarks/serving_overload.py`` builds one engine per ladder rung and
the PR 8 audit plane measures the realized quality cost of each.

The controller is pure host bookkeeping; its only outputs are mutated
step budgets on not-yet-admitted requests and the ``shed_level`` /
``queue_depth_ready`` gauges.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.obs import metrics as obs_metrics
from repro.serving.scheduler import DiffusionRequest


@dataclasses.dataclass(frozen=True)
class ShedLevel:
    """One rung of a degradation ladder.  ``steps_scale`` shrinks admitted
    step budgets (1.0 = none); ``alpha`` is the chi^2 gate significance an
    engine serving this rung should be constructed with (None = policy
    default); ``capacity_scale`` shrinks fastcache's STR motion capacity
    at engine construction (1.0 = none); classes numbered below
    ``min_priority`` are protected from budget shedding."""
    name: str
    steps_scale: float = 1.0
    alpha: Optional[float] = None
    capacity_scale: float = 1.0
    min_priority: int = 1

    def __post_init__(self):
        if not 0.0 < self.steps_scale <= 1.0:
            raise ValueError(f"ShedLevel {self.name!r}: steps_scale must "
                             f"be in (0, 1], got {self.steps_scale}")
        if not 0.0 < self.capacity_scale <= 1.0:
            raise ValueError(f"ShedLevel {self.name!r}: capacity_scale "
                             f"must be in (0, 1], got "
                             f"{self.capacity_scale}")


DEFAULT_SHED_LEVELS = (
    ShedLevel("nominal"),
    ShedLevel("shed-1", steps_scale=0.75),
    ShedLevel("shed-2", steps_scale=0.5),
)


class DegradationController:
    """Watermark+patience hysteresis over a ``ShedLevel`` ladder."""

    def __init__(self, levels: Sequence[ShedLevel] = DEFAULT_SHED_LEVELS,
                 *, high_watermark: int = 8, low_watermark: int = 2,
                 patience: int = 4, min_steps: int = 2,
                 start_level: int = 0, collector=None):
        levels = tuple(levels)
        if not levels:
            raise ValueError("DegradationController needs >= 1 ShedLevel")
        if low_watermark >= high_watermark:
            raise ValueError(
                f"low_watermark ({low_watermark}) must be < high_watermark "
                f"({high_watermark}) or the hysteresis band is empty")
        if not 0 <= start_level < len(levels):
            raise ValueError(f"start_level {start_level} out of range for "
                             f"{len(levels)} levels")
        self.levels = levels
        self.level_idx = start_level
        self.high_watermark = int(high_watermark)
        self.low_watermark = int(low_watermark)
        self.patience = int(patience)
        self.min_steps = int(min_steps)
        self.collector = collector
        self._hi_streak = 0
        self._lo_streak = 0

    @property
    def level(self) -> ShedLevel:
        return self.levels[self.level_idx]

    def observe(self, depth: int) -> ShedLevel:
        """Fold one tick's ready-queue depth into the hysteresis state and
        return the (possibly changed) active level."""
        if depth >= self.high_watermark:
            self._hi_streak += 1
            self._lo_streak = 0
        elif depth <= self.low_watermark:
            self._lo_streak += 1
            self._hi_streak = 0
        else:
            self._hi_streak = 0
            self._lo_streak = 0
        if (self._hi_streak >= self.patience
                and self.level_idx < len(self.levels) - 1):
            self.level_idx += 1
            self._hi_streak = 0
        elif self._lo_streak >= self.patience and self.level_idx > 0:
            self.level_idx -= 1
            self._lo_streak = 0
        if self.collector is not None:
            self.collector.observe(obs_metrics.QUEUE_DEPTH, depth)
            self.collector.set_gauge("shed_level", float(self.level_idx))
        return self.level

    def scale_request(self, req: DiffusionRequest, *,
                      default_steps: int) -> None:
        """Apply the active level's budget shedding to a not-yet-admitted
        request (in place, so the engine resolves and records the shed
        plan).  Protected classes and resumed requests are left alone —
        the caller gates on ``req.snapshot`` for the latter."""
        lvl = self.level
        if req.priority < lvl.min_priority or lvl.steps_scale >= 1.0:
            return
        base = (req.num_steps if req.num_steps is not None
                else default_steps)
        req.num_steps = max(self.min_steps,
                            int(round(base * lvl.steps_scale)))
