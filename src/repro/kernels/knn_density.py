"""Local-window kNN token density Pallas kernel (Eq. 10; CTM stage 1).

Each grid step loads one window of w tokens into VMEM, forms the (w, w)
pairwise squared-distance matrix (one MXU (w,D)x(D,w) matmul + rank-1 terms),
then extracts the K smallest off-diagonal distances per row by K rounds of
masked-min (K <= 10, unrolled) — no sort, no gather.  rho_sp = exp(-mean_K).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32
INF = jnp.inf


def _kernel(h_ref, out_ref, *, k: int, w: int, d: int):
    h = h_ref[0].astype(F32)                               # (w, D)
    sq = jnp.sum(h * h, axis=1)
    dist = (sq[:, None] + sq[None, :]
            - 2.0 * jax.lax.dot_general(h, h, (((1,), (1,)), ((), ()))))
    dist = jnp.maximum(dist, 0.0)
    ii = jax.lax.broadcasted_iota(jnp.int32, (w, w), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (w, w), 1)
    dist = jnp.where(ii == jj, INF, dist)
    acc = jnp.zeros((w,), F32)
    for _ in range(k):                                     # unrolled K-min
        mn = jnp.min(dist, axis=1)                         # (w,)
        acc = acc + mn
        # mask exactly one argmin occurrence per row
        is_min = dist == mn[:, None]
        first = jnp.cumsum(is_min.astype(jnp.int32), axis=1) == 1
        dist = jnp.where(is_min & first, INF, dist)
    out_ref[0] = jnp.exp(-acc / (k * d))   # per-dim normalized (see ref.py)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def knn_density(h: jax.Array, *, k: int = 5,
                interpret: bool = True) -> jax.Array:
    """h: (n_windows, w, D) -> rho_sp (n_windows, w)."""
    nw, w, d = h.shape
    if not 1 <= k <= w - 1:
        # identical validation to kernels/ref.py and core/token_merge —
        # the static-k unroll below must never silently diverge from the
        # k the caller asked for (the pre-fix clamp did exactly that)
        raise ValueError(f"knn_density k={k} out of range for window "
                         f"w={w}; need 1 <= k <= w-1 = {w - 1}")
    return pl.pallas_call(
        functools.partial(_kernel, k=k, w=w, d=d),
        grid=(nw,),
        in_specs=[pl.BlockSpec((1, w, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nw, w), F32),
        interpret=interpret,
    )(h)
