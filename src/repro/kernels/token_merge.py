"""Fused token-merge Pallas kernels (CTM stages 2-3; Eqs. 12-13, Alg. 2).

``merge_assign`` fuses center selection -> nearest-center assignment ->
importance-weighted cluster means for one window per grid step, entirely in
VMEM: M unrolled masked-max rounds pick the top-M scored tokens as centers
(same first-occurrence tie-break as ``lax.top_k``), one (w, M) distance
matrix assigns every token to its nearest center (first-occurrence argmin),
and two MXU matmuls produce the merged (M, D) cluster means — no sort, no
gather, mirroring the masked-min idiom of ``knn_density.py``.

``unmerge_scatter`` restores the window: a one-hot (w, M) assignment matmul
replicates each cluster representative back to every member token (the
gather-as-matmul form the MXU wants; exact, since each row selects one
element).

Pure-jnp twins with the same names live in ``kernels/ref.py``; interpret-mode
parity is pinned by tests/test_kernels.py per the reprolint kernel-parity
rule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32
NEG_INF = -jnp.inf


def _merge_kernel(h_ref, s_ref, merged_ref, assign_ref, centers_ref, *,
                  m: int, w: int, d: int):
    h = h_ref[0].astype(F32)                               # (w, D)
    s = s_ref[0].astype(F32).reshape(1, w)                 # (1, w)

    # ---- top-M centers by score: M unrolled masked-max rounds with the
    # cumsum first-occurrence dedup (ties resolve to the lower index,
    # matching lax.top_k's stable ordering in ref.merge_assign)
    sc = s
    sel_rows = []
    for _ in range(m):
        mx = jnp.max(sc, axis=1, keepdims=True)            # (1, 1)
        is_max = sc == mx
        first = jnp.cumsum(is_max.astype(jnp.int32), axis=1) == 1
        sel = (is_max & first).astype(F32)                 # (1, w) one-hot
        sel_rows.append(sel)
        sc = jnp.where(sel > 0.0, NEG_INF, sc)
    sel_mat = jnp.concatenate(sel_rows, axis=0)            # (M, w)
    jj_mw = jax.lax.broadcasted_iota(jnp.int32, (m, w), 1)
    centers = jnp.sum(sel_mat * jj_mw.astype(F32), axis=1).astype(jnp.int32)
    centers_ref[0] = centers                               # (M,)

    # ---- nearest-center assignment: (w, M) squared distances, then a
    # first-occurrence argmin via masked one-hot (matches jnp.argmin)
    ch = jax.lax.dot_general(sel_mat, h, (((1,), (0,)), ((), ())),
                             preferred_element_type=F32)   # (M, D)
    hsq = jnp.sum(h * h, axis=1, keepdims=True)            # (w, 1)
    csq = jnp.sum(ch * ch, axis=1, keepdims=True)          # (M, 1)
    d2 = (hsq + csq.reshape(1, m)
          - 2.0 * jax.lax.dot_general(h, ch, (((1,), (1,)), ((), ())),
                                      preferred_element_type=F32))  # (w, M)
    mn = jnp.min(d2, axis=1, keepdims=True)
    is_min = d2 == mn
    firstm = jnp.cumsum(is_min.astype(jnp.int32), axis=1) == 1
    onehot = (is_min & firstm).astype(F32)                 # (w, M)
    jj_wm = jax.lax.broadcasted_iota(jnp.int32, (w, m), 1)
    assign_ref[0] = jnp.sum(onehot * jj_wm.astype(F32),
                            axis=1).astype(jnp.int32)      # (w,)

    # ---- importance-weighted cluster means (Eq. 13)
    wgt = onehot * s.reshape(w, 1)                         # (w, M)
    num = jax.lax.dot_general(wgt, h, (((0,), (0,)), ((), ())),
                              preferred_element_type=F32)  # (M, D)
    den = jnp.maximum(jnp.sum(wgt, axis=0), 1e-9)          # (M,)
    merged_ref[0] = (num / den[:, None]).astype(merged_ref.dtype)


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def merge_assign(h: jax.Array, s: jax.Array, *, m: int,
                 interpret: bool = True):
    """h: (W, w, D) windowed tokens, s: (W, w) per-window-normalized
    importance -> (merged (W, M, D), assign (W, w) int32, centers (W, M)
    int32) with M = ``m`` static centers per window."""
    nw, w, d = h.shape
    if not 1 <= m <= w:
        raise ValueError(f"merge_assign m={m} out of range for window "
                         f"w={w}; need 1 <= m <= w")
    return pl.pallas_call(
        functools.partial(_merge_kernel, m=m, w=w, d=d),
        grid=(nw,),
        in_specs=[pl.BlockSpec((1, w, d), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, w), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, m, d), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, w), lambda i: (i, 0)),
                   pl.BlockSpec((1, m), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nw, m, d), h.dtype),
                   jax.ShapeDtypeStruct((nw, w), jnp.int32),
                   jax.ShapeDtypeStruct((nw, m), jnp.int32)],
        interpret=interpret,
    )(h, s)


def _unmerge_kernel(merged_ref, assign_ref, out_ref, *, m: int, w: int,
                    d: int):
    mg = merged_ref[0].astype(F32)                         # (M, D)
    a = assign_ref[0].reshape(w, 1)                        # (w, 1)
    jj = jax.lax.broadcasted_iota(jnp.int32, (w, m), 1)
    onehot = (a == jj).astype(F32)                         # (w, M)
    out = jax.lax.dot_general(onehot, mg, (((1,), (0,)), ((), ())),
                              preferred_element_type=F32)  # (w, D)
    out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def unmerge_scatter(merged: jax.Array, assign: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """merged: (W, M, D) cluster means, assign: (W, w) int32 ->
    (W, w, D): every token takes its cluster representative."""
    nw, m, d = merged.shape
    w = assign.shape[1]
    return pl.pallas_call(
        functools.partial(_unmerge_kernel, m=m, w=w, d=d),
        grid=(nw,),
        in_specs=[pl.BlockSpec((1, m, d), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, w, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nw, w, d), merged.dtype),
        interpret=interpret,
    )(merged, assign)
