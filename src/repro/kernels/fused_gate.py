"""Fused per-sample cache-gate Pallas kernel.

One pass over a layer's motion-stream hiddens fuses the four stages of the
FastCache block decision (Eqs. 4-7 + Eq. 6/MB) *per batch sample*:

    saliency delta   diff_b = ||X_b - Xprev_b||_F^2
    chi^2 statistic  stat_b = diff_b / (sigma2_b * ND)
    gate             g_b    = (stat_b <= chi2_{ND,1-a}/ND) & eligible_b
    linear blend     out_b  = g_b ? gamma*(X_b W + c) + (1-gamma)*prev_out_b
                                  : X_b

The non-gated samples pass through unchanged and are overwritten by the real
transformer block outside the kernel; the gated samples never leave VMEM
between the reduction and the blend.

Grid: (B, 2, C/BC) — for each sample the phase axis makes two passes over the
token blocks: phase 0 accumulates the Frobenius reductions into the (1, 1)
scalar outputs (TPU grid execution is sequential, so revisited output blocks
stay resident in VMEM); phase 1 reads the finished statistic, decides the
gate, and writes the blended output tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(x_ref, xp_ref, po_ref, w_ref, b_ref, sig_ref, elig_ref,
            out_ref, gate_ref, diff_ref, prev_ref, *, nd: int,
            threshold: float, gamma: float, use_blend: bool):
    p = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((p == 0) & (j == 0))
    def _():
        diff_ref[...] = jnp.zeros_like(diff_ref)
        prev_ref[...] = jnp.zeros_like(prev_ref)

    @pl.when(p == 0)
    def _():
        x = x_ref[0].astype(F32)                       # (BC, D)
        xp = xp_ref[0].astype(F32)
        d = x - xp
        diff_ref[...] += jnp.sum(d * d)[None, None]
        prev_ref[...] += jnp.sum(xp * xp)[None, None]

    @pl.when(p == 1)
    def _():
        stat = diff_ref[0, 0] / (jnp.maximum(sig_ref[0, 0], 1e-30) * nd)
        g = (stat <= threshold) & (elig_ref[0, 0] > 0.0)

        @pl.when(j == 0)
        def _():
            gate_ref[...] = jnp.where(g, 1.0, 0.0)[None, None]

        # non-gated samples pass through and are overwritten by the real
        # block outside the kernel — skip their MXU work entirely
        @pl.when(g)
        def _():
            x = x_ref[0].astype(F32)
            approx = jnp.dot(x, w_ref[...].astype(F32),
                             preferred_element_type=F32) \
                + b_ref[...].astype(F32)
            if use_blend:
                approx = gamma * approx + (1.0 - gamma) * po_ref[0].astype(F32)
            out_ref[...] = approx[None]

        @pl.when(jnp.logical_not(g))
        def _():
            out_ref[...] = x_ref[0].astype(F32)[None]


@functools.partial(jax.jit, static_argnames=("threshold", "gamma",
                                             "use_blend", "bc", "interpret"))
def fused_gate(x: jax.Array, prev_in: jax.Array, prev_out: jax.Array,
               w: jax.Array, b: jax.Array, sigma2: jax.Array,
               eligible: jax.Array, *, threshold: float, gamma: float = 0.5,
               use_blend: bool = True, bc: int = 0, interpret: bool = True):
    """x, prev_in, prev_out: (B, C, D); w: (D, D); b: (D,);
    sigma2, eligible: (B,).  Returns (out (B,C,D) in x.dtype, gate (B,) bool,
    diff_sq (B,) f32, prev_sq (B,) f32)."""
    bsz, c, d = x.shape
    bc = min(bc or c, c)
    if c % bc:
        raise ValueError(f"motion length {c} not divisible by block {bc}")
    nd = c * d
    sig = sigma2.astype(F32).reshape(bsz, 1)
    elig = eligible.astype(F32).reshape(bsz, 1)
    grid = (bsz, 2, c // bc)
    out, gate, diff, prevsq = pl.pallas_call(
        functools.partial(_kernel, nd=nd, threshold=threshold, gamma=gamma,
                          use_blend=use_blend),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda i, p, j: (i, j, 0)),
            pl.BlockSpec((1, bc, d), lambda i, p, j: (i, j, 0)),
            pl.BlockSpec((1, bc, d), lambda i, p, j: (i, j, 0)),
            pl.BlockSpec((d, d), lambda i, p, j: (0, 0)),
            pl.BlockSpec((1, d), lambda i, p, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, p, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, p, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bc, d), lambda i, p, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, p, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, p, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, p, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, c, d), F32),
            jax.ShapeDtypeStruct((bsz, 1), F32),
            jax.ShapeDtypeStruct((bsz, 1), F32),
            jax.ShapeDtypeStruct((bsz, 1), F32),
        ],
        interpret=interpret,
    )(x, prev_in, prev_out, w, b.reshape(1, d), sig, elig)
    return (out.astype(x.dtype), gate[:, 0] > 0.0, diff[:, 0], prevsq[:, 0])
