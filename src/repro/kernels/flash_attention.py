"""Flash attention (online softmax) Pallas kernel: causal, sliding-window,
GQA.  TPU tiling: grid (B, H, Sq/BQ, Skv/BK) with the KV axis minor; the
(BQ, dh) f32 accumulator plus (BQ, 1) running max / denominator live in VMEM
scratch across KV steps.  Causal block-skipping uses @pl.when — fully-masked
KV blocks issue no MXU work on TPU (this is the kernel that removes the
masked-FLOP waste of the XLA fallback path, see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, bq: int, bk: int, nk: int,
            q_offset: int, scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq + q_offset
    k_start = kj * bk

    # live unless the whole KV block is masked out
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + bq - 1
    if window > 0:
        live &= k_start + bk - 1 > q_start - window

    @pl.when(live)
    def _():
        q = q_ref[0, 0].astype(F32)                    # (BQ, dh)
        k = k_ref[0, 0].astype(F32)                    # (BK, dh)
        v = v_ref[0, 0].astype(F32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                            # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, bq: int = 128,
                    bk: int = 128, interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, dh); k, v: (B, KVH, Skv, dh). GQA via head grouping;
    query positions are aligned to the END of the KV sequence."""
    b, h, sq, dh = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    bq = min(bq, sq)
    bk = min(bk, skv)
    if sq % bq or skv % bk:
        raise ValueError(f"(Sq={sq}, Skv={skv}) not divisible by ({bq},{bk})")
    nk = skv // bk
    grid = (b, h, sq // bq, nk)
    kernel = functools.partial(
        _kernel, causal=causal, window=window, bq=bq, bk=bk, nk=nk,
        q_offset=skv - sq, scale=dh ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, qi, kj: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, qi, kj, g=g: (b_, h_ // g, kj, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, qi, kj, g=g: (b_, h_ // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b_, h_, qi, kj: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), F32),
            pltpu.VMEM((bq, 1), F32),
            pltpu.VMEM((bq, dh), F32),
        ],
        interpret=interpret,
    )(q, k, v)
