"""Pallas TPU kernels for FastCache hot spots.

<name>.py  pl.pallas_call + BlockSpec kernels (TPU target; interpret-mode
           validated on CPU)
ops.py     jitted wrappers with backend-auto interpret
ref.py     pure-jnp oracles (the allclose ground truth for tests)
"""
from repro.kernels import ops, ref  # noqa: F401
