"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def saliency_delta(x: jax.Array, x_prev: jax.Array
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x, x_prev: (N, D). Returns (per-token saliency (N,), ||dX||_F^2,
    ||X_prev||_F^2) — the fused quantities of Eqs. 1 and 4."""
    d = x.astype(F32) - x_prev.astype(F32)
    sal = jnp.sum(d * d, axis=-1)
    return sal, jnp.sum(sal), jnp.sum(jnp.square(x_prev.astype(F32)))


def linear_blend(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: jax.Array, gamma: float) -> jax.Array:
    """out = gamma * (x @ w + b) + (1-gamma) * prev.  x: (M, D); w: (D, F)."""
    y = jnp.matmul(x.astype(F32), w.astype(F32)) + b.astype(F32)
    return (gamma * y + (1.0 - gamma) * prev.astype(F32)).astype(x.dtype)


def fused_gate(x: jax.Array, prev_in: jax.Array, prev_out: jax.Array,
               w: jax.Array, b: jax.Array, sigma2: jax.Array,
               eligible: jax.Array, *, threshold: float, gamma: float = 0.5,
               use_blend: bool = True):
    """Per-sample fused cache gate (Eqs. 4-7 + 6/MB).  x, prev_in, prev_out:
    (B, C, D); w: (D, D); b: (D,); sigma2, eligible: (B,).  Returns
    (out (B,C,D), gate (B,) bool, diff_sq (B,), prev_sq (B,)): gated samples
    get the blended linear approximation, the rest pass through."""
    xf = x.astype(F32)
    pf = prev_in.astype(F32)
    dd = xf - pf
    diff = jnp.sum(dd * dd, axis=(1, 2))
    prevsq = jnp.sum(pf * pf, axis=(1, 2))
    nd = x.shape[1] * x.shape[2]
    stat = diff / (jnp.maximum(sigma2.astype(F32), 1e-30) * nd)
    gate = (stat <= threshold) & eligible.astype(bool)
    approx = jnp.matmul(xf, w.astype(F32)) + b.astype(F32)
    if use_blend:
        approx = gamma * approx + (1.0 - gamma) * prev_out.astype(F32)
    out = jnp.where(gate[:, None, None], approx, xf)
    return out.astype(x.dtype), gate, diff, prevsq


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, window: int = 0) -> jax.Array:
    """q: (B, H, Sq, dh); k, v: (B, KVH, Skv, dh); GQA by head grouping.
    Positions are aligned to the sequence end (prefill: Sq == Skv)."""
    b, h, sq, dh = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, sq, dh)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(F32), k.astype(F32))
    s = s * dh ** -0.5
    qpos = jnp.arange(sq) + (skv - sq)
    kpos = jnp.arange(skv)
    m = jnp.ones((sq, skv), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        m &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(F32))
    return o.reshape(b, h, sq, dh).astype(q.dtype)


def knn_density(h: jax.Array, k: int) -> jax.Array:
    """h: (W, w, D) windowed tokens -> rho_sp (W, w) (Eq. 10)."""
    w = h.shape[-2]
    if not 1 <= k <= w - 1:
        # identical validation to the Pallas kernel's static-k unroll and
        # core/token_merge.knn_density — no silent clamping on any path
        raise ValueError(f"knn_density k={k} out of range for window "
                         f"w={w}; need 1 <= k <= w-1 = {w - 1}")
    hf = h.astype(F32)
    sq = jnp.sum(hf * hf, axis=-1)
    dist = (sq[..., :, None] + sq[..., None, :]
            - 2.0 * jnp.einsum("wid,wjd->wij", hf, hf))
    dist = jnp.maximum(dist, 0.0)
    dist = jnp.where(jnp.eye(w, dtype=bool), jnp.inf, dist)
    neg_topk, _ = jax.lax.top_k(-dist, k)
    return jnp.exp(-jnp.mean(-neg_topk, axis=-1) / h.shape[-1])


def merge_assign(h: jax.Array, s: jax.Array, m: int
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Ground truth of the fused merge kernel (Eqs. 12-13, Alg. 2; one
    window per leading row).  h: (W, w, D) tokens, s: (W, w) per-window-
    normalized importance -> (merged (W, M, D) importance-weighted cluster
    means, assign (W, w) int32 nearest-center ids, centers (W, M) int32
    window-local center indices)."""
    _, centers = jax.lax.top_k(s, m)                       # (W, M)
    ch = jnp.take_along_axis(h, centers[..., None], axis=1)   # (W, M, D)
    hf, cf = h.astype(F32), ch.astype(F32)
    d2 = (jnp.sum(jnp.square(hf), -1)[..., :, None]
          + jnp.sum(jnp.square(cf), -1)[..., None, :]
          - 2.0 * jnp.einsum("wid,wjd->wij", hf, cf))      # (W, w, M)
    assign = jnp.argmin(d2, axis=-1).astype(jnp.int32)     # (W, w)
    onehot = jax.nn.one_hot(assign, m, dtype=F32)          # (W, w, M)
    wgt = onehot * s.astype(F32)[..., None]
    num = jnp.einsum("wim,wid->wmd", wgt, hf)
    den = jnp.maximum(jnp.sum(wgt, axis=1), 1e-9)          # (W, M)
    merged = (num / den[..., None]).astype(h.dtype)        # (W, M, D)
    return merged, assign, centers.astype(jnp.int32)


def unmerge_scatter(merged: jax.Array, assign: jax.Array) -> jax.Array:
    """merged: (W, M, D), assign: (W, w) int32 -> (W, w, D): exact gather
    of each token's cluster representative (the scatter that restores the
    full-resolution grid)."""
    return jnp.take_along_axis(merged, assign[..., None], axis=1)
