"""Fused saliency + Frobenius-delta Pallas kernel.

One pass over (X_t, X_{t-1}) produces the three reductions FastCache needs
per step (Eqs. 1 and 4): per-token squared-L2 saliency, ||X_t - X_{t-1}||_F^2
and ||X_{t-1}||_F^2 — replacing three separate HBM passes with one.

Grid: (N / BN, D / BD); the feature axis is the inner (minor) reduction axis,
so per-token partials accumulate in the (BN,) output block while the two
scalars accumulate across the whole grid (TPU grid execution is sequential,
revisited output blocks stay resident in VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(x_ref, xp_ref, sal_ref, diff_ref, prev_ref):
    j = pl.program_id(1)
    i = pl.program_id(0)
    x = x_ref[...].astype(F32)
    xp = xp_ref[...].astype(F32)
    d = x - xp
    part = jnp.sum(d * d, axis=1)                      # (BN,)

    @pl.when(j == 0)
    def _():
        sal_ref[...] = jnp.zeros_like(sal_ref)

    sal_ref[...] += part

    @pl.when((i == 0) & (j == 0))
    def _():
        diff_ref[...] = jnp.zeros_like(diff_ref)
        prev_ref[...] = jnp.zeros_like(prev_ref)

    diff_ref[...] += jnp.sum(part)[None, None]
    prev_ref[...] += jnp.sum(xp * xp)[None, None]


@functools.partial(jax.jit, static_argnames=("bn", "bd", "interpret"))
def saliency_delta(x: jax.Array, x_prev: jax.Array, *, bn: int = 128,
                   bd: int = 512, interpret: bool = True):
    """x, x_prev: (N, D) -> (saliency (N,), diff_sq (), prev_sq ())."""
    n, d = x.shape
    bn = min(bn, n)
    bd = min(bd, d)
    if n % bn or d % bd:
        raise ValueError(f"shape ({n},{d}) not divisible by block ({bn},{bd})")
    grid = (n // bn, d // bd)
    sal, diff, prev = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), F32),
            jax.ShapeDtypeStruct((1, 1), F32),
            jax.ShapeDtypeStruct((1, 1), F32),
        ],
        interpret=interpret,
    )(x, x_prev)
    return sal, diff[0, 0], prev[0, 0]
