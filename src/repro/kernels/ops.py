"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to auto: compiled Mosaic on TPU, the Pallas
interpreter elsewhere (CPU CI / this container).  The interpreter executes
the same kernel bodies, so correctness tests here transfer to TPU.
"""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import fused_gate as _fg
from repro.kernels import knn_density as _knn
from repro.kernels import linear_blend as _lb
from repro.kernels import saliency_delta as _sd
from repro.kernels import token_merge as _tm


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def default_use_fused() -> bool:
    """Backend auto-selection for the fused gate kernel: compiled Mosaic on
    TPU; elsewhere the pure-JAX reference path is both faster than the Pallas
    interpreter and the kernel's ground truth."""
    return jax.default_backend() == "tpu"


def saliency_delta(x, x_prev, *, bn: int = 128, bd: int = 512,
                   interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _sd.saliency_delta(x, x_prev, bn=bn, bd=bd, interpret=interpret)


def linear_blend(x, w, b, prev, *, gamma: float = 0.5, bm: int = 128,
                 bf: int = 256, bk: int = 256, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _lb.linear_blend(x, w, b, prev, gamma=gamma, bm=bm, bf=bf, bk=bk,
                            interpret=interpret)


def fused_gate(x, prev_in, prev_out, w, b, sigma2, eligible, *,
               threshold: float, gamma: float = 0.5, use_blend: bool = True,
               bc: int = 0, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _fg.fused_gate(x, prev_in, prev_out, w, b, sigma2, eligible,
                          threshold=threshold, gamma=gamma,
                          use_blend=use_blend, bc=bc, interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _fa.flash_attention(q, k, v, causal=causal, window=window, bq=bq,
                               bk=bk, interpret=interpret)


def knn_density(h, *, k: int = 5, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _knn.knn_density(h, k=k, interpret=interpret)


def merge_assign(h, s, *, m: int, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _tm.merge_assign(h, s, m=m, interpret=interpret)


def unmerge_scatter(merged, assign, *, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _tm.unmerge_scatter(merged, assign, interpret=interpret)
