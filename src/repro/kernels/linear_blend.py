"""Fused linear-approximation + motion-aware-blend Pallas kernel.

The FastCache hot path when a block is cached (Eqs. 3/6 + MB):

    out = gamma * (X @ W + b) + (1 - gamma) * prev

One MXU-tiled GEMM with the bias add and blend fused into the epilogue —
no (M, F) intermediate ever hits HBM.  Grid (M/BM, F/BF, D/BK); the K axis is
minor so the f32 accumulator block stays resident in VMEM across K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(x_ref, w_ref, b_ref, prev_ref, out_ref, *, gamma: float,
            nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(x_ref[...].astype(F32), w_ref[...].astype(F32),
                            preferred_element_type=F32)

    @pl.when(k == nk - 1)
    def _():
        acc = out_ref[...] + b_ref[...].astype(F32)
        out_ref[...] = gamma * acc + (1.0 - gamma) * prev_ref[...].astype(F32)


@functools.partial(jax.jit,
                   static_argnames=("gamma", "bm", "bf", "bk", "interpret"))
def linear_blend(x: jax.Array, w: jax.Array, b: jax.Array, prev: jax.Array,
                 *, gamma: float = 0.5, bm: int = 128, bf: int = 256,
                 bk: int = 256, interpret: bool = True) -> jax.Array:
    """x: (M, D); w: (D, F); b: (F,); prev: (M, F) -> (M, F) in f32."""
    m, d = x.shape
    f = w.shape[1]
    bm, bf, bk = min(bm, m), min(bf, f), min(bk, d)
    if m % bm or f % bf or d % bk:
        raise ValueError(f"({m},{d},{f}) not divisible by ({bm},{bk},{bf})")
    nk = d // bk
    out = pl.pallas_call(
        functools.partial(_kernel, gamma=gamma, nk=nk),
        grid=(m // bm, f // bf, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bf), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bf), lambda i, j, k: (0, j)),
            pl.BlockSpec((bm, bf), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, f), F32),
        interpret=interpret,
    )(x, w, b.reshape(1, f), prev)
    return out.astype(x.dtype)
