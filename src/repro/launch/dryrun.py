import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + (os.environ.get("REPRO_DRYRUN_DEVICES") or "512")
                           + " " + os.environ.get("XLA_FLAGS", ""))
# ^ MUST run before any jax import: jax locks the device count on first init.
#   REPRO_DRYRUN_DEVICES overrides for small-mesh CI tests.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, print memory/cost analysis, extract collective bytes
from the partitioned HLO, and write one JSON artifact per combo.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system.  Artifacts land in experiments/dryrun/ and feed
benchmarks/roofline.py (EXPERIMENTS.md §Dry-run / §Roofline).
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.shapes import SHAPES
from repro.distributed.hlo import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (Bundle, build_bundle, model_flops,
                                skip_reason)
from repro.models import flags as model_flags

def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a per-program list of dicts on the
    pinned jax 0.4.37 and a bare dict on newer releases — normalize both."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def _measure_cost(arch: str, shape_name: str, mesh, num_layers: int,
                  prefix_groups: int, seq: int | None = None,
                  attn_seq_shard: bool = False) -> dict:
    """Compile a reduced-depth FULLY-UNROLLED variant and read exact
    per-device costs (XLA's HloCostAnalysis counts while bodies once, so the
    production scan-over-layers compile cannot give exact FLOPs; two of
    these extrapolate linearly in depth — see flags.UNROLL_INNER)."""
    with model_flags.unroll_inner():
        bundle = build_bundle(arch, shape_name, mesh,
                              prefix_groups=prefix_groups,
                              num_layers=num_layers, seq_override=seq,
                              attn_seq_shard=attn_seq_shard)
        jitted = jax.jit(bundle.step_fn, in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings)
        compiled = jitted.lower(*bundle.args).compile()
    cost = _cost_dict(compiled)
    coll, _ = collective_bytes(compiled.as_text(), default_trip=1)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "collectives": coll}


def _measure_at_depth(arch, shape_name, mesh, num_layers, prefix_groups,
                      target_seq: int | None, probe_seqs,
                      attn_seq_shard: bool = False) -> dict:
    """Cost at one depth. If `probe_seqs` is set, compile at those (small)
    sequence lengths and fit a quadratic in S per metric — every per-token
    cost in the system is at most quadratic in S (attention) and the probes
    sit on chunk-size multiples, so the polynomial is exact.  Used for
    ssm/hybrid archs whose unrolled inner scans make direct 32k compiles
    intractably slow."""
    if not probe_seqs:
        return _measure_cost(arch, shape_name, mesh, num_layers,
                             prefix_groups, attn_seq_shard=attn_seq_shard)
    import numpy as np
    probes = [_measure_cost(arch, shape_name, mesh, num_layers,
                            prefix_groups, seq=s,
                            attn_seq_shard=attn_seq_shard)
              for s in probe_seqs]
    xs = np.asarray(probe_seqs, dtype=float)

    def fit(ys):
        coeff = np.polyfit(xs, np.asarray(ys, dtype=float),
                           min(2, len(xs) - 1))
        return float(np.polyval(coeff, target_seq))

    kinds = set()
    for p in probes:
        kinds |= set(p["collectives"])
    return {
        "flops": fit([p["flops"] for p in probes]),
        "bytes": fit([p["bytes"] for p in probes]),
        "collectives": {k: max(0.0, fit([p["collectives"].get(k, 0.0)
                                         for p in probes])) for k in kinds},
    }


def _extrapolate(c1: dict, c2: dict, l1: int, l2: int, l: int) -> dict:
    def lin(a, b):
        return max(0.0, a + (b - a) * (l - l1) / (l2 - l1))

    kinds = set(c1["collectives"]) | set(c2["collectives"])
    return {
        "flops": lin(c1["flops"], c2["flops"]),
        "bytes": lin(c1["bytes"], c2["bytes"]),
        "collectives": {k: lin(c1["collectives"].get(k, 0.0),
                               c2["collectives"].get(k, 0.0))
                        for k in kinds},
    }


def _make_mesh(multi_pod: bool, mesh_shape: str = ""):
    if mesh_shape:
        dims = tuple(int(x) for x in mesh_shape.split(","))
        axes = ("pod", "data", "model") if len(dims) == 3 else ("data",
                                                                "model")
        return jax.make_mesh(dims, axes)
    return make_production_mesh(multi_pod=multi_pod)


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            prefix_groups: int = 1, tag: str = "",
            mesh_shape: str = "", measure_cost: bool = True,
            attn_seq_shard: bool = False) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    if mesh_shape:
        mesh_name = f"mesh{mesh_shape.replace(',', 'x')}"
    reason = skip_reason(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "skip", "skip_reason": reason, "tag": tag}
    if reason:
        print(f"[dryrun] SKIP {arch} x {shape_name}: {reason}", flush=True)
        return rec
    try:
        mesh = _make_mesh(multi_pod, mesh_shape)
        n_chips = mesh.devices.size
        t0 = time.perf_counter()
        bundle: Bundle = build_bundle(arch, shape_name, mesh,
                                      prefix_groups=prefix_groups,
                                      attn_seq_shard=attn_seq_shard)
        jitted = jax.jit(bundle.step_fn, in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings)
        lowered = jitted.lower(*bundle.args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        mem_rec = {}
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    mem_rec[k] = int(v)
        cost = _cost_dict(compiled)
        flops = float(cost.get("flops", 0.0))
        bytes_accessed = float(cost.get("bytes accessed", 0.0))

        hlo = compiled.as_text()
        coll, diag = collective_bytes(hlo, default_trip=bundle.meta["n_super"])

        # ---- exact cost: reduced-depth unrolled variants, linear in depth;
        # ssm/hybrid additionally probe small sequence lengths and fit a
        # quadratic in S (their unrolled chunk loops make 32k compiles slow)
        cfg = get_config(arch)
        period = len(cfg.block_pattern) or 1
        shape = SHAPES[shape_name]
        t0 = time.perf_counter()
        if measure_cost:
            probe_seqs = None
            if (cfg.family in ("ssm", "hybrid")
                    and shape.kind in ("train", "prefill")):
                s = shape.seq_len
                if cfg.family == "ssm":
                    # attention-free: cost is exactly linear in S
                    probe_seqs = [min(512, s), min(1024, s)]
                else:
                    probe_seqs = [min(1024, s), min(2048, s), min(3072, s)]
                if len(set(probe_seqs)) < len(probe_seqs):
                    probe_seqs = None
            c1 = _measure_at_depth(arch, shape_name, mesh, period,
                                   prefix_groups, shape.seq_len, probe_seqs,
                                   attn_seq_shard=attn_seq_shard)
            c2 = _measure_at_depth(arch, shape_name, mesh, 2 * period,
                                   prefix_groups, shape.seq_len, probe_seqs,
                                   attn_seq_shard=attn_seq_shard)
            exact = _extrapolate(c1, c2, period, 2 * period, cfg.num_layers)
        else:
            # compile-proof only (multi-pod pass): reuse raw scan costs
            exact = {"flops": flops, "bytes": bytes_accessed,
                     "collectives": coll}
        t_cost = time.perf_counter() - t0

        mflops = model_flops(cfg, SHAPES[shape_name])
        # all cost numbers are for the per-device (partitioned) program
        terms = {
            "compute_s": exact["flops"] / PEAK_FLOPS,
            "memory_s": exact["bytes"] / HBM_BW,
            "collective_s": exact["collectives"].get("total", 0.0) / ICI_BW,
        }
        terms["dominant"] = max(
            (k for k in terms if k.endswith("_s")), key=lambda k: terms[k])
        rec.update({
            "status": "ok",
            "n_chips": n_chips,
            "params": bundle.meta["params"],
            "meta": bundle.meta,
            "per_device_flops": exact["flops"],
            "per_device_bytes_accessed": exact["bytes"],
            "collective_bytes": exact["collectives"],
            "scan_compile": {"flops": flops, "bytes": bytes_accessed,
                             "collectives": coll,
                             "collectives_static": diag["static"]},
            "memory_analysis": mem_rec,
            "model_flops_global": mflops,
            "model_flops_per_device": mflops / n_chips,
            "useful_flops_ratio": ((mflops / n_chips) / exact["flops"]
                                   if exact["flops"] else 0.0),
            "roofline": terms,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "cost_measure_s": round(t_cost, 2),
            "hlo_bytes": len(hlo),
        })
        print(f"[dryrun] OK {arch} x {shape_name} x {mesh_name}"
              f" flops/dev={exact['flops']:.3e}"
              f" bytes/dev={exact['bytes']:.3e}"
              f" coll/dev={exact['collectives'].get('total', 0):.3e}B"
              f" useful={rec['useful_flops_ratio']:.2f}"
              f" temp={mem_rec.get('temp_size_in_bytes', -1)/2**30:.2f}GiB"
              f" compile={t_compile:.1f}s cost={t_cost:.1f}s", flush=True)
        if mem is not None:
            print(f"         memory_analysis: {mem_rec}", flush=True)
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        print(f"[dryrun] FAIL {arch} x {shape_name} x {mesh_name}: {e}",
              flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        path = os.path.join(out_dir,
                            f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--prefix-groups", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--mesh-shape", default="",
                    help="override mesh, e.g. '2,2' (CI small-mesh tests)")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the cost-extrapolation compiles (multi-pod "
                         "compile-proof runs)")
    ap.add_argument("--moe-gather-decode", action="store_true",
                    help="perf variant: gather-based MoE for decode shapes")
    ap.add_argument("--attn-seq-shard", action="store_true",
                    help="perf variant: shard attention q/logits seq over "
                         "`model`")
    ap.add_argument("--attn-chunk", type=int, default=0,
                    help="perf variant: force chunked attention above this "
                         "Sq*Skv (elements)")
    ap.add_argument("--moe-constrain-dispatch", action="store_true",
                    help="perf variant: shard MoE dispatch intermediates")
    ap.add_argument("--ce-remat", action="store_true",
                    help="perf variant: rematerialize chunked-CE logits")
    args = ap.parse_args()
    if args.ce_remat:
        model_flags.CE_REMAT = True
    if args.attn_chunk:
        model_flags.DIRECT_MAX_ELEMS = args.attn_chunk
    if args.moe_constrain_dispatch:
        model_flags.MOE_CONSTRAIN_DISPATCH = True
    if args.moe_gather_decode:
        model_flags.MOE_GATHER_DECODE = True

    archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_one(arch, shape, mp, args.out,
                                       prefix_groups=args.prefix_groups,
                                       tag=args.tag,
                                       mesh_shape=args.mesh_shape,
                                       measure_cost=not args.no_cost,
                                       attn_seq_shard=args.attn_seq_shard))
    ok = sum(r["status"] == "ok" for r in results)
    skip = sum(r["status"] == "skip" for r in results)
    fail = sum(r["status"] == "fail" for r in results)
    print(f"[dryrun] done: {ok} ok, {skip} skip, {fail} fail", flush=True)
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
