"""Bundles for the dry-run and launchers: per (arch x input-shape) the step
function, abstract inputs (ShapeDtypeStruct — no allocation), and explicit
in/out shardings.

Shape -> step mapping (brief §MULTI-POD DRY-RUN):
  train_4k     train_step  (loss + grad + optimizer update)
  prefill_32k  prefill (decoders) / encode (encoder-only archs)
  decode_32k   serve_step: ONE new token against a seq_len KV cache
  long_500k    serve_step; sub-quadratic only (SWA window for dense/moe/vlm,
               native state for ssm/hybrid) — see DESIGN.md §6
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import InputShape, ModelConfig
from repro.configs.shapes import SHAPES
from repro.distributed.sharding import (ShardingCtx, make_rules,
                                        param_shardings, spec_for,
                                        use_sharding)
from repro.models import build_model
from repro.models.params import ParamDef, abstract_params, count_params
from repro.training.loop import make_train_step
from repro.training.optimizer import (AdamW, Adafactor, cosine_schedule,
                                      make_optimizer)

SWA_WINDOW = 8192  # sliding window substituting full attention at 500k


class Bundle(NamedTuple):
    step_fn: Any
    args: Tuple                     # ShapeDtypeStructs
    in_shardings: Tuple
    out_shardings: Any
    meta: Dict


def skip_reason(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    if cfg.is_encoder and shape_name in ("decode_32k", "long_500k"):
        return "encoder-only architecture: no decode step (DESIGN.md §6)"
    return None


def resolve_config(arch: str, shape: InputShape) -> ModelConfig:
    cfg = get_config(arch)
    if shape.name == "long_500k" and not cfg.is_encoder:
        kinds = set(cfg.layer_kinds)
        if kinds == {"attn"} or (cfg.moe is not None and kinds == {"attn"}):
            # dense/moe/vlm: sub-quadratic via sliding-window attention
            cfg = cfg.replace(sliding_window=SWA_WINDOW)
    return cfg


# --------------------------------------------------------------------------
# Abstract batches
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, batch: int, seq: int, *, train: bool
                ) -> Tuple[Dict, Dict]:
    """(SDS dict, logical-axes dict) for a full-sequence batch."""
    dt = cfg.dtype
    if cfg.family == "audio":
        sds = {"features": _sds((batch, seq, cfg.frontend_dim), "float32")}
        axes = {"features": ("act_batch", "act_seq", None)}
        if train:
            sds["targets"] = _sds((batch, seq), "int32")
            sds["mask_indices"] = _sds((batch, seq), "bool")
            axes["targets"] = ("act_batch", "act_seq")
            axes["mask_indices"] = ("act_batch", "act_seq")
        return sds, axes
    sds = {"tokens": _sds((batch, seq), "int32")}
    axes = {"tokens": ("act_batch", "act_seq")}
    if cfg.family == "vlm":
        sds["vision_embeds"] = _sds((batch, cfg.vision_tokens, cfg.d_model),
                                    dt)
        sds["vision_mask"] = _sds((batch, seq), "bool")
        sds["positions"] = _sds((batch, seq, 3), "int32")
        axes["vision_embeds"] = ("act_batch", None, "act_embed")
        axes["vision_mask"] = ("act_batch", "act_seq")
        axes["positions"] = ("act_batch", "act_seq", None)
    return sds, axes


def _shard_tree(sds_tree, axes_tree, ctx: ShardingCtx):
    return jax.tree.map(
        lambda s, a: NamedSharding(ctx.mesh, spec_for(s.shape, a, ctx)),
        sds_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, (tuple, jax.ShapeDtypeStruct))
        and not isinstance(x, dict))


def _replicated(ctx):
    return NamedSharding(ctx.mesh, P())


# --------------------------------------------------------------------------
# Optimizer sharding
# --------------------------------------------------------------------------

def optimizer_shardings(opt, defs, ctx: ShardingCtx):
    scalar = _replicated(ctx)
    if isinstance(opt, AdamW):
        ps = param_shardings(defs, ctx)
        from repro.training.optimizer import AdamWState
        return AdamWState(step=scalar, mu=ps, nu=ps)
    if not isinstance(opt, Adafactor):
        raise TypeError(f"optimizer_shardings supports AdamW and Adafactor; "
                        f"got {type(opt).__name__}")
    from repro.training.optimizer import AdafactorState

    def vr(d: ParamDef):
        if len(d.shape) >= 2:
            return NamedSharding(ctx.mesh,
                                 spec_for(d.shape[:-1], d.axes[:-1], ctx))
        return NamedSharding(ctx.mesh, spec_for(d.shape, d.axes, ctx))

    def vc(d: ParamDef):
        if len(d.shape) >= 2:
            return NamedSharding(
                ctx.mesh,
                spec_for(d.shape[:-2] + d.shape[-1:],
                         d.axes[:-2] + d.axes[-1:], ctx))
        return _replicated(ctx)

    leaf = lambda x: isinstance(x, ParamDef)
    return AdafactorState(step=scalar,
                          vr=jax.tree.map(vr, defs, is_leaf=leaf),
                          vc=jax.tree.map(vc, defs, is_leaf=leaf))


# --------------------------------------------------------------------------
# Bundle builder
# --------------------------------------------------------------------------

def build_bundle(arch: str, shape_name: str, mesh, *,
                 prefix_groups: int = 1,
                 num_layers: Optional[int] = None,
                 seq_override: Optional[int] = None,
                 attn_seq_shard: bool = False) -> Bundle:
    shape = SHAPES[shape_name]
    cfg = resolve_config(arch, shape)
    if num_layers is not None:
        cfg = cfg.replace(num_layers=num_layers)
    if seq_override is not None:
        shape = dataclasses.replace(shape, seq_len=seq_override)
    long_ctx = shape.name == "long_500k"
    rules = make_rules(shape.kind, long_context=long_ctx,
                       attn_seq_shard=attn_seq_shard)
    ctx = ShardingCtx(mesh, rules)

    model = build_model(cfg) if cfg.family == "dit" else build_model(
        cfg, prefix_groups=prefix_groups)
    defs = model.param_defs()
    params_sds = model.abstract_params()
    params_sh = param_shardings(defs, ctx)
    n_params = count_params(defs)

    meta = {"arch": arch, "shape": shape_name, "config": cfg.name,
            "params": n_params, "family": cfg.family,
            "n_super": getattr(model, "n_super", cfg.num_layers),
            "seq_len": shape.seq_len, "global_batch": shape.global_batch,
            "kind": shape.kind,
            "sliding_window": cfg.sliding_window}

    if shape.kind == "train":
        bsds, baxes = batch_specs(cfg, shape.global_batch, shape.seq_len,
                                  train=True)
        bsh = _shard_tree(bsds, baxes, ctx)
        opt = make_optimizer(cfg.optimizer)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        opt_sh = optimizer_shardings(opt, defs, ctx)
        lr_fn = cosine_schedule(3e-4, 100, 10_000)
        train_step = make_train_step(model, opt, lr_fn)

        def step(params, opt_state, batch):
            with use_sharding(mesh, rules):
                return train_step(params, opt_state, batch)

        metrics_sh = jax.tree.map(
            lambda _: _replicated(ctx),
            jax.eval_shape(train_step, params_sds, opt_sds, bsds)[2])
        return Bundle(step, (params_sds, opt_sds, bsds),
                      (params_sh, opt_sh, bsh),
                      (params_sh, opt_sh, metrics_sh), meta)

    if shape.kind == "prefill":
        bsds, baxes = batch_specs(cfg, shape.global_batch, shape.seq_len,
                                  train=False)
        bsh = _shard_tree(bsds, baxes, ctx)
        if cfg.is_encoder:
            def step(params, batch):
                with use_sharding(mesh, rules):
                    hidden, _ = model.apply(params, batch)
                    return hidden

            out_sds = jax.eval_shape(step, params_sds, bsds)
            out_sh = NamedSharding(ctx.mesh, spec_for(
                out_sds.shape, ("act_batch", "act_seq", "act_embed"), ctx))
            return Bundle(step, (params_sds, bsds), (params_sh, bsh),
                          out_sh, meta)

        window = shape.seq_len

        def step(params, batch):
            with use_sharding(mesh, rules):
                return model.prefill(params, batch, window)

        cache_sh = param_shardings(
            model.cache_defs(shape.global_batch, window), ctx)
        logits_sds, _ = jax.eval_shape(step, params_sds, bsds)
        logits_sh = NamedSharding(ctx.mesh, spec_for(
            logits_sds.shape, ("act_batch", "act_vocab"), ctx))
        return Bundle(step, (params_sds, bsds), (params_sh, bsh),
                      (logits_sh, cache_sh), meta)

    # ---- decode
    window = min(shape.seq_len,
                 cfg.sliding_window if cfg.sliding_window else shape.seq_len)
    meta["cache_window"] = window
    cache_defs = model.cache_defs(shape.global_batch, window)
    cache_sds = abstract_params(cache_defs, cfg.dtype)
    cache_sh = param_shardings(cache_defs, ctx)
    tokens_sds = _sds((shape.global_batch,), "int32")
    tokens_sh = NamedSharding(ctx.mesh, spec_for(
        (shape.global_batch,), ("act_batch",), ctx))

    def step(params, tokens, cache):
        with use_sharding(mesh, rules):
            return model.decode_step(params, tokens, cache)

    logits_sds, _ = jax.eval_shape(step, params_sds, tokens_sds, cache_sds)
    logits_sh = NamedSharding(ctx.mesh, spec_for(
        logits_sds.shape, ("act_batch", "act_vocab"), ctx))
    return Bundle(step, (params_sds, tokens_sds, cache_sds),
                  (params_sh, tokens_sh, cache_sh),
                  (logits_sh, cache_sh), meta)


# --------------------------------------------------------------------------
# Model FLOPs (roofline's "useful compute" reference)
# --------------------------------------------------------------------------

def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: routed fraction only)."""
    model = build_model(cfg)
    total = count_params(model.param_defs())
    if cfg.moe is None:
        return total
    m = cfg.moe
    expert_block = 3 * cfg.d_model * m.d_ff_expert
    n_moe_layers = sum(1 for i in range(len(cfg.layer_kinds))
                       if cfg.family == "moe"
                       or (cfg.moe and i % m.moe_layer_period == 1))
    if cfg.family == "moe":
        n_moe_layers = cfg.num_layers
    all_expert = n_moe_layers * m.num_experts * expert_block
    active_expert = n_moe_layers * m.top_k * expert_block
    return total - all_expert + active_expert


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """6*N*D for training, 2*N_active*D for inference (D = tokens)."""
    n_act = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch  # one token per sequence
