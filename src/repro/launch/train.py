"""Distributed training launcher.

On real hardware this runs under `jax.distributed.initialize` with the
production mesh; on this container it runs the same code path on the host
mesh with reduced configs (--reduced) — the dry-run (launch/dryrun.py) is the
production-mesh proof.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save as save_ckpt
from repro.configs import get_config, get_reduced
from repro.data import audio_stream, latent_stream, token_stream
from repro.distributed.sharding import make_rules, use_sharding
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build_model
from repro.training import cosine_schedule, make_optimizer, train


def data_for(cfg, batch, seq, seed=0):
    if cfg.family == "audio":
        return audio_stream(batch, seq, cfg.frontend_dim, cfg.vocab_size,
                            seed=seed)
    if cfg.family == "dit":
        return latent_stream(batch, cfg.dit.image_size, cfg.dit.in_channels,
                             num_classes=cfg.dit.num_classes, seed=seed)
    return token_stream(cfg.vocab_size, batch, seq, seed=seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default="")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = cfg.replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params, opt={cfg.optimizer}")

    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    rules = make_rules("train")
    opt = make_optimizer(cfg.optimizer)
    lr_fn = cosine_schedule(args.lr, args.warmup, args.steps)
    it = data_for(cfg, args.batch, args.seq, args.seed)

    def log(i, m):
        print(f"[train] step {i:5d} loss={m['loss']:.4f} "
              f"lr={m['lr']:.2e} |g|={m['grad_norm']:.2f} "
              f"({m['elapsed_s']:.1f}s)", flush=True)

    with use_sharding(mesh, rules):
        params, _, hist = train(model, params, opt, lr_fn, it,
                                steps=args.steps, log_every=10, callback=log)
    if args.save:
        save_ckpt(args.save, params, {"arch": cfg.name, "steps": args.steps,
                                      "history": hist})
        print(f"[train] saved -> {args.save}")


if __name__ == "__main__":
    main()
