"""Calibration recorder launcher: record per-layer per-step output deltas
on an uncached run into an ``.npz`` artifact.

    PYTHONPATH=src python -m repro.launch.calibrate --arch dit-b2 \
        --reduced --batch 2 --steps 20 --out calib_dit-b2.npz

The artifact carries ``errors_mean`` (L, T) — exactly the matrix
``smooth_schedule_from_errors`` consumes — plus the raw per-row deltas
(``rel_delta`` (T, L, B)) for policies that calibrate per-band or
per-percentile (ROADMAP: spectralcache).  ``--threshold`` prints the
SmoothCache schedule the recording implies, as a quick sanity readout.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT
from repro.core.policies.smoothcache import smooth_schedule_from_errors
from repro.models import build_model
from repro.obs import record_calibration, save_calibration


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-b2")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--guidance", type=float, default=4.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", required=True,
                    help="output .npz artifact path")
    ap.add_argument("--threshold", type=float, default=0.0,
                    help="if > 0, print the SmoothCache schedule this "
                         "recording implies at that error threshold")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = cfg.replace(dtype="float32")
    if cfg.dit is None:
        raise SystemExit(f"{cfg.name} is not a DiT — nothing to calibrate")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    runner = CachedDiT(model, FastCacheConfig(), policy="nocache")

    result = record_calibration(runner, params, batch=args.batch,
                                num_steps=args.steps,
                                guidance_scale=args.guidance,
                                seed=args.seed)
    save_calibration(args.out, result)
    em = result["errors_mean"]
    print(f"[calibrate] {args.arch}: recorded ({em.shape[0]} layers, "
          f"{em.shape[1]} steps) x batch {int(result['batch'])} -> "
          f"{args.out}")
    print(f"[calibrate] mean rel delta per step: "
          f"{np.round(em.mean(axis=0), 4).tolist()}")
    if args.threshold > 0.0:
        schedule = smooth_schedule_from_errors(em, args.threshold)
        frac = float(np.asarray(schedule, np.float32).mean())
        print(f"[calibrate] smoothcache schedule @ thr={args.threshold}: "
              f"{frac:.1%} of (layer, step) cells reuse the cache")


if __name__ == "__main__":
    main()
