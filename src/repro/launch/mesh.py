"""Production meshes.

Functions (not module constants) so importing this module never touches jax
device state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests (axis names match production)."""
    return jax.make_mesh((1, 1), ("data", "model"))
