"""Production meshes.

Functions (not module constants) so importing this module never touches jax
device state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests (axis names match production)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serving_mesh(data=None, model: int = 1):
    """(data, model) mesh for the sharded diffusion serving engine —
    slots over `data`, DiT weights tensor-parallel over `model`.  The
    implementation lives next to its consumer in
    repro.serving.sharded_engine; the lazy import keeps `import
    repro.launch.mesh` from pulling in the whole serving stack."""
    from repro.serving.sharded_engine import make_serving_mesh as _make
    return _make(data, model)
