"""Diffusion serving launcher: continuous-batching DiT sampling with
per-slot FastCache state (the image-generation twin of launch/serve.py).

    PYTHONPATH=src python -m repro.launch.serve_diffusion --arch dit-b2 \
        --reduced --requests 8 --slots 2 --steps 10 --policy fastcache

``--lockstep`` switches to the fixed-wave baseline (admit a full batch only
when every slot is free) for latency comparisons; ``--json`` emits the
summary as JSON.

``--steps-mix 20,50`` / ``--guidance-mix 1.0,4.0`` make the workload
heterogeneous: each request draws its own sampling plan (DDIM step budget,
guidance scale) from the mix and one engine batch serves them side by side
— the engine's plan tables are sized to the largest budget in the mix.
``--sched sjf`` switches the admission queue from FIFO to
shortest-job-first (smallest step budget among arrived requests first);
``--sched edf`` to earliest-deadline-first (needs ``--deadline-slack-mix``).

SLO control plane (``src/repro/serving/slo/``): ``--priority-mix 0,1,1,2``
and ``--deadline-slack-mix 12,20,32`` draw per-request priority classes
and deadlines; ``--burst-rate 2.0 --burst-start 5 --burst-len 20``
modulates the Poisson arrivals into a calm -> burst -> calm trace.
``--slo`` serves through ``SLOScheduler`` — strict-priority queues,
deadline-aware admission (``--on-miss reject|defer``), priority
preemption with bitwise device-side snapshot/resume (``--no-preempt``
disables), and, with ``--shed``, the watermark-hysteresis degradation
controller walking the default shed-level ladder under queue pressure
(``--shed-high``/``--shed-low`` watermarks, in ready-queue depth).  The
summary gains per-class latency/deadline/queue-wait breakdowns and
admission-rejection reasons.

``--no-cfg`` opts a guidance==1.0-only deployment into the static no-CFG
fast path: single-row slots, no materialized uncond half — the model batch
is S instead of 2S.

Observability (see ``src/repro/obs/``): ``--metrics-out prom.txt`` writes
the Prometheus text exposition at run end, ``--metrics-jsonl m.jsonl`` the
per-window JSONL trajectory (window size via ``--metrics-window N``, in
engine steps; default: one window at run end), and ``--trace-out t.json``
a Chrome/Perfetto trace of the run (open in ``ui.perfetto.dev``) with
per-request admit/finish spans and per-slot denoise slices annotated with
the policy's cache decision.

``--audit-fraction 0.03125`` turns on the shadow-compute audit plane
(``src/repro/obs/audit.py``): a deterministic seeded fraction of serve
steps also runs the full uncached forward and measures cached-vs-true
error on device, checked against the policy's chi^2-predicted bound.
``--audit-baseline calib.npz`` arms the drift gauge against a PR 7
calibration recording; ``--audit-out audit.json`` writes per-request
error budgets plus the windowed drift/burn summary at run end.

``--mesh data,model`` serves through ``ShardedDiffusionEngine`` on a
``(data, model)`` device mesh (slots over ``data``, DiT weights over
``model``) with async host admission — disable the overlap with
``--sync-admission``.  Multi-device CPU runs need
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before launch:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.serve_diffusion --arch dit-b2 \\
        --reduced --requests 8 --slots 4 --steps 10 --mesh 4,2
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT, POLICIES
from repro.models import build_model
from repro.launch.mesh import make_serving_mesh
from repro.obs import (MetricsCollector, TraceRecorder, load_calibration,
                       validate_trace)
from repro.obs import audit as obs_audit
from repro.serving import (SCHED_POLICIES, AdmissionController,
                           DegradationController, DiffusionServingEngine,
                           ShardedDiffusionEngine, SLOScheduler,
                           piecewise_rate, poisson_trace,
                           summarize_by_class, summarize_by_steps)


def percentile(xs, p):
    return float(np.percentile(np.asarray(xs, np.float64), p)) if xs else -1.0


def parse_mesh(spec: str):
    """'data,model' (e.g. '4,2') -> (data, model) ints."""
    try:
        data, model = (int(v) for v in spec.split(","))
    except ValueError:
        raise SystemExit(f"--mesh expects 'data,model' ints, got {spec!r}")
    return data, model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-b2")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--steps", type=int, default=10,
                    help="default DDIM steps per request")
    ap.add_argument("--guidance", type=float, default=4.0,
                    help="default guidance scale per request")
    ap.add_argument("--steps-mix", default="",
                    help="comma list of DDIM step budgets; each request "
                         "draws its own (e.g. 20,50)")
    ap.add_argument("--guidance-mix", default="",
                    help="comma list of guidance scales; each request "
                         "draws its own (e.g. 1.0,4.0)")
    ap.add_argument("--sched", default="fifo", choices=SCHED_POLICIES,
                    help="admission order among arrived requests (within "
                         "a priority class): FIFO, shortest-job-first, or "
                         "earliest-deadline-first")
    ap.add_argument("--priority-mix", default="",
                    help="comma list of priority classes requests draw "
                         "from uniformly (0 = most critical; empty = all "
                         "class 0)")
    ap.add_argument("--deadline-slack-mix", default="",
                    help="comma list of deadline slacks (engine steps "
                         "past arrival) requests draw from uniformly "
                         "(empty = no deadlines)")
    ap.add_argument("--burst-rate", type=float, default=0.0,
                    help="burst arrival rate; with --burst-len > 0 the "
                         "trace is calm (--rate) -> burst -> calm")
    ap.add_argument("--burst-start", type=int, default=0,
                    help="engine step the burst begins at")
    ap.add_argument("--burst-len", type=int, default=0,
                    help="burst duration in engine steps (0 = no burst)")
    ap.add_argument("--slo", action="store_true",
                    help="serve through the SLO control plane "
                         "(SLOScheduler): strict-priority queues, "
                         "deadline-aware admission, priority preemption "
                         "with device-side snapshot/resume")
    ap.add_argument("--on-miss", default="reject",
                    choices=("reject", "defer"),
                    help="--slo: what deadline-aware admission does with "
                         "a request predicted to miss: reject it, or "
                         "defer and re-test later")
    ap.add_argument("--no-preempt", action="store_true",
                    help="--slo: disable priority preemption")
    ap.add_argument("--shed", action="store_true",
                    help="--slo: enable the graceful-degradation "
                         "controller (default shed-level ladder, "
                         "watermark hysteresis on ready-queue depth)")
    ap.add_argument("--shed-high", type=int, default=8,
                    help="--shed: queue depth escalating one shed level "
                         "when sustained")
    ap.add_argument("--shed-low", type=int, default=2,
                    help="--shed: queue depth de-escalating one shed "
                         "level when sustained")
    ap.add_argument("--no-cfg", action="store_true",
                    help="static no-CFG fast path for guidance==1.0-only "
                         "deployments: single-row slots, no materialized "
                         "uncond half (model batch S instead of 2S); "
                         "requires --guidance 1.0 and an all-1.0 "
                         "--guidance-mix")
    ap.add_argument("--policy", default="fastcache", choices=POLICIES)
    ap.add_argument("--token-merge-ratio", type=float, default=1.0,
                    help="serving-path token compression: keep "
                         "ceil(ratio * window) cluster centers per window "
                         "of tokens before the cache policy runs "
                         "(core/token_reduce.py); 1.0 disables the stage "
                         "(bitwise-identical to merge-off)")
    ap.add_argument("--token-merge-window", type=int, default=16,
                    help="token-compression window size w; the DiT token "
                         "count must be divisible by it")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate (requests per engine step)")
    ap.add_argument("--lockstep", action="store_true",
                    help="fixed-wave baseline instead of continuous admission")
    ap.add_argument("--mesh", default="",
                    help="serve sharded on a 'data,model' mesh (e.g. 4,2); "
                         "empty = single-device engine")
    ap.add_argument("--sync-admission", action="store_true",
                    help="sharded engine only: disable the async admission/"
                         "harvest overlap (sync per-completion fetches)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--metrics-out", default="",
                    help="write the Prometheus text exposition here at "
                         "run end")
    ap.add_argument("--metrics-jsonl", default="",
                    help="write the per-window JSONL metrics trajectory "
                         "here at run end")
    ap.add_argument("--metrics-window", type=int, default=0,
                    help="harvest a metrics window every N engine steps "
                         "(each window close is one device sync); 0 = one "
                         "window at run end only")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome/Perfetto trace JSON of the run "
                         "here (per-request spans, per-slot denoise "
                         "slices with cache decisions)")
    ap.add_argument("--audit-fraction", type=float, default=0.0,
                    help="shadow-audit this fraction of serve steps "
                         "(deterministic seeded schedule; 0 disables the "
                         "audit plane entirely — it is statically dead "
                         "code in the jitted step)")
    ap.add_argument("--audit-seed", type=int, default=0,
                    help="seed for the audit sampling schedule")
    ap.add_argument("--audit-baseline", default="",
                    help="calibration .npz (obs.calibration) to arm the "
                         "audit_drift_ratio gauge: measured per-layer "
                         "cache error vs the nocache run's natural "
                         "inter-step deltas")
    ap.add_argument("--audit-out", default="",
                    help="write the audit report JSON (per-request error "
                         "budgets, windowed drift/burn summary) here at "
                         "run end")
    args = ap.parse_args()
    if args.audit_out and args.audit_fraction <= 0.0:
        raise SystemExit("--audit-out needs --audit-fraction > 0")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = cfg.replace(dtype="float32")
    if cfg.dit is None:
        raise SystemExit(f"{cfg.name} is not a DiT — nothing to diffuse")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if not 0.0 < args.token_merge_ratio <= 1.0:
        raise SystemExit(f"--token-merge-ratio must be in (0, 1], got "
                         f"{args.token_merge_ratio}")
    fc = FastCacheConfig(merge_enabled=args.token_merge_ratio < 1.0,
                         merge_ratio=args.token_merge_ratio,
                         merge_window=args.token_merge_window)
    runner = CachedDiT(model, fc, policy=args.policy)
    steps_mix = [int(v) for v in args.steps_mix.split(",") if v.strip()]
    guidance_mix = [float(v) for v in args.guidance_mix.split(",")
                    if v.strip()]
    # plan tables must fit the largest step budget in the workload
    max_steps = max(steps_mix + [args.steps])
    if args.no_cfg and (args.guidance != 1.0
                        or any(g != 1.0 for g in guidance_mix)):
        raise SystemExit("--no-cfg serves guidance==1.0 only; pass "
                         "--guidance 1.0 and an all-1.0 --guidance-mix")
    # the audit plane folds into the device metrics pytree, so auditing
    # implies the metrics plane (and a collector to harvest drift/burn)
    want_metrics = bool(args.metrics_out or args.metrics_jsonl
                        or args.audit_fraction > 0.0)
    collector = MetricsCollector(
        labels={"policy": args.policy, "arch": args.arch},
        window_steps=args.metrics_window or None) if want_metrics else None
    if collector is not None and args.audit_baseline:
        calib = load_calibration(args.audit_baseline)
        collector.set_audit_context(baseline=calib["errors_mean"])
    tracer = TraceRecorder() if args.trace_out else None
    if args.mesh:
        data, tp = parse_mesh(args.mesh)
        engine = ShardedDiffusionEngine(
            runner, params, max_slots=args.slots, num_steps=args.steps,
            guidance_scale=args.guidance, max_steps=max_steps,
            mesh=make_serving_mesh(data, tp),
            async_admission=not args.sync_admission,
            cfg_rows=not args.no_cfg, collector=collector, tracer=tracer,
            audit_fraction=args.audit_fraction, audit_seed=args.audit_seed)
    else:
        engine = DiffusionServingEngine(runner, params,
                                        max_slots=args.slots,
                                        num_steps=args.steps,
                                        guidance_scale=args.guidance,
                                        max_steps=max_steps,
                                        cfg_rows=not args.no_cfg,
                                        collector=collector, tracer=tracer,
                                        audit_fraction=args.audit_fraction,
                                        audit_seed=args.audit_seed)
    priority_mix = [int(v) for v in args.priority_mix.split(",")
                    if v.strip()]
    slack_mix = [int(v) for v in args.deadline_slack_mix.split(",")
                 if v.strip()]
    rate_fn = None
    if args.burst_len > 0:
        if args.burst_rate <= 0.0:
            raise SystemExit("--burst-len needs --burst-rate > 0")
        rate_fn = piecewise_rate([(args.burst_start, args.rate),
                                  (args.burst_start + args.burst_len,
                                   args.burst_rate),
                                  (10 ** 9, args.rate)])
    trace = poisson_trace(args.requests, args.rate, seed=args.seed,
                          num_classes=cfg.dit.num_classes,
                          steps_mix=steps_mix or None,
                          guidance_mix=guidance_mix or None,
                          rate_fn=rate_fn,
                          priority_mix=priority_mix or None,
                          deadline_slack_mix=slack_mix or None)
    rejected = []
    if args.slo:
        if args.lockstep:
            raise SystemExit("--slo drives continuous admission; drop "
                             "--lockstep")
        admission = AdmissionController(engine, on_miss=args.on_miss,
                                        collector=collector)
        controller = DegradationController(
            high_watermark=args.shed_high, low_watermark=args.shed_low,
            collector=collector) if args.shed else None
        slo = SLOScheduler(engine, sched_policy=args.sched,
                           admission=admission, controller=controller,
                           preempt=not args.no_preempt,
                           collector=collector)
        t0 = time.perf_counter()
        done = slo.run(trace)
        dt = time.perf_counter() - t0
        rejected = slo.rejected
    else:
        t0 = time.perf_counter()
        done = engine.run(trace, lockstep=args.lockstep,
                          sched_policy=args.sched)
        dt = time.perf_counter() - t0

    lats = [r.latency_steps for r in done]
    summary = {
        "mode": "lockstep" if args.lockstep else "continuous",
        "sched_policy": args.sched,
        "topology": (engine.topology() if args.mesh
                     else {"data": 1, "model": 1, "devices": 1}),
        "async_admission": bool(args.mesh) and not args.sync_admission,
        "cfg_rows": not args.no_cfg,
        "policy": args.policy,
        "requests": len(done),
        "steps_mix": steps_mix or [args.steps],
        "guidance_mix": guidance_mix or [args.guidance],
        "engine_steps": engine.clock,
        "model_steps": engine.model_steps,
        "wall_s": dt,
        "requests_per_s": len(done) / dt if dt else 0.0,
        "latency_steps_p50": percentile(lats, 50),
        "latency_steps_p95": percentile(lats, 95),
        "latency_by_steps": summarize_by_steps(done + rejected),
        "by_class": summarize_by_class(done + rejected),
        "cache": engine.cache_stats(),
        "token_merge": {"ratio": args.token_merge_ratio,
                        "window": args.token_merge_window,
                        "active": runner.reducer is not None},
    }
    if args.slo:
        met = sum(1 for r in done
                  if r.deadline_step is None
                  or r.finish_step <= r.deadline_step)
        summary["slo"] = {
            "on_miss": args.on_miss,
            "preempt": not args.no_preempt,
            "shed": bool(args.shed),
            "shed_level": (controller.level.name if controller is not None
                           else None),
            "rejected": len(rejected),
            "deadline_met": met,
            "goodput": met / len(trace) if trace else 0.0,
            "preemptions": sum(r.preemptions for r in done),
        }
    if collector is not None:
        collector.set_gauge("run_wall_seconds", dt)
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                f.write(collector.to_prometheus())
        if args.metrics_jsonl:
            with open(args.metrics_jsonl, "w") as f:
                f.write(collector.to_jsonl())
    if args.audit_fraction > 0.0:
        report = obs_audit.audit_report(done, fraction=args.audit_fraction,
                                        bound=runner.audit_bound(),
                                        collector=collector)
        summary["audit"] = {k: report[k] for k in
                            ("audit_fraction", "predicted_bound",
                             "violations_total")}
        if args.audit_out:
            with open(args.audit_out, "w") as f:
                json.dump(report, f, indent=2)
    if tracer is not None:
        doc = tracer.to_json()
        validate_trace(doc)
        with open(args.trace_out, "w") as f:
            json.dump(doc, f)
    if args.json:
        print(json.dumps(summary, indent=2))
    else:
        print(f"[serve-diffusion] {summary['mode']} sched={args.sched} "
              f"policy={args.policy}: "
              f"{len(done)} requests in {dt:.2f}s "
              f"({summary['requests_per_s']:.2f} req/s incl. compile), "
              f"{engine.clock} engine steps")
        print(f"[serve-diffusion] latency (steps): "
              f"p50={summary['latency_steps_p50']:.0f} "
              f"p95={summary['latency_steps_p95']:.0f}")
        print(f"[serve-diffusion] cache: {engine.cache_stats()}")


if __name__ == "__main__":
    main()
