"""Diffusion serving launcher: continuous-batching DiT sampling with
per-slot FastCache state (the image-generation twin of launch/serve.py).

    PYTHONPATH=src python -m repro.launch.serve_diffusion --arch dit-b2 \
        --reduced --requests 8 --slots 2 --steps 10 --policy fastcache

``--lockstep`` switches to the fixed-wave baseline (admit a full batch only
when every slot is free) for latency comparisons; ``--json`` emits the
summary as JSON.

``--mesh data,model`` serves through ``ShardedDiffusionEngine`` on a
``(data, model)`` device mesh (slots over ``data``, DiT weights over
``model``) with async host admission — disable the overlap with
``--sync-admission``.  Multi-device CPU runs need
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before launch:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.serve_diffusion --arch dit-b2 \\
        --reduced --requests 8 --slots 4 --steps 10 --mesh 4,2
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT, POLICIES
from repro.models import build_model
from repro.launch.mesh import make_serving_mesh
from repro.serving import (DiffusionServingEngine, ShardedDiffusionEngine,
                           poisson_trace)


def percentile(xs, p):
    return float(np.percentile(np.asarray(xs, np.float64), p)) if xs else -1.0


def parse_mesh(spec: str):
    """'data,model' (e.g. '4,2') -> (data, model) ints."""
    try:
        data, model = (int(v) for v in spec.split(","))
    except ValueError:
        raise SystemExit(f"--mesh expects 'data,model' ints, got {spec!r}")
    return data, model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-b2")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--steps", type=int, default=10,
                    help="DDIM steps per request")
    ap.add_argument("--guidance", type=float, default=4.0)
    ap.add_argument("--policy", default="fastcache", choices=POLICIES)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate (requests per engine step)")
    ap.add_argument("--lockstep", action="store_true",
                    help="fixed-wave baseline instead of continuous admission")
    ap.add_argument("--mesh", default="",
                    help="serve sharded on a 'data,model' mesh (e.g. 4,2); "
                         "empty = single-device engine")
    ap.add_argument("--sync-admission", action="store_true",
                    help="sharded engine only: disable the async admission/"
                         "harvest overlap (sync per-completion fetches)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = cfg.replace(dtype="float32")
    if cfg.dit is None:
        raise SystemExit(f"{cfg.name} is not a DiT — nothing to diffuse")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    runner = CachedDiT(model, FastCacheConfig(), policy=args.policy)
    if args.mesh:
        data, tp = parse_mesh(args.mesh)
        engine = ShardedDiffusionEngine(
            runner, params, max_slots=args.slots, num_steps=args.steps,
            guidance_scale=args.guidance,
            mesh=make_serving_mesh(data, tp),
            async_admission=not args.sync_admission)
    else:
        engine = DiffusionServingEngine(runner, params,
                                        max_slots=args.slots,
                                        num_steps=args.steps,
                                        guidance_scale=args.guidance)
    trace = poisson_trace(args.requests, args.rate, seed=args.seed,
                          num_classes=cfg.dit.num_classes)
    t0 = time.perf_counter()
    done = engine.run(trace, lockstep=args.lockstep)
    dt = time.perf_counter() - t0

    lats = [r.latency_steps for r in done]
    summary = {
        "mode": "lockstep" if args.lockstep else "continuous",
        "topology": (engine.topology() if args.mesh
                     else {"data": 1, "model": 1, "devices": 1}),
        "async_admission": bool(args.mesh) and not args.sync_admission,
        "policy": args.policy,
        "requests": len(done),
        "engine_steps": engine.clock,
        "model_steps": engine.model_steps,
        "wall_s": dt,
        "requests_per_s": len(done) / dt if dt else 0.0,
        "latency_steps_p50": percentile(lats, 50),
        "latency_steps_p95": percentile(lats, 95),
        "cache": engine.cache_stats(),
    }
    if args.json:
        print(json.dumps(summary, indent=2))
    else:
        print(f"[serve-diffusion] {summary['mode']} policy={args.policy}: "
              f"{len(done)} requests in {dt:.2f}s "
              f"({summary['requests_per_s']:.2f} req/s incl. compile), "
              f"{engine.clock} engine steps")
        print(f"[serve-diffusion] latency (steps): "
              f"p50={summary['latency_steps_p50']:.0f} "
              f"p95={summary['latency_steps_p95']:.0f}")
        print(f"[serve-diffusion] cache: {engine.cache_stats()}")


if __name__ == "__main__":
    main()
