"""Serving launcher: batched prefill + decode with optional FastCache decode
gating (the paper's technique on the AR-decode axis).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 8 --new-tokens 16 --fastcache
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.configs.base import FastCacheConfig
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--window", type=int, default=256)
    ap.add_argument("--fastcache", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = cfg.replace(dtype="float32")
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    fc = FastCacheConfig() if args.fastcache else None
    if fc is not None and (model.period != 1 or model.kinds != ("attn",)):
        print("[serve] FastCache decode gating needs a period-1 attention "
              "stack; running without it")
        fc = None
    engine = ServingEngine(model, params, max_batch=args.max_batch,
                           window=args.window, fastcache=fc)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s incl. compile)")
    stats = engine.cache_stats()
    if stats:
        print(f"[serve] FastCache decode: {stats}")


if __name__ == "__main__":
    main()
