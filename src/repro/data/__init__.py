from repro.data.synthetic import (audio_stream, latent_stream,  # noqa: F401
                                  token_stream, video_latents)
