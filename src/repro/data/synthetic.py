"""Deterministic synthetic data pipelines.

Offline container: no datasets ship with it, so training/serving examples run
on seeded synthetic streams with enough structure to be learnable:

* ``token_stream`` — Zipf-ish unigram mixture with a first-order Markov
  kicker: next-token distribution depends on the previous token's residue
  class, so a real LM can beat the unigram entropy floor (tests check this).
* ``latent_stream`` — class-conditioned Gaussian blobs with per-class spatial
  frequency patterns in (H, W, C) latent space (DiT training).
* ``video_latents`` — temporally-correlated latent sequences with a moving
  foreground and a static background: the workload FastCache's saliency
  split is designed for (used by benchmarks to reproduce Fig. 1/Table 5
  static-ratio behaviour).
"""
from __future__ import annotations

import math
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


def token_stream(vocab: int, batch: int, seq: int, *, seed: int = 0,
                 num_classes: int = 8) -> Iterator[Dict]:
    rng = np.random.default_rng(seed)
    # class-conditional unigram tables (Zipf base re-shuffled per class)
    base = 1.0 / (np.arange(1, vocab + 1) ** 1.1)
    tables = np.stack([rng.permutation(base) for _ in range(num_classes)])
    tables /= tables.sum(-1, keepdims=True)
    while True:
        out = np.empty((batch, seq), np.int32)
        prev = rng.integers(0, vocab, size=batch)
        for t in range(seq):
            cls = prev % num_classes
            # vectorized per-class sampling
            u = rng.random(batch)
            cdf = np.cumsum(tables[cls], axis=-1)
            nxt = (u[:, None] < cdf).argmax(-1)
            out[:, t] = nxt
            prev = nxt
        yield {"tokens": jnp.asarray(out)}


def latent_stream(batch: int, image_size: int, channels: int, *,
                  num_classes: int = 10, seed: int = 0,
                  num_train_steps: int = 1000) -> Iterator[Dict]:
    """DiT training batches: (x_t, t, labels, noise) per DDPM forward."""
    from repro.diffusion.schedule import add_noise, linear_schedule
    rng = np.random.default_rng(seed)
    sched = linear_schedule(num_train_steps)
    yy, xx = np.meshgrid(np.arange(image_size), np.arange(image_size),
                         indexing="ij")
    while True:
        labels = rng.integers(0, num_classes, size=batch)
        freq = (labels % 4 + 1)[:, None, None, None]
        phase = (labels // 4)[:, None, None, None] * 0.7
        grid = np.sin(2 * np.pi * freq * xx[None, ..., None]
                      / image_size + phase) \
            * np.cos(2 * np.pi * freq * yy[None, ..., None] / image_size)
        x0 = grid + 0.1 * rng.standard_normal(
            (batch, image_size, image_size, channels))
        t = rng.integers(0, num_train_steps, size=batch)
        noise = rng.standard_normal(x0.shape)
        x_t = add_noise(sched, jnp.asarray(x0, F32), jnp.asarray(noise, F32),
                        jnp.asarray(t))
        yield {"latents": x_t, "t": jnp.asarray(t, jnp.int32),
               "labels": jnp.asarray(labels, jnp.int32),
               "noise": jnp.asarray(noise, F32)}


def video_latents(batch: int, frames: int, image_size: int, channels: int,
                  *, motion_amplitude: float = 1.0, seed: int = 0
                  ) -> jnp.ndarray:
    """(B, T, H, W, C) latents: static textured background + a small moving
    square whose speed scales with motion_amplitude."""
    rng = np.random.default_rng(seed)
    bg = rng.standard_normal((batch, 1, image_size, image_size, channels))
    out = np.repeat(bg, frames, axis=1).astype(np.float32)
    sq = max(2, image_size // 4)
    for b in range(batch):
        cx = rng.integers(0, image_size - sq)
        cy = rng.integers(0, image_size - sq)
        vx = motion_amplitude * rng.uniform(0.5, 1.5)
        vy = motion_amplitude * rng.uniform(-1.0, 1.0)
        patch = 2.0 * rng.standard_normal((sq, sq, channels))
        for t in range(frames):
            x0 = int(cx + vx * t) % (image_size - sq + 1)
            y0 = int(cy + vy * t) % (image_size - sq + 1)
            out[b, t, y0:y0 + sq, x0:x0 + sq] = patch
    return jnp.asarray(out)


def audio_stream(batch: int, seq: int, frontend_dim: int, vocab: int, *,
                 seed: int = 0, mask_prob: float = 0.2) -> Iterator[Dict]:
    """HuBERT-style masked-prediction batches over stub conv features."""
    rng = np.random.default_rng(seed)
    proto = rng.standard_normal((vocab, frontend_dim)).astype(np.float32)
    while True:
        targets = rng.integers(0, vocab, size=(batch, seq))
        feats = proto[targets] + 0.3 * rng.standard_normal(
            (batch, seq, frontend_dim)).astype(np.float32)
        mask = rng.random((batch, seq)) < mask_prob
        feats = np.where(mask[..., None], 0.0, feats)
        yield {"features": jnp.asarray(feats),
               "targets": jnp.asarray(targets, jnp.int32),
               "mask_indices": jnp.asarray(mask)}
