"""Observability: device-resident serving metrics, request tracing, and
the offline calibration recorder.

Three planes, three sync disciplines:

- **metrics** (``obs.metrics``): a device pytree of counters/histograms
  updated with pure ``jnp`` inside the jitted serve_step; the host-side
  ``MetricsCollector`` harvests only at run end / window close.  Zero
  per-step syncs — machine-checked by reprolint's ``obs-discipline``;
- **tracing** (``obs.tracing``): per-request Chrome/Perfetto trace JSON.
  Diagnostic mode: host clocks per step, deferred device snapshots;
- **calibration** (``obs.calibration``): nocache per-layer delta recorder
  for SmoothCache/spectral schedules.  Offline, syncs freely.
"""
from repro.obs.calibration import (load_calibration, record_calibration,
                                   save_calibration)
from repro.obs.metrics import (METRICS, MetricsCollector, MetricSpec,
                               counter, histogram, init_device_metrics,
                               parse_prometheus)
from repro.obs.tracing import TraceRecorder, validate_trace

__all__ = [
    "METRICS", "MetricSpec", "MetricsCollector", "TraceRecorder",
    "counter", "histogram", "init_device_metrics", "load_calibration",
    "parse_prometheus", "record_calibration", "save_calibration",
    "validate_trace",
]
