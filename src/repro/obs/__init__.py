"""Observability: device-resident serving metrics, request tracing, and
the offline calibration recorder.

Three planes, three sync disciplines:

- **metrics** (``obs.metrics``): a device pytree of counters/histograms
  updated with pure ``jnp`` inside the jitted serve_step; the host-side
  ``MetricsCollector`` harvests only at run end / window close.  Zero
  per-step syncs — machine-checked by reprolint's ``obs-discipline``;
- **tracing** (``obs.tracing``): per-request Chrome/Perfetto trace JSON.
  Diagnostic mode: host clocks per step, deferred device snapshots;
- **calibration** (``obs.calibration``): nocache per-layer delta recorder
  for SmoothCache/spectral schedules.  Offline, syncs freely;
- **audit** (``obs.audit``): the shadow-compute quality plane — on a
  deterministic seeded fraction of serve steps the jitted step also runs
  the full uncached forward and folds cached-vs-true error into the
  metrics pytree and the per-request accumulators.  Pure ``jnp`` under one
  ``lax.cond``; statically dead when ``audit_fraction == 0``.
"""
from repro.obs.audit import (DEFAULT_AUDIT_FRACTION, audit_mask,
                             audit_report)
from repro.obs.calibration import (load_calibration, record_calibration,
                                   save_calibration)
from repro.obs.metrics import (METRICS, MetricsCollector, MetricSpec,
                               counter, histogram, histogram_quantile,
                               init_device_metrics, parse_prometheus)
from repro.obs.tracing import TraceRecorder, validate_trace

__all__ = [
    "DEFAULT_AUDIT_FRACTION", "METRICS", "MetricSpec", "MetricsCollector",
    "TraceRecorder", "audit_mask", "audit_report", "counter", "histogram",
    "histogram_quantile", "init_device_metrics", "load_calibration",
    "parse_prometheus", "record_calibration", "save_calibration",
    "validate_trace",
]
