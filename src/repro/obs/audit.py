"""Shadow-compute audit plane: online cached-vs-true error measurement.

The paper's headline theoretical claim is a *bounded approximation error*
under the chi^2 decision rule — this module measures that error while the
cache is serving.  On a deterministic seeded schedule (``audit_mask``,
computed host-side from the engine's step counter, so the jitted program
is compile-static), the serve_step runs the full uncached forward
alongside the cached path for the same inputs and accumulates:

- **end-to-end error**: per-slot relative eps error after the identical
  CFG/guidance blend (``sampler.denoise_step`` with ``model_eval`` routed
  through ``CachedDiT.audit_eval``) — observed into the ``audit_rel_err``
  histogram and the per-slot / per-request accumulators;
- **per-layer error**: when the policy exposes its hidden stack
  (``CachePolicy.audit_hidden``; fastcache's ``prev_hidden``), the
  relative error of every block's cached hidden vs the true stack, into
  the metrics pytree's ``audit`` group;
- **bound violations**: audited rows whose measured error exceeds the
  policy's ``predicted_error_bound()`` (Eq. 9 for fastcache) bump
  ``bound_violations_total``;
- **per-request error budget**: ``audit_err_sum / audit_err_sq_sum /
  audit_steps / audit_violations`` ride the engines' per-slot ``slot_acc``
  accumulators, so they are zeroed at admission and harvested into
  ``req.cache`` at finish like every policy stat.

Sync discipline (the reason this lives under ``obs/``): everything here is
pure ``jnp`` inside the jitted step, wrapped in one ``lax.cond`` on a
traced boolean flag — non-audited steps execute none of the shadow
forward, audited steps recompile nothing, and no value crosses to the
host.  The engines guard every call with a static ``if self._audit_on:``
so the whole plane is dead code when ``audit_fraction == 0`` — reprolint's
``obs-discipline`` check enforces that guard at every call site.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.diffusion import sampler
from repro.obs import metrics as obs_metrics

F32 = jnp.float32

# 1/32 of serve steps: measured overhead well under the 5% budget (the
# shadow forward roughly doubles an audited step, so fraction ~= overhead)
DEFAULT_AUDIT_FRACTION = 1.0 / 32.0

# per-request error-budget keys that ride the engines' slot_acc pytree
# (zeroed at admission, harvested into req.cache at finish)
ACC_ERR_SUM = "audit_err_sum"
ACC_ERR_SQ = "audit_err_sq_sum"
ACC_STEPS = "audit_steps"
ACC_VIOLATIONS = "audit_violations"
AUDIT_ACC_KEYS = (ACC_ERR_SUM, ACC_ERR_SQ, ACC_STEPS, ACC_VIOLATIONS)


def _splitmix64(z: int) -> int:
    """SplitMix64 finalizer: a cheap, well-mixed 64-bit hash."""
    z = (z + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return z ^ (z >> 31)


def audit_mask(step: int, fraction: float, seed: int = 0) -> bool:
    """Deterministic stratified audit schedule: the step counter is
    partitioned into windows of ``round(1/fraction)`` steps and exactly
    one hashed offset per window is audited.  Stratification (vs an
    i.i.d. per-step hash) pins the realized rate to the nominal fraction
    over ANY horizon — no audit bursts inflating a short run's overhead,
    no droughts starving drift detection — while staying unpredictable
    per window.  Host-side Python on the engine's step counter — the jit
    sees only the resulting boolean as a traced ``()`` argument, so the
    schedule is compile-static and reproducible across runs/engines for
    the same seed."""
    if fraction <= 0.0:
        return False
    if fraction >= 1.0:
        return True
    period = max(2, round(1.0 / fraction))
    window, offset = divmod(int(step), period)
    h = _splitmix64((window << 17) ^ (int(seed) * 0x5851F42D4C957F2D
                                      & 0xFFFFFFFFFFFFFFFF))
    return offset == h % period


def rel_err_rows(a: jax.Array, b: jax.Array,
                 eps: float = 1e-12) -> jax.Array:
    """Per-row relative Frobenius error ||a - b|| / ||b||, reducing every
    axis but the leading one.  ``b`` is the reference (the true forward)."""
    axes = tuple(range(1, b.ndim))
    num = jnp.sum(jnp.square(a.astype(F32) - b.astype(F32)), axis=axes)
    den = jnp.sum(jnp.square(b.astype(F32)), axis=axes)
    return jnp.sqrt(num / jnp.maximum(den, eps))


def layer_rel_err(cached: jax.Array, true: jax.Array,
                  eps: float = 1e-12) -> jax.Array:
    """Per-layer per-row relative Frobenius error for (L+1, B, N, D)
    hidden stacks -> (L+1, B)."""
    num = jnp.sum(jnp.square(cached.astype(F32) - true.astype(F32)),
                  axis=(2, 3))
    den = jnp.sum(jnp.square(true.astype(F32)), axis=(2, 3))
    return jnp.sqrt(num / jnp.maximum(den, eps))


def apply_audit(runner, params, sched, state: Dict, x: jax.Array,
                t: jax.Array, t_prev: jax.Array, labels: jax.Array,
                guidance, active: jax.Array, eps_cached: jax.Array,
                cfg_rows: bool, bound: Optional[float], metrics: Dict,
                slot_acc: Dict, audit_flag: jax.Array
                ) -> Tuple[Dict, Dict]:
    """One audit decision inside the jitted serve_step: ``lax.cond`` on the
    traced ``audit_flag`` — the true branch runs the shadow full forward on
    the SAME pre-step latents ``x`` and folds cached-vs-true errors into
    the metrics pytree and the per-slot request accumulators; the false
    branch passes both through untouched (one executable, no recompiles,
    nothing leaves the device).

    ``state`` is the post-step policy state (read-only here: the hidden
    stack the cached path just produced), ``eps_cached`` the post-blend eps
    the cached path fed its DDIM update, ``bound`` the policy's claimed
    per-step relative error bound (None = no claim, never violates)."""
    bound_val = float("inf") if bound is None else float(bound)

    def audited(ops):
        metrics, slot_acc = ops
        hidden_box = []

        def shadow_eval(p, st, lat, t_in, lab):
            eps_true, hid = runner.audit_eval(p, lat, t_in, lab)
            hidden_box.append(hid)
            return eps_true, st

        _, _, eps_true = sampler.denoise_step(
            runner, params, sched, {}, x, t, t_prev, labels,
            guidance_scale=guidance, model_eval=shadow_eval,
            return_eps=True)

        act = active.astype(F32)                        # (S,)
        err = rel_err_rows(eps_cached, eps_true) * act  # (S,)
        viol = ((err > bound_val) & active).astype(F32)

        metrics = obs_metrics.inc(metrics, obs_metrics.AUDIT_STEPS, 1.0)
        metrics = obs_metrics.inc(metrics, obs_metrics.AUDIT_SLOT_STEPS,
                                  jnp.sum(act))
        metrics = obs_metrics.inc(metrics, obs_metrics.BOUND_VIOLATIONS,
                                  jnp.sum(viol))
        metrics = obs_metrics.observe_many(metrics,
                                           obs_metrics.AUDIT_REL_ERR,
                                           err, act)
        metrics = obs_metrics.slot_add(metrics, obs_metrics.SLOT_AUDIT_ERR,
                                       err)
        metrics = obs_metrics.slot_add(metrics,
                                       obs_metrics.SLOT_AUDIT_STEPS, act)

        hid_cached = runner.audit_hidden(state)
        if hid_cached is not None:      # static per policy: None = the
            #                             policy caches no hidden stack
            hid_true = hidden_box[0]
            act_rows = (jnp.concatenate([act, act]) if cfg_rows else act)
            lerr = layer_rel_err(hid_cached, hid_true)  # (L+1, B_eff)
            grp = dict(metrics["audit"])
            grp["layer_err_sum"] = (grp["layer_err_sum"]
                                    + jnp.sum(lerr * act_rows[None],
                                              axis=1))
            grp["layer_rows"] = grp["layer_rows"] + jnp.sum(act_rows)
            metrics = {**metrics, "audit": grp}

        slot_acc = dict(slot_acc)
        slot_acc[ACC_ERR_SUM] = slot_acc[ACC_ERR_SUM] + err
        slot_acc[ACC_ERR_SQ] = slot_acc[ACC_ERR_SQ] + err * err
        slot_acc[ACC_STEPS] = slot_acc[ACC_STEPS] + act
        slot_acc[ACC_VIOLATIONS] = slot_acc[ACC_VIOLATIONS] + viol
        return metrics, slot_acc

    def passthrough(ops):
        return ops

    return jax.lax.cond(audit_flag, audited, passthrough,
                        (metrics, slot_acc))


# --------------------------------------------------------------------------
# Host-side reporting (--audit-out)
# --------------------------------------------------------------------------


def request_budget(cache: Dict) -> Dict[str, float]:
    """Summarize one finished request's harvested error budget (the
    ``AUDIT_ACC_KEYS`` the engine copied into ``req.cache``)."""
    steps = float(cache.get(ACC_STEPS, 0.0))
    err_sum = float(cache.get(ACC_ERR_SUM, 0.0))
    err_sq = float(cache.get(ACC_ERR_SQ, 0.0))
    mean = err_sum / steps if steps > 0 else 0.0
    var = max(err_sq / steps - mean * mean, 0.0) if steps > 0 else 0.0
    return {
        "audited_steps": steps,
        "err_sum": err_sum,
        "err_mean": mean,
        "err_std": var ** 0.5,
        "violations": float(cache.get(ACC_VIOLATIONS, 0.0)),
    }


def audit_report(finished, *, fraction: float,
                 bound: Optional[float] = None,
                 collector=None) -> Dict:
    """The ``--audit-out`` JSON document: per-request error budgets plus
    the collector's latest windowed drift/burn summary (when a collector
    with harvested audit metrics is supplied)."""
    requests = []
    for r in finished:
        row = {"rid": r.rid}
        row.update(request_budget(r.cache or {}))
        requests.append(row)
    doc = {
        "audit_fraction": fraction,
        "predicted_bound": bound,
        "requests": requests,
        "violations_total": sum(r["violations"] for r in requests),
    }
    if collector is not None and collector.windows:
        last = collector.windows[-1]
        if "audit" in last:
            doc["window"] = last["audit"]
    return doc
