"""Device-resident serving metrics: counters and fixed-bucket histograms
that live as a pytree threaded through the jitted ``serve_step``.

**The zero-sync design rule.**  The serving engines' steady-state contract
(PR 6, ``tests/test_serving_invariants.py``) forbids per-step host syncs
and recompiles — so the metrics plane is split in two:

- the **device plane** is a pytree of ``jnp`` arrays (scalar counters,
  per-bin histogram counts, per-slot accumulators) that the engines donate
  alongside the cache state and update with pure ``jnp`` ops inside the
  jitted step.  Updating a metric costs a few fused elementwise ops and
  never touches the host;
- the **host plane** is a :class:`MetricsCollector` that accumulates
  host-clock observations (admissions, request latencies — plain Python
  floats, no device round-trip) and *harvests* the device pytree only at
  existing sync points: run end, or an explicit periodic window
  (``window_steps``).  ``MetricsCollector.harvest`` is the ONLY place a
  metric value crosses to the host, and reprolint's ``obs-discipline``
  check statically proves it is unreachable from any jit region.

Metric *names* are registered once, module-import time, via
:func:`counter` / :func:`histogram`; duplicate names raise (and are also
caught statically by ``obs-discipline``).  Exports: Prometheus text
exposition (:meth:`MetricsCollector.to_prometheus`) and JSONL windows
(:meth:`MetricsCollector.to_jsonl`).
"""
from __future__ import annotations

import dataclasses
import json
import re
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32

_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    name: str
    kind: str                       # "counter" | "histogram"
    help: str
    buckets: Tuple[float, ...] = ()  # histogram upper bounds (le), +Inf
    #                                  overflow bin is implicit


METRICS: Dict[str, MetricSpec] = {}


def _register(spec: MetricSpec) -> str:
    if not _NAME_RE.match(spec.name):
        raise ValueError(f"metric name {spec.name!r} is not a valid "
                         f"Prometheus metric name")
    prev = METRICS.get(spec.name)
    if prev is not None and prev != spec:
        raise ValueError(f"metric {spec.name!r} already registered with a "
                         f"different spec ({prev})")
    METRICS[spec.name] = spec
    return spec.name


def counter(name: str, help: str = "") -> str:
    """Register a monotonic counter; returns the name (use the returned
    binding so reprolint's ``obs-discipline`` can see every registration)."""
    return _register(MetricSpec(name, "counter", help))


def histogram(name: str, help: str = "",
              buckets: Tuple[float, ...] = (1, 2, 4, 8, 16, 32)) -> str:
    """Register a fixed-bucket histogram.  ``buckets`` are ascending upper
    bounds (Prometheus ``le``); an overflow (+Inf) bin is implicit."""
    b = tuple(float(x) for x in buckets)
    if list(b) != sorted(b) or len(set(b)) != len(b):
        raise ValueError(f"histogram {name!r} buckets must be strictly "
                         f"ascending, got {b}")
    return _register(MetricSpec(name, "histogram", help, b))


def spec(name: str) -> MetricSpec:
    try:
        return METRICS[name]
    except KeyError:
        raise ValueError(f"unknown metric {name!r}; registered: "
                         f"{', '.join(sorted(METRICS)) or '(none)'}") from None


# --------------------------------------------------------------------------
# The serving metric set (names shared by both engines; one registration
# site so obs-discipline's uniqueness rule has a single source of truth)
# --------------------------------------------------------------------------

SERVE_STEPS = counter(
    "serve_steps_total", "jitted serve_step dispatches (model steps)")
ACTIVE_SLOT_STEPS = counter(
    "active_slot_steps_total", "slot-steps carrying a live request")
BLOCKS_COMPUTED = counter(
    "blocks_computed_total", "transformer blocks executed")
BLOCKS_SKIPPED = counter(
    "blocks_skipped_total", "transformer blocks served from cache")
STEP_REUSES = counter(
    "cache_step_reuses_total", "whole-step cache reuses (active rows)")
ADMISSIONS = counter(
    "admissions_total", "requests admitted into a slot")
REQUESTS_FINISHED = counter(
    "requests_finished_total", "requests served to completion")
DECODE_TOKENS = counter(
    "decode_tokens_total", "AR tokens sampled across all slots")
PREFILLS = counter(
    "prefills_total", "AR prefill dispatches")

ACTIVE_SLOTS = histogram(
    "active_slots", "active slots per serve_step",
    buckets=(0, 1, 2, 4, 8, 16, 32, 64))
SKIP_FRACTION = histogram(
    "cache_skip_fraction", "per-step fraction of active rows reusing the "
    "whole-step cache", buckets=(0.0, 0.25, 0.5, 0.75, 0.9, 1.0))
REQUEST_LATENCY = histogram(
    "request_latency_steps", "queueing + service latency (engine steps)",
    buckets=(4, 8, 16, 32, 64, 128, 256, 512))
QUEUE_WAIT = histogram(
    "queue_wait_steps", "arrival -> admission wait (engine steps)",
    buckets=(0, 1, 2, 4, 8, 16, 32, 64))

SLOT_ACTIVE_STEPS = counter(
    "slot_active_steps", "per-slot steps carrying a live request "
    "(device-resident (S,) counter, sharded over the mesh data axis)")

# device-plane membership for the diffusion serve_step
DEVICE_COUNTERS = (SERVE_STEPS, ACTIVE_SLOT_STEPS, BLOCKS_COMPUTED,
                   BLOCKS_SKIPPED, STEP_REUSES)
DEVICE_HISTOGRAMS = (ACTIVE_SLOTS, SKIP_FRACTION)
DEVICE_PER_SLOT = (SLOT_ACTIVE_STEPS,)


# --------------------------------------------------------------------------
# Device plane: pure-jnp init / update (jit- and donation-safe)
# --------------------------------------------------------------------------


def init_device_metrics(max_slots: int) -> Dict:
    """The serving device-metrics pytree: scalar counters, per-bin
    histogram counts (+ sum/count), and per-slot ``(S,)`` accumulators.
    Arrays only — the engines donate it buffer-for-buffer alongside the
    cache state, and the sharding walker places the per-slot group over
    the mesh ``data`` axis."""
    return {
        "counters": {n: jnp.zeros((), F32) for n in DEVICE_COUNTERS},
        "hist": {n: {"bucket": jnp.zeros((len(spec(n).buckets) + 1,), F32),
                     "sum": jnp.zeros((), F32),
                     "count": jnp.zeros((), F32)}
                 for n in DEVICE_HISTOGRAMS},
        "per_slot": {n: jnp.zeros((max_slots,), F32)
                     for n in DEVICE_PER_SLOT},
    }


def inc(m: Dict, name: str, value) -> Dict:
    """Pure counter bump: returns a new metrics pytree with
    ``counters[name] += value`` (``value`` may be a traced scalar)."""
    counters = dict(m["counters"])
    counters[name] = counters[name] + value
    return {**m, "counters": counters}


def observe(m: Dict, name: str, value) -> Dict:
    """Pure histogram observation: bumps the bin ``value`` falls in (upper
    bounds from the registered spec; overflow bin last) plus sum/count."""
    bounds = jnp.asarray(spec(name).buckets, F32)
    idx = jnp.searchsorted(bounds, jnp.asarray(value, F32), side="left")
    hist = dict(m["hist"])
    h = dict(hist[name])
    h["bucket"] = h["bucket"].at[idx].add(1.0)
    h["sum"] = h["sum"] + value
    h["count"] = h["count"] + 1.0
    hist[name] = h
    return {**m, "hist": hist}


def slot_add(m: Dict, name: str, values) -> Dict:
    """Pure per-slot accumulation: ``per_slot[name] += values`` ((S,))."""
    per_slot = dict(m["per_slot"])
    per_slot[name] = per_slot[name] + values
    return {**m, "per_slot": per_slot}


# --------------------------------------------------------------------------
# Host plane
# --------------------------------------------------------------------------


class MetricsCollector:
    """Host-side metrics aggregation + export.

    Host observations (:meth:`inc` / :meth:`observe`) are plain Python
    arithmetic — safe anywhere on the orchestration path.  Device metrics
    cross to the host ONLY through :meth:`harvest`, which the engines call
    at run end (and optionally every ``window_steps`` engine steps); each
    harvest appends one window snapshot for the JSONL trajectory, and the
    latest cumulative values feed the Prometheus exposition."""

    def __init__(self, labels: Optional[Dict[str, str]] = None, *,
                 window_steps: Optional[int] = None):
        if window_steps is not None and window_steps < 1:
            raise ValueError(f"window_steps must be >= 1, got "
                             f"{window_steps}")
        self.labels = dict(labels or {})
        self.window_steps = window_steps
        self._counters: Dict[str, float] = {}
        self._hist: Dict[str, Dict] = {}
        self._device: Dict = {}          # latest harvested device snapshot
        self._gauges: Dict[str, float] = {}
        self.windows: List[Dict] = []
        self._t0 = time.perf_counter()

    # -- host observations (no device involvement) ---------------------

    def inc(self, name: str, value: float = 1.0) -> None:
        if spec(name).kind != "counter":
            raise ValueError(f"metric {name!r} is not a counter")
        self._counters[name] = self._counters.get(name, 0.0) + float(value)

    def observe(self, name: str, value: float) -> None:
        s = spec(name)
        if s.kind != "histogram":
            raise ValueError(f"metric {name!r} is not a histogram")
        h = self._hist.setdefault(
            name, {"bucket": np.zeros(len(s.buckets) + 1, np.float64),
                   "sum": 0.0, "count": 0.0})
        idx = int(np.searchsorted(np.asarray(s.buckets), float(value),
                                  side="left"))
        h["bucket"][idx] += 1.0
        h["sum"] += float(value)
        h["count"] += 1.0

    def set_gauge(self, name: str, value: float) -> None:
        """Free-form gauge (clock readings, occupancy at harvest time);
        gauges need no registration — they are point-in-time readings, not
        accumulated series, so the uniqueness rule does not apply."""
        self._gauges[name] = float(value)

    # -- the sync point -------------------------------------------------

    def harvest(self, device_metrics: Optional[Dict] = None, *,
                at_step: Optional[int] = None) -> Dict:
        """Materialize the device metrics pytree (THE device->host sync —
        engines call this only at run end / window close) and snapshot one
        window.  Values are cumulative since engine start; the window
        record carries the wall-clock and step-clock stamps so the JSONL
        series is a trajectory, not deltas."""
        if device_metrics:
            host = jax.tree.map(np.asarray, device_metrics)
            self._device = host
        window = {
            "at_step": at_step,
            "wall_s": time.perf_counter() - self._t0,
            "labels": dict(self.labels),
            "counters": self._merged_counters(),
            "histograms": {n: {"buckets": list(spec(n).buckets),
                               "bucket_counts": [float(v)
                                                 for v in h["bucket"]],
                               "sum": float(h["sum"]),
                               "count": float(h["count"])}
                           for n, h in self._all_hists().items()},
            "gauges": dict(self._gauges),
        }
        if self._device.get("per_slot"):
            window["per_slot"] = {
                n: [float(x) for x in v]
                for n, v in self._device["per_slot"].items()}
        self.windows.append(window)
        return window

    # -- merged views ---------------------------------------------------

    def _merged_counters(self) -> Dict[str, float]:
        out = {n: float(v) for n, v in self._counters.items()}
        for n, v in self._device.get("counters", {}).items():
            out[n] = out.get(n, 0.0) + float(v)
        return out

    def _all_hists(self) -> Dict[str, Dict]:
        out = {n: {"bucket": np.asarray(h["bucket"], np.float64),
                   "sum": float(h["sum"]), "count": float(h["count"])}
               for n, h in self._hist.items()}
        for n, h in self._device.get("hist", {}).items():
            cur = out.get(n)
            add = {"bucket": np.asarray(h["bucket"], np.float64),
                   "sum": float(h["sum"]), "count": float(h["count"])}
            if cur is None:
                out[n] = add
            else:
                out[n] = {"bucket": cur["bucket"] + add["bucket"],
                          "sum": cur["sum"] + add["sum"],
                          "count": cur["count"] + add["count"]}
        return out

    def totals(self) -> Dict[str, float]:
        """Cumulative counters (host + last-harvested device values)."""
        return self._merged_counters()

    # -- exports --------------------------------------------------------

    def _label_str(self, extra: Optional[Dict[str, str]] = None) -> str:
        labels = {**self.labels, **(extra or {})}
        if not labels:
            return ""
        body = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
        return "{" + body + "}"

    def to_prometheus(self, prefix: str = "repro_") -> str:
        """Prometheus text exposition format (v0.0.4): counters as
        ``<prefix><name>``, histograms as cumulative ``_bucket{le=...}``
        series plus ``_sum``/``_count``, gauges as-is."""
        lines: List[str] = []
        ls = self._label_str()
        for n, v in sorted(self._merged_counters().items()):
            full = prefix + n
            if spec(n).help:
                lines.append(f"# HELP {full} {spec(n).help}")
            lines.append(f"# TYPE {full} counter")
            lines.append(f"{full}{ls} {_fmt(v)}")
        for n, h in sorted(self._all_hists().items()):
            full = prefix + n
            if spec(n).help:
                lines.append(f"# HELP {full} {spec(n).help}")
            lines.append(f"# TYPE {full} histogram")
            cum = 0.0
            for le, cnt in zip(spec(n).buckets, h["bucket"]):
                cum += float(cnt)
                lines.append(f"{full}_bucket"
                             f"{self._label_str({'le': _fmt(le)})} "
                             f"{_fmt(cum)}")
            cum += float(h["bucket"][-1])
            lines.append(f"{full}_bucket{self._label_str({'le': '+Inf'})} "
                         f"{_fmt(cum)}")
            lines.append(f"{full}_sum{ls} {_fmt(h['sum'])}")
            lines.append(f"{full}_count{ls} {_fmt(h['count'])}")
        for n, v in sorted(self._gauges.items()):
            full = prefix + n
            lines.append(f"# TYPE {full} gauge")
            lines.append(f"{full}{ls} {_fmt(v)}")
        return "\n".join(lines) + "\n"

    def to_jsonl(self) -> str:
        """One JSON object per harvested window (cumulative snapshots)."""
        return "\n".join(json.dumps(w) for w in self.windows) + (
            "\n" if self.windows else "")


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


# --------------------------------------------------------------------------
# Exposition parser (round-trip validation; also used by tests)
# --------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")


def parse_prometheus(text: str) -> Dict[str, Dict]:
    """Parse Prometheus text exposition into
    ``{metric: {"type": ..., "samples": [(labels dict, value)]}}``.
    Raises ``ValueError`` on any malformed line — the tests use this to
    assert the export parses cleanly."""
    out: Dict[str, Dict] = {}
    types: Dict[str, str] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(None, 3)
            types[name] = kind
            out.setdefault(name, {"type": kind, "samples": []})
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"malformed exposition line {lineno}: "
                             f"{line!r}")
        labels: Dict[str, str] = {}
        if m.group("labels"):
            for part in m.group("labels").split(","):
                if not part:
                    continue
                k, _, v = part.partition("=")
                if not (v.startswith('"') and v.endswith('"')):
                    raise ValueError(f"malformed label on line {lineno}: "
                                     f"{part!r}")
                labels[k] = v[1:-1]
        value = float(m.group("value")) if m.group("value") != "+Inf" \
            else float("inf")
        base = m.group("name")
        for suffix in ("_bucket", "_sum", "_count"):
            if base.endswith(suffix) and base[:-len(suffix)] in types:
                base = base[:-len(suffix)]
                break
        out.setdefault(base, {"type": types.get(base, "untyped"),
                              "samples": []})
        out[base]["samples"].append((labels, value))
    return out
