"""Device-resident serving metrics: counters and fixed-bucket histograms
that live as a pytree threaded through the jitted ``serve_step``.

**The zero-sync design rule.**  The serving engines' steady-state contract
(PR 6, ``tests/test_serving_invariants.py``) forbids per-step host syncs
and recompiles — so the metrics plane is split in two:

- the **device plane** is a pytree of ``jnp`` arrays (scalar counters,
  per-bin histogram counts, per-slot accumulators) that the engines donate
  alongside the cache state and update with pure ``jnp`` ops inside the
  jitted step.  Updating a metric costs a few fused elementwise ops and
  never touches the host;
- the **host plane** is a :class:`MetricsCollector` that accumulates
  host-clock observations (admissions, request latencies — plain Python
  floats, no device round-trip) and *harvests* the device pytree only at
  existing sync points: run end, or an explicit periodic window
  (``window_steps``).  ``MetricsCollector.harvest`` is the ONLY place a
  metric value crosses to the host, and reprolint's ``obs-discipline``
  check statically proves it is unreachable from any jit region.

Metric *names* are registered once, module-import time, via
:func:`counter` / :func:`histogram`; duplicate names raise (and are also
caught statically by ``obs-discipline``).  Exports: Prometheus text
exposition (:meth:`MetricsCollector.to_prometheus`) and JSONL windows
(:meth:`MetricsCollector.to_jsonl`).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32

_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    name: str
    kind: str                       # "counter" | "histogram"
    help: str
    buckets: Tuple[float, ...] = ()  # histogram upper bounds (le), +Inf
    #                                  overflow bin is implicit


METRICS: Dict[str, MetricSpec] = {}


def _register(spec: MetricSpec) -> str:
    if not _NAME_RE.match(spec.name):
        raise ValueError(f"metric name {spec.name!r} is not a valid "
                         f"Prometheus metric name")
    prev = METRICS.get(spec.name)
    if prev is not None and prev != spec:
        raise ValueError(f"metric {spec.name!r} already registered with a "
                         f"different spec ({prev})")
    METRICS[spec.name] = spec
    return spec.name


def counter(name: str, help: str = "") -> str:
    """Register a monotonic counter; returns the name (use the returned
    binding so reprolint's ``obs-discipline`` can see every registration)."""
    return _register(MetricSpec(name, "counter", help))


def histogram(name: str, help: str = "",
              buckets: Tuple[float, ...] = (1, 2, 4, 8, 16, 32)) -> str:
    """Register a fixed-bucket histogram.  ``buckets`` are ascending upper
    bounds (Prometheus ``le``); an overflow (+Inf) bin is implicit."""
    b = tuple(float(x) for x in buckets)
    if list(b) != sorted(b) or len(set(b)) != len(b):
        raise ValueError(f"histogram {name!r} buckets must be strictly "
                         f"ascending, got {b}")
    return _register(MetricSpec(name, "histogram", help, b))


def spec(name: str) -> MetricSpec:
    try:
        return METRICS[name]
    except KeyError:
        raise ValueError(f"unknown metric {name!r}; registered: "
                         f"{', '.join(sorted(METRICS)) or '(none)'}") from None


# --------------------------------------------------------------------------
# The serving metric set (names shared by both engines; one registration
# site so obs-discipline's uniqueness rule has a single source of truth)
# --------------------------------------------------------------------------

SERVE_STEPS = counter(
    "serve_steps_total", "jitted serve_step dispatches (model steps)")
ACTIVE_SLOT_STEPS = counter(
    "active_slot_steps_total", "slot-steps carrying a live request")
BLOCKS_COMPUTED = counter(
    "blocks_computed_total", "transformer blocks executed")
BLOCKS_SKIPPED = counter(
    "blocks_skipped_total", "transformer blocks served from cache")
STEP_REUSES = counter(
    "cache_step_reuses_total", "whole-step cache reuses (active rows)")
ADMISSIONS = counter(
    "admissions_total", "requests admitted into a slot")
REQUESTS_FINISHED = counter(
    "requests_finished_total", "requests served to completion")
DECODE_TOKENS = counter(
    "decode_tokens_total", "AR tokens sampled across all slots")
PREFILLS = counter(
    "prefills_total", "AR prefill dispatches")

ACTIVE_SLOTS = histogram(
    "active_slots", "active slots per serve_step",
    buckets=(0, 1, 2, 4, 8, 16, 32, 64))
SKIP_FRACTION = histogram(
    "cache_skip_fraction", "per-step fraction of active rows reusing the "
    "whole-step cache", buckets=(0.0, 0.25, 0.5, 0.75, 0.9, 1.0))
REQUEST_LATENCY = histogram(
    "request_latency_steps", "queueing + service latency (engine steps)",
    buckets=(4, 8, 16, 32, 64, 128, 256, 512))
QUEUE_WAIT = histogram(
    "queue_wait_steps", "arrival -> admission wait (engine steps)",
    buckets=(0, 1, 2, 4, 8, 16, 32, 64))

SLOT_ACTIVE_STEPS = counter(
    "slot_active_steps", "per-slot steps carrying a live request "
    "(device-resident (S,) counter, sharded over the mesh data axis)")

# -- SLO control plane (serving/slo/): host-plane only — preemption,
# admission and deadline accounting happen in host bookkeeping between
# engine steps, so none of these join the device pytree (DEVICE_* below
# is unchanged and steady state stays transfer-free with the plane on).
# Per-class queue depth and the current shed level are unregistered
# gauges (``MetricsCollector.set_gauge``): ``queue_depth_class_<c>`` and
# ``shed_level``.

PREEMPTIONS = counter(
    "preemptions_total", "in-flight requests checkpointed out of a slot "
    "(device-side row snapshot) and requeued")
RESUMES = counter(
    "resumes_total", "preempted requests re-admitted from their snapshot")
REJECTIONS = counter(
    "admission_rejections_total", "requests refused admission "
    "(deadline-unattainable or expired)")
DEADLINE_MISSES = counter(
    "deadline_misses_total", "requests finished after their deadline_step")
QUEUE_DEPTH = histogram(
    "queue_depth_ready", "eligible requests waiting at each control-plane "
    "tick", buckets=(0, 1, 2, 4, 8, 16, 32, 64, 128))

# -- token-compression plane (core/token_reduce.py) ------------------------

TOKENS_MERGED = counter(
    "tokens_merged_total", "tokens folded into cluster centers by the "
    "serving-path merge stage, summed over active slot-steps")
TOKENS_KEPT = counter(
    "tokens_kept_total", "cluster centers the transformer actually ran on, "
    "summed over active slot-steps")
SLOT_MERGE_RATIO = counter(
    "slot_merge_ratio_sum", "per-slot cumulative kept/(kept+merged) ratio "
    "(device-resident (S,), sharded over the mesh data axis; divide by "
    "slot_active_steps for the mean merge ratio)")

# -- audit plane (obs/audit.py): shadow-compute quality metrics ------------

AUDIT_STEPS = counter(
    "audit_steps_total", "serve_steps that ran the shadow full-forward "
    "audit")
AUDIT_SLOT_STEPS = counter(
    "audit_slot_steps_total", "active slot-steps audited against the true "
    "forward")
BOUND_VIOLATIONS = counter(
    "bound_violations_total", "audited slot-steps whose measured relative "
    "error exceeded the policy's predicted bound")
AUDIT_REL_ERR = histogram(
    "audit_rel_err", "end-to-end relative eps error of the cached path vs "
    "the true forward, per audited slot-step",
    buckets=(1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0))
SLOT_AUDIT_ERR = counter(
    "slot_audit_err_sum", "per-slot cumulative audited relative error "
    "(device-resident (S,), sharded over the mesh data axis)")
SLOT_AUDIT_STEPS = counter(
    "slot_audit_steps", "per-slot audited slot-steps (device-resident "
    "(S,), sharded over the mesh data axis)")

# device-plane membership for the diffusion serve_step
DEVICE_COUNTERS = (SERVE_STEPS, ACTIVE_SLOT_STEPS, BLOCKS_COMPUTED,
                   BLOCKS_SKIPPED, STEP_REUSES)
DEVICE_HISTOGRAMS = (ACTIVE_SLOTS, SKIP_FRACTION)
DEVICE_PER_SLOT = (SLOT_ACTIVE_STEPS,)

# extra membership when the audit plane is on (audit_layers is set)
AUDIT_COUNTERS = (AUDIT_STEPS, AUDIT_SLOT_STEPS, BOUND_VIOLATIONS)
AUDIT_HISTOGRAMS = (AUDIT_REL_ERR,)
AUDIT_PER_SLOT = (SLOT_AUDIT_ERR, SLOT_AUDIT_STEPS)

# extra membership when the token-compression stage is on
TOKEN_COUNTERS = (TOKENS_MERGED, TOKENS_KEPT)
TOKEN_PER_SLOT = (SLOT_MERGE_RATIO,)


# --------------------------------------------------------------------------
# Device plane: pure-jnp init / update (jit- and donation-safe)
# --------------------------------------------------------------------------


def init_device_metrics(max_slots: int, *,
                        audit_layers: Optional[int] = None,
                        token_metrics: bool = False) -> Dict:
    """The serving device-metrics pytree: scalar counters, per-bin
    histogram counts (+ sum/count), and per-slot ``(S,)`` accumulators.
    Arrays only — the engines donate it buffer-for-buffer alongside the
    cache state, and the sharding walker places the per-slot group over
    the mesh ``data`` axis.

    ``audit_layers`` (= L+1 when the shadow-compute audit plane is on)
    additionally installs the audit counters / error histogram / per-slot
    accumulators plus an ``audit`` group carrying the per-layer error sum —
    the walker shards the per-slot audit keys over ``data`` like every
    other per-slot leaf and replicates the small ``audit`` group.

    ``token_metrics`` (the engine passes ``runner.reducer is not None``)
    installs the token-compression counters and per-slot merge-ratio
    accumulator — absent otherwise, so merge-off pytrees are unchanged."""
    counters = (DEVICE_COUNTERS
                + (AUDIT_COUNTERS if audit_layers is not None else ())
                + (TOKEN_COUNTERS if token_metrics else ()))
    hists = DEVICE_HISTOGRAMS + (AUDIT_HISTOGRAMS
                                 if audit_layers is not None else ())
    per_slot = (DEVICE_PER_SLOT
                + (AUDIT_PER_SLOT if audit_layers is not None else ())
                + (TOKEN_PER_SLOT if token_metrics else ()))
    m = {
        "counters": {n: jnp.zeros((), F32) for n in counters},
        "hist": {n: {"bucket": jnp.zeros((len(spec(n).buckets) + 1,), F32),
                     "sum": jnp.zeros((), F32),
                     "count": jnp.zeros((), F32)}
                 for n in hists},
        "per_slot": {n: jnp.zeros((max_slots,), F32) for n in per_slot},
    }
    if audit_layers is not None:
        m["audit"] = {"layer_err_sum": jnp.zeros((audit_layers,), F32),
                      "layer_rows": jnp.zeros((), F32)}
    return m


def inc(m: Dict, name: str, value) -> Dict:
    """Pure counter bump: returns a new metrics pytree with
    ``counters[name] += value`` (``value`` may be a traced scalar)."""
    counters = dict(m["counters"])
    counters[name] = counters[name] + value
    return {**m, "counters": counters}


def observe(m: Dict, name: str, value) -> Dict:
    """Pure histogram observation: bumps the bin ``value`` falls in (upper
    bounds from the registered spec; overflow bin last) plus sum/count."""
    bounds = jnp.asarray(spec(name).buckets, F32)
    idx = jnp.searchsorted(bounds, jnp.asarray(value, F32), side="left")
    hist = dict(m["hist"])
    h = dict(hist[name])
    h["bucket"] = h["bucket"].at[idx].add(1.0)
    h["sum"] = h["sum"] + value
    h["count"] = h["count"] + 1.0
    hist[name] = h
    return {**m, "hist": hist}


def observe_many(m: Dict, name: str, values, weights) -> Dict:
    """Pure vectorized histogram observation: bin each entry of ``values``
    (S,) and scatter-add its ``weights`` entry (weight 0 = not observed) —
    one fused update for a whole batch of observations.  The audit plane
    uses this to observe one error per active audited slot."""
    bounds = jnp.asarray(spec(name).buckets, F32)
    v = jnp.asarray(values, F32)
    w = jnp.asarray(weights, F32)
    idx = jnp.searchsorted(bounds, v, side="left")
    hist = dict(m["hist"])
    h = dict(hist[name])
    h["bucket"] = h["bucket"].at[idx].add(w)
    h["sum"] = h["sum"] + jnp.sum(v * w)
    h["count"] = h["count"] + jnp.sum(w)
    hist[name] = h
    return {**m, "hist": hist}


def slot_add(m: Dict, name: str, values) -> Dict:
    """Pure per-slot accumulation: ``per_slot[name] += values`` ((S,))."""
    per_slot = dict(m["per_slot"])
    per_slot[name] = per_slot[name] + values
    return {**m, "per_slot": per_slot}


def histogram_quantile(buckets: Tuple[float, ...], bucket_counts,
                       q: float) -> float:
    """Host-side Prometheus-style quantile estimate from per-bin counts
    (``len(buckets) + 1`` entries, overflow last): linear interpolation
    within the bucket the rank lands in, with observations in the overflow
    bin clamped to the last finite bound.  Returns 0.0 for an empty
    histogram."""
    counts = np.asarray(bucket_counts, np.float64)
    total = float(counts.sum())
    if total <= 0.0:
        return 0.0
    rank = q * total
    cum, lo = 0.0, 0.0
    for bound, cnt in zip(buckets, counts[:-1]):
        hi = float(bound)
        if cnt > 0 and cum + float(cnt) >= rank:
            return lo + (rank - cum) / float(cnt) * (hi - lo)
        cum += float(cnt)
        lo = hi
    return float(buckets[-1]) if buckets else 0.0


# --------------------------------------------------------------------------
# Host plane
# --------------------------------------------------------------------------


class MetricsCollector:
    """Host-side metrics aggregation + export.

    Host observations (:meth:`inc` / :meth:`observe`) are plain Python
    arithmetic — safe anywhere on the orchestration path.  Device metrics
    cross to the host ONLY through :meth:`harvest`, which the engines call
    at run end (and optionally every ``window_steps`` engine steps); each
    harvest appends one window snapshot for the JSONL trajectory, and the
    latest cumulative values feed the Prometheus exposition."""

    def __init__(self, labels: Optional[Dict[str, str]] = None, *,
                 window_steps: Optional[int] = None):
        if window_steps is not None and window_steps < 1:
            raise ValueError(f"window_steps must be >= 1, got "
                             f"{window_steps}")
        self.labels = dict(labels or {})
        self.window_steps = window_steps
        self._counters: Dict[str, float] = {}
        self._hist: Dict[str, Dict] = {}
        self._device: Dict = {}          # latest harvested device snapshot
        self._gauges: Dict[str, float] = {}
        self.windows: List[Dict] = []
        self._t0 = time.perf_counter()
        # audit plane comparison context + previous-harvest totals (the
        # windowed drift / burn-rate summaries are deltas between harvests)
        self._audit_bound: Optional[float] = None
        self._audit_baseline: Optional[np.ndarray] = None
        self._audit_fraction: Optional[float] = None
        self._prev_audit = {"rows": 0.0, "err": 0.0, "viol": 0.0}

    # -- host observations (no device involvement) ---------------------

    def inc(self, name: str, value: float = 1.0) -> None:
        if spec(name).kind != "counter":
            raise ValueError(f"metric {name!r} is not a counter")
        self._counters[name] = self._counters.get(name, 0.0) + float(value)

    def observe(self, name: str, value: float) -> None:
        s = spec(name)
        if s.kind != "histogram":
            raise ValueError(f"metric {name!r} is not a histogram")
        h = self._hist.setdefault(
            name, {"bucket": np.zeros(len(s.buckets) + 1, np.float64),
                   "sum": 0.0, "count": 0.0})
        idx = int(np.searchsorted(np.asarray(s.buckets), float(value),
                                  side="left"))
        h["bucket"][idx] += 1.0
        h["sum"] += float(value)
        h["count"] += 1.0

    def set_gauge(self, name: str, value: float) -> None:
        """Free-form gauge (clock readings, occupancy at harvest time);
        gauges need no registration — they are point-in-time readings, not
        accumulated series, so the uniqueness rule does not apply."""
        self._gauges[name] = float(value)

    def set_audit_context(self, *, bound: Optional[float] = None,
                          baseline=None,
                          fraction: Optional[float] = None) -> None:
        """Install the audit plane's comparison context: the policy's
        predicted per-step relative error bound (the burn-rate
        denominator), a calibration baseline (``errors_mean`` (L, T) from
        ``obs/calibration.py`` — the drift denominator), and the sampling
        fraction (recorded in windows).  None leaves a field untouched, so
        the engine (bound, fraction) and the launcher (baseline) each
        contribute their half."""
        if bound is not None:
            self._audit_bound = float(bound)
        if baseline is not None:
            base = np.asarray(baseline, np.float64)
            if base.ndim != 2:
                raise ValueError(f"audit baseline must be an (L, T) "
                                 f"errors_mean array, got shape "
                                 f"{base.shape}")
            self._audit_baseline = base
        if fraction is not None:
            self._audit_fraction = float(fraction)

    # -- the sync point -------------------------------------------------

    def harvest(self, device_metrics: Optional[Dict] = None, *,
                at_step: Optional[int] = None) -> Dict:
        """Materialize the device metrics pytree (THE device->host sync —
        engines call this only at run end / window close) and snapshot one
        window.  Values are cumulative since engine start; the window
        record carries the wall-clock and step-clock stamps so the JSONL
        series is a trajectory, not deltas."""
        if device_metrics:
            host = jax.tree.map(np.asarray, device_metrics)
            self._device = host
        audit = self._audit_window()    # sets the drift/burn gauges first
        window = {
            "at_step": at_step,
            "wall_s": time.perf_counter() - self._t0,
            "labels": dict(self.labels),
            "counters": self._merged_counters(),
            "histograms": {n: {"buckets": list(spec(n).buckets),
                               "bucket_counts": [float(v)
                                                 for v in h["bucket"]],
                               "sum": float(h["sum"]),
                               "count": float(h["count"])}
                           for n, h in self._all_hists().items()},
            "gauges": dict(self._gauges),
        }
        if self._device.get("per_slot"):
            window["per_slot"] = {
                n: [float(x) for x in v]
                for n, v in self._device["per_slot"].items()}
        if audit is not None:
            window["audit"] = audit
        self.windows.append(window)
        return window

    def _audit_window(self) -> Optional[Dict]:
        """Windowed audit summary (None when no audit metrics have been
        harvested): deltas of the audited totals since the previous harvest
        become error-mean / violation-rate gauges; with a bound installed,
        ``audit_burn_rate_window`` reads the fraction of the per-step error
        budget the window consumed; with a calibration baseline,
        ``audit_drift_ratio`` compares the measured per-layer cache error
        against the nocache run's natural inter-step deltas — the
        SmoothCache/SpectralCache health signal that says when a calibrated
        schedule is no longer safe."""
        dev = self._device
        counters = dev.get("counters", {})
        if AUDIT_SLOT_STEPS not in counters:
            return None
        per_slot = dev.get("per_slot", {})
        rows = float(counters.get(AUDIT_SLOT_STEPS, 0.0))
        err = float(np.sum(per_slot.get(SLOT_AUDIT_ERR, 0.0)))
        viol = float(counters.get(BOUND_VIOLATIONS, 0.0))
        d_rows = rows - self._prev_audit["rows"]
        d_err = err - self._prev_audit["err"]
        d_viol = viol - self._prev_audit["viol"]
        self._prev_audit = {"rows": rows, "err": err, "viol": viol}
        err_mean = d_err / d_rows if d_rows > 0 else 0.0
        viol_rate = d_viol / d_rows if d_rows > 0 else 0.0
        out = {
            "audited_rows_total": rows,
            "audited_rows_window": d_rows,
            "err_mean_window": err_mean,
            "violation_rate_window": viol_rate,
        }
        if self._audit_fraction is not None:
            out["audit_fraction"] = self._audit_fraction
        self.set_gauge("audit_err_mean_window", err_mean)
        self.set_gauge("audit_violation_rate_window", viol_rate)
        if self._audit_bound is not None:
            out["predicted_bound"] = self._audit_bound
            burn = (err_mean / self._audit_bound
                    if self._audit_bound > 0 else 0.0)
            out["burn_rate_window"] = burn
            self.set_gauge("audit_burn_rate_window", burn)
        grp = dev.get("audit")
        if grp is not None:
            sums = np.asarray(grp["layer_err_sum"], np.float64)
            n = float(grp["layer_rows"])
            layer_mean = sums / n if n > 0 else np.zeros_like(sums)
            out["layer_err_mean"] = [float(x) for x in layer_mean]
            if self._audit_baseline is not None and n > 0:
                # measured stack entry l+1 is block l's output; the
                # calibration rows are block outputs over the schedule
                # (its forced step-0 column of 1.0 excluded)
                base_cols = (self._audit_baseline[:, 1:]
                             if self._audit_baseline.shape[1] > 1
                             else self._audit_baseline)
                base = float(np.mean(base_cols))
                measured = float(np.mean(layer_mean[1:])
                                 if layer_mean.shape[0] > 1
                                 else np.mean(layer_mean))
                drift = measured / base if base > 0 else 0.0
                out["drift_ratio"] = drift
                self.set_gauge("audit_drift_ratio", drift)
        return out

    # -- merged views ---------------------------------------------------

    def _merged_counters(self) -> Dict[str, float]:
        out = {n: float(v) for n, v in self._counters.items()}
        for n, v in self._device.get("counters", {}).items():
            out[n] = out.get(n, 0.0) + float(v)
        return out

    def _all_hists(self) -> Dict[str, Dict]:
        out = {n: {"bucket": np.asarray(h["bucket"], np.float64),
                   "sum": float(h["sum"]), "count": float(h["count"])}
               for n, h in self._hist.items()}
        for n, h in self._device.get("hist", {}).items():
            cur = out.get(n)
            add = {"bucket": np.asarray(h["bucket"], np.float64),
                   "sum": float(h["sum"]), "count": float(h["count"])}
            if cur is None:
                out[n] = add
            else:
                out[n] = {"bucket": cur["bucket"] + add["bucket"],
                          "sum": cur["sum"] + add["sum"],
                          "count": cur["count"] + add["count"]}
        return out

    def totals(self) -> Dict[str, float]:
        """Cumulative counters (host + last-harvested device values)."""
        return self._merged_counters()

    def quantile(self, name: str, q: float) -> float:
        """Quantile estimate over a registered histogram's merged (host +
        harvested device) counts — e.g. ``quantile(AUDIT_REL_ERR, 0.95)``
        is the trajectory's ``audit_err_p95`` column.  0.0 when the
        histogram has no observations."""
        s = spec(name)
        if s.kind != "histogram":
            raise ValueError(f"metric {name!r} is not a histogram")
        h = self._all_hists().get(name)
        if h is None:
            return 0.0
        return histogram_quantile(s.buckets, h["bucket"], q)

    # -- exports --------------------------------------------------------

    def _label_str(self, extra: Optional[Dict[str, str]] = None) -> str:
        labels = {**self.labels, **(extra or {})}
        if not labels:
            return ""
        body = ",".join(f'{k}="{_escape_label(str(v))}"'
                        for k, v in sorted(labels.items()))
        return "{" + body + "}"

    def to_prometheus(self, prefix: str = "repro_") -> str:
        """Prometheus text exposition format (v0.0.4): counters as
        ``<prefix><name>``, histograms as cumulative ``_bucket{le=...}``
        series plus ``_sum``/``_count``, gauges as-is."""
        lines: List[str] = []
        ls = self._label_str()
        for n, v in sorted(self._merged_counters().items()):
            full = prefix + n
            if spec(n).help:
                lines.append(f"# HELP {full} {spec(n).help}")
            lines.append(f"# TYPE {full} counter")
            lines.append(f"{full}{ls} {_fmt(v)}")
        for n, h in sorted(self._all_hists().items()):
            full = prefix + n
            if spec(n).help:
                lines.append(f"# HELP {full} {spec(n).help}")
            lines.append(f"# TYPE {full} histogram")
            cum = 0.0
            for le, cnt in zip(spec(n).buckets, h["bucket"]):
                cum += float(cnt)
                lines.append(f"{full}_bucket"
                             f"{self._label_str({'le': _fmt(le)})} "
                             f"{_fmt(cum)}")
            cum += float(h["bucket"][-1])
            lines.append(f"{full}_bucket{self._label_str({'le': '+Inf'})} "
                         f"{_fmt(cum)}")
            lines.append(f"{full}_sum{ls} {_fmt(h['sum'])}")
            lines.append(f"{full}_count{ls} {_fmt(h['count'])}")
        for n, v in sorted(self._gauges.items()):
            full = prefix + n
            lines.append(f"# TYPE {full} gauge")
            lines.append(f"{full}{ls} {_fmt(v)}")
        return "\n".join(lines) + "\n"

    def to_jsonl(self) -> str:
        """One JSON object per harvested window (cumulative snapshots)."""
        return "\n".join(json.dumps(w) for w in self.windows) + (
            "\n" if self.windows else "")


def _escape_label(v: str) -> str:
    """Prometheus text-format label-value escaping (v0.0.4): backslash,
    double-quote, and newline."""
    return (v.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt(v: float) -> str:
    f = float(v)
    if math.isnan(f):
        return "NaN"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _parse_value(s: str) -> float:
    """A sample value in the exposition format: the canonical non-finite
    spellings plus ordinary floats."""
    if s == "NaN":
        return float("nan")
    if s == "+Inf":
        return float("inf")
    if s == "-Inf":
        return float("-inf")
    return float(s)


# --------------------------------------------------------------------------
# Exposition parser (round-trip validation; also used by tests)
# --------------------------------------------------------------------------

_METRIC_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*")

_ESCAPES = {"\\": "\\", '"': '"', "n": "\n"}


def _scan_labels(line: str, i: int, lineno: int
                 ) -> Tuple[Dict[str, str], int]:
    """Scan a ``{k="v",...}`` label block starting at ``line[i] == "{"``;
    returns ``(labels, index past the closing brace)``.  Quoted values may
    contain escaped backslashes / quotes / newlines and literal ``,`` or
    ``}`` — the character scan respects quoting, which a fixed ``[^}]*``
    regex cannot."""
    labels: Dict[str, str] = {}
    i += 1
    n = len(line)
    while i < n and line[i] != "}":
        j = line.find("=", i)
        if j < 0 or j + 1 >= n or line[j + 1] != '"':
            raise ValueError(f"malformed label on line {lineno}: "
                             f"{line[i:]!r}")
        key = line[i:j]
        i = j + 2
        buf: List[str] = []
        while i < n and line[i] != '"':
            ch = line[i]
            if ch == "\\":
                if i + 1 >= n:
                    raise ValueError(f"dangling escape on line {lineno}")
                buf.append(_ESCAPES.get(line[i + 1], line[i + 1]))
                i += 2
            else:
                buf.append(ch)
                i += 1
        if i >= n:
            raise ValueError(f"unterminated label value on line {lineno}")
        i += 1                        # closing quote
        labels[key] = "".join(buf)
        if i < n and line[i] == ",":
            i += 1
    if i >= n or line[i] != "}":
        raise ValueError(f"unterminated label block on line {lineno}")
    return labels, i + 1


def parse_prometheus(text: str) -> Dict[str, Dict]:
    """Parse Prometheus text exposition into
    ``{metric: {"type": ..., "samples": [(labels dict, value)]}}``.
    Raises ``ValueError`` on any malformed line — the tests use this to
    assert the export parses cleanly.  Handles escaped label values,
    ``+Inf``/``-Inf`` bucket bounds, and ``NaN`` gauge values (all of
    which the exporter can legitimately emit)."""
    out: Dict[str, Dict] = {}
    types: Dict[str, str] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(None, 3)
            types[name] = kind
            out.setdefault(name, {"type": kind, "samples": []})
            continue
        if line.startswith("#"):
            continue
        m = _METRIC_NAME_RE.match(line)
        if m is None:
            raise ValueError(f"malformed exposition line {lineno}: "
                             f"{line!r}")
        name = m.group(0)
        i = m.end()
        labels: Dict[str, str] = {}
        if i < len(line) and line[i] == "{":
            labels, i = _scan_labels(line, i, lineno)
        rest = line[i:].split()
        if len(rest) != 1:
            raise ValueError(f"malformed exposition line {lineno}: "
                             f"{line!r}")
        try:
            value = _parse_value(rest[0])
        except ValueError:
            raise ValueError(f"malformed value on line {lineno}: "
                             f"{rest[0]!r}") from None
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if base.endswith(suffix) and base[:-len(suffix)] in types:
                base = base[:-len(suffix)]
                break
        out.setdefault(base, {"type": types.get(base, "untyped"),
                              "samples": []})
        out[base]["samples"].append((labels, value))
    return out
