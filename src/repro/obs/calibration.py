"""Calibration recorder: per-layer per-step output deltas on a nocache run.

The input contract for error-bounded cache calibration (ROADMAP "error-
bounded auto-calibrated caching"): SmoothCache (arXiv 2411.10510) derives
its layer schedule from the relative L1/L2 change of each block's output
across adjacent denoising steps measured on an *uncached* run, and a
future spectralcache policy needs the same trajectory for its frequency-
band bounds.  This module records that trajectory once and saves it as an
``.npz`` artifact:

- ``rel_delta``  (T, L, B)  per-step per-layer per-row relative Frobenius
  change of block outputs (step 0 is 1.0 by convention: no previous);
- ``errors_mean``  (L, T)  batch-mean, exactly the matrix
  ``smooth_schedule_from_errors`` consumes;
- ``ts``  (T,)  the DDIM timestep of each recorded step;
- scalar metadata (num_steps, guidance_scale, layers, batch, policy).

Calibration is an **offline diagnostic mode**: it fetches one small
(B, L) matrix per step, which is fine off the serving path — the zero-
sync rule applies to serving steady state, not to this recorder (its
module is deliberately outside every jit scope reprolint tracks).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.diffusion import schedule as sch

F32 = jnp.float32
EPS = 1e-8

CALIBRATION_SCHEMA = ("rel_delta", "errors_mean", "ts")


def _block_outputs(impl, params, x_in, c):
    """(L, B, N, D) block outputs from one full forward: block l's output
    is the stacked scan carry ``inputs[l + 1]``, the last block's is the
    stack's final output."""
    x_out, inputs = impl._full_forward(params, x_in, c)
    return x_out, jnp.concatenate([inputs[1:], x_out[None]], axis=0)


def record_calibration(runner, params, *, batch: int,
                       labels: Optional[jax.Array] = None,
                       num_steps: int = 50, guidance_scale: float = 4.0,
                       num_train_steps: int = 1000, seed: int = 0) -> Dict:
    """Run ``num_steps`` of uncached DDIM sampling and record per-layer
    relative output deltas.  ``runner`` must be a nocache ``CachedDiT`` —
    a caching policy would corrupt the measurement (deltas of partially
    reused outputs are exactly what the schedule must NOT be fit to)."""
    if runner.policy != "nocache":
        raise ValueError(
            f"calibration must run uncached; got policy "
            f"{runner.policy!r} (build the runner with policy='nocache')")
    model, impl = runner.model, runner.impl
    cfg = model.cfg
    img, ch = cfg.dit.image_size, cfg.dit.in_channels
    if labels is None:
        labels = jnp.zeros((batch,), jnp.int32)
    use_cfg = guidance_scale != 1.0
    null_label = cfg.dit.num_classes

    sched = sch.linear_schedule(num_train_steps)
    ts = sch.ddim_timesteps(num_train_steps, num_steps)
    ts_prev = jnp.concatenate([ts[1:], jnp.array([-1], jnp.int32)])

    def step(x, prev_out, t, t_prev, lab):
        if use_cfg:
            x_m = jnp.concatenate([x, x], axis=0)
            t_m = jnp.concatenate([t, t], axis=0)
            lab_m = jnp.concatenate(
                [lab, jnp.full((batch,), null_label, jnp.int32)])
        else:
            x_m, t_m, lab_m = x, t, lab
        x_tok = model.tokens_in(params, x_m)
        c = model.conditioning(params, t_m, lab_m)
        x_out, outs = _block_outputs(impl, params, x_tok, c)
        # (L, B_eff): relative Frobenius change vs the previous step
        diff = jnp.sqrt(jnp.sum((outs - prev_out) ** 2, axis=(2, 3)))
        norm = jnp.sqrt(jnp.sum(prev_out ** 2, axis=(2, 3)))
        rel = diff / (norm + EPS)
        eps_hat = impl._eps(params, x_out, c)
        if use_cfg:
            eps_c, eps_u = jnp.split(eps_hat, 2, axis=0)
            eps_hat = eps_u + guidance_scale * (eps_c - eps_u)
        x_next = sch.ddim_step(sched, x, eps_hat, t, t_prev)
        return x_next, outs, rel

    step = jax.jit(step)

    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (batch, img, img, ch), F32)
    b_eff = 2 * batch if use_cfg else batch
    # shape from one abstract eval keeps this robust to token layout
    prev_shape = jax.eval_shape(
        lambda xx: _block_outputs(
            impl, params, model.tokens_in(params, xx),
            model.conditioning(
                params, jnp.zeros((b_eff,), jnp.int32),
                jnp.zeros((b_eff,), jnp.int32))),
        jnp.zeros((b_eff, img, img, ch), F32))[1]
    prev = jnp.zeros(prev_shape.shape, F32)

    rels = []
    for i in range(num_steps):
        t = jnp.full((batch,), ts[i], jnp.int32)
        t_prev = jnp.full((batch,), ts_prev[i], jnp.int32)
        x, prev, rel = step(x, prev, t, t_prev, labels)
        rels.append(np.asarray(rel))          # (L, B_eff) host fetch — OK
    rel_delta = np.stack(rels, axis=0)        # (T, L, B_eff)
    rel_delta[0, :, :] = 1.0                  # no previous step: force compute
    errors_mean = rel_delta.mean(axis=2).T    # (L, T)
    return {
        "rel_delta": rel_delta.astype(np.float32),
        "errors_mean": errors_mean.astype(np.float32),
        "ts": np.asarray(ts, np.int32)[:num_steps],
        "num_steps": np.int32(num_steps),
        "guidance_scale": np.float32(guidance_scale),
        "layers": np.int32(runner.L),
        "batch": np.int32(b_eff),
        "policy": np.str_(runner.policy),
    }


def save_calibration(path: str, result: Dict) -> None:
    for key in CALIBRATION_SCHEMA:
        if key not in result:
            raise ValueError(f"calibration result missing {key!r}")
    np.savez(path, **result)


def load_calibration(path: str) -> Dict:
    with np.load(path, allow_pickle=False) as f:
        out = {k: f[k] for k in f.files}
    for key in CALIBRATION_SCHEMA:
        if key not in out:
            raise ValueError(f"{path} is not a calibration artifact "
                             f"(missing {key!r})")
    L, T = int(out["layers"]), int(out["num_steps"])
    if out["errors_mean"].shape != (L, T):
        raise ValueError(
            f"errors_mean shape {out['errors_mean'].shape} != ({L}, {T})")
    return out
