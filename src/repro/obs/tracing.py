"""Per-request trace events exported as Chrome/Perfetto trace JSON.

Tracing is a **diagnostic mode** — unlike the metrics plane it is allowed
to keep host-side state per engine step (wall-clock stamps around each
dispatch) and, when per-slot cache attribution is requested, to snapshot
device accumulators.  Snapshots are *dispatched copies* (``jnp.add(v, 0)``)
of the donated buffers, fetched only at :meth:`TraceRecorder.finalize`;
the steady-state zero-transfer invariant is asserted with tracing OFF.

Event model (Chrome trace-event format, ``displayTimeUnit: ms``):

- ``ph="X"`` complete events: one per engine step ("serve_step", with
  active-slot count), plus per-request "request" spans (admit -> finish)
  on a per-slot track;
- ``ph="i"`` instant events: "admit" / "finish" markers carrying rid,
  label, step counts;
- per-step "denoise" slices on each slot's track, annotated post-hoc with
  the policy's gate/skip decision for that step (reconstructed by
  diffing consecutive accumulator snapshots at finalize);
- ``ph="C"`` counter tracks: the running block-cache ratio and, when the
  audit plane's per-slot accumulators ride the snapshots, the running
  mean audited error — rendered by Perfetto as counter plots alongside
  the slices.

Device-side phases (CFG split, eps, guidance blend, DDIM update) are
annotated with ``jax.named_scope`` in ``diffusion/sampler.py`` and
``jax.profiler.TraceAnnotation`` here around dispatch, so an XLA-level
profile (``jax.profiler.trace``) nests under the same names.
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

_US = 1e6  # trace timestamps are microseconds


class TraceRecorder:
    """Collects trace events on the host; ``finalize()`` resolves deferred
    device snapshots and ``write()`` emits Chrome/Perfetto JSON."""

    def __init__(self, *, pid: int = 0, capture_slots: bool = True):
        self.pid = pid
        self.capture_slots = capture_slots
        self.events: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()
        self._open_steps: List[Dict[str, Any]] = []
        self._snapshots: List[Dict[str, Any]] = []  # deferred device copies
        self._requests: Dict[int, Dict[str, Any]] = {}
        self._finalized = False

    # -- clocks ---------------------------------------------------------

    def _now(self) -> float:
        return (time.perf_counter() - self._t0) * _US

    # -- request lifecycle ---------------------------------------------

    def admit(self, rid: int, slot: int, *, label: int = -1,
              num_steps: int = -1, engine_step: int = -1) -> None:
        ts = self._now()
        self._requests[rid] = {"slot": slot, "t_admit": ts,
                               "admit_step": engine_step}
        self.events.append({
            "name": "admit", "ph": "i", "ts": ts, "pid": self.pid,
            "tid": slot + 1, "cat": "request", "s": "t",
            "args": {"rid": rid, "label": label, "num_steps": num_steps,
                     "engine_step": engine_step}})

    def finish(self, rid: int, *, engine_step: int = -1,
               stats: Optional[Dict[str, float]] = None) -> None:
        ts = self._now()
        info = self._requests.pop(rid, None)
        slot = info["slot"] if info else 0
        self.events.append({
            "name": "finish", "ph": "i", "ts": ts, "pid": self.pid,
            "tid": slot + 1, "cat": "request", "s": "t",
            "args": {"rid": rid, "engine_step": engine_step,
                     **(stats or {})}})
        if info is not None:
            self.events.append({
                "name": f"request rid={rid}", "ph": "X",
                "ts": info["t_admit"], "dur": ts - info["t_admit"],
                "pid": self.pid, "tid": slot + 1, "cat": "request",
                "args": {"rid": rid, "admit_step": info["admit_step"],
                         "finish_step": engine_step, **(stats or {})}})

    # -- engine steps ---------------------------------------------------

    def step_begin(self, engine_step: int, *, active: int = -1) -> "_Span":
        """Open a "serve_step" complete event; use as a context manager
        around the dispatch.  Also opens a ``jax.profiler``
        TraceAnnotation so XLA profiles align with the exported trace."""
        return _Span(self, engine_step, active)

    def snapshot_slots(self, engine_step: int, active_rows,
                       slot_stats: Dict[str, Any]) -> None:
        """Defer a per-slot accumulator snapshot.  ``slot_stats`` holds
        *donated* device buffers — we enqueue dispatched copies (cheap
        async device work, no sync) and fetch them all in finalize()."""
        if not self.capture_slots or self._finalized:
            return
        self._snapshots.append({
            "engine_step": engine_step,
            "ts": self._now(),
            "active": jnp.add(jnp.asarray(active_rows, jnp.float32), 0.0),
            "stats": {k: jnp.add(v, 0.0) for k, v in slot_stats.items()},
        })

    # -- finalize / export ---------------------------------------------

    def finalize(self) -> None:
        """Fetch deferred snapshots (the single sync) and turn consecutive
        diffs into per-slot per-step "denoise" slices annotated with the
        policy's skip/compute decision, plus Perfetto counter tracks
        (``ph="C"``) for the running cache ratio and — when the audit
        plane's accumulators ride the snapshots — the running mean
        audited error."""
        if self._finalized:
            return
        self._finalized = True
        snaps = [{"engine_step": s["engine_step"], "ts": s["ts"],
                  "active": np.asarray(s["active"]),
                  "stats": {k: np.asarray(v)
                            for k, v in s["stats"].items()}}
                 for s in self._snapshots]
        self._snapshots = []
        self._emit_counter_tracks(snaps)
        for prev, cur in zip(snaps, snaps[1:]):
            dur = max(cur["ts"] - prev["ts"], 1.0)
            d = {k: cur["stats"][k] - prev["stats"][k]
                 for k in cur["stats"]}
            active = prev["active"]
            n_slots = active.shape[0]
            for s in range(n_slots):
                if active[s] <= 0.0:
                    continue
                args = {"engine_step": prev["engine_step"]}
                for k, v in d.items():
                    args[k] = float(v[s])
                skipped = args.get("steps_reused", 0.0) > 0.0
                self.events.append({
                    "name": "denoise (cache reuse)" if skipped
                    else "denoise (compute)",
                    "ph": "X", "ts": prev["ts"], "dur": dur,
                    "pid": self.pid, "tid": s + 1, "cat": "denoise",
                    "args": args})

    def _emit_counter_tracks(self, snaps: List[Dict[str, Any]]) -> None:
        """Counter-track events from the cumulative per-slot snapshots:
        Perfetto renders each ``args`` key of a same-named ``ph="C"``
        event series as a stacked counter plot.  The snapshots are
        running totals, so each point is a cumulative ratio — the curves
        converge to the run's headline numbers."""
        for s in snaps:
            st = s["stats"]
            if "blocks_computed" in st:
                skipped = float(np.sum(st.get("blocks_skipped", 0.0)))
                computed = float(np.sum(st["blocks_computed"]))
                total = skipped + computed
                self.events.append({
                    "name": "cache ratio (running)", "ph": "C",
                    "ts": s["ts"], "pid": self.pid, "cat": "counter",
                    "args": {"cache_ratio":
                             skipped / total if total else 0.0}})
            if "audit_err_sum" in st and "audit_steps" in st:
                err = float(np.sum(st["audit_err_sum"]))
                steps = float(np.sum(st["audit_steps"]))
                self.events.append({
                    "name": "audit error (running mean)", "ph": "C",
                    "ts": s["ts"], "pid": self.pid, "cat": "counter",
                    "args": {"audit_err_mean":
                             err / steps if steps else 0.0}})

    def to_json(self) -> Dict[str, Any]:
        self.finalize()
        meta = [{"name": "process_name", "ph": "M", "pid": self.pid,
                 "args": {"name": "repro serving engine"}},
                {"name": "thread_name", "ph": "M", "pid": self.pid,
                 "tid": 0, "args": {"name": "engine loop"}}]
        tids = sorted({e.get("tid", 0) for e in self.events} - {0})
        for tid in tids:
            meta.append({"name": "thread_name", "ph": "M", "pid": self.pid,
                         "tid": tid, "args": {"name": f"slot {tid - 1}"}})
        return {"traceEvents": meta + self.events,
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


class _Span:
    def __init__(self, rec: TraceRecorder, engine_step: int, active: int):
        self.rec = rec
        self.engine_step = engine_step
        self.active = active
        self._ann = jax.profiler.TraceAnnotation(
            f"serve_step[{engine_step}]")

    def __enter__(self):
        self.t0 = self.rec._now()
        self._ann.__enter__()
        return self

    def __exit__(self, *exc):
        self._ann.__exit__(*exc)
        self.rec.events.append({
            "name": "serve_step", "ph": "X", "ts": self.t0,
            "dur": max(self.rec._now() - self.t0, 0.01),
            "pid": self.rec.pid, "tid": 0, "cat": "engine",
            "args": {"engine_step": self.engine_step,
                     "active_slots": self.active}})
        return False


def validate_trace(doc: Dict[str, Any]) -> None:
    """Raise ``ValueError`` unless ``doc`` is structurally valid
    Chrome/Perfetto trace JSON (used by tests and the CLI after write)."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace document must carry a traceEvents array")
    for i, ev in enumerate(doc["traceEvents"]):
        for key in ("name", "ph", "pid"):
            if key not in ev:
                raise ValueError(f"event {i} missing {key!r}: {ev}")
        ph = ev["ph"]
        if ph not in ("X", "i", "B", "E", "M", "C"):
            raise ValueError(f"event {i} has unknown phase {ph!r}")
        if ph == "X" and ("ts" not in ev or "dur" not in ev):
            raise ValueError(f"complete event {i} missing ts/dur: {ev}")
        if ph in ("i", "C") and "ts" not in ev:
            raise ValueError(f"event {i} ({ph!r}) missing ts: {ev}")
        if ph == "C" and not ev.get("args"):
            raise ValueError(f"counter event {i} has no series args: {ev}")
