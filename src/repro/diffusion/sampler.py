"""DDIM sampler with classifier-free guidance and cache-policy hooks.

The sampler drives a ``CachedDiT`` runner: every denoising step is one
runner.step call, so any cache policy (nocache / fastcache / baselines) slots
in unchanged.  CFG doubles the batch (cond + null label) — the cache state is
sized 2B and cond/uncond streams are cached independently, matching how the
paper runs DiT with guidance enabled (§5.2).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.runner import CachedDiT
from repro.diffusion import schedule as sch

F32 = jnp.float32


def sample(runner: CachedDiT, params, key: jax.Array, *, batch: int,
           labels: Optional[jax.Array] = None, num_steps: int = 50,
           guidance_scale: float = 4.0, num_train_steps: int = 1000,
           jit_step: bool = True, t_offsets: Optional[jax.Array] = None,
           x_init: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """Returns (samples (B, H, W, C) latents, cache stats state).

    The batch may be heterogeneous: per-sample ``labels`` (B,) and per-sample
    integer ``t_offsets`` (B,) that shift each sample's DDIM schedule — the
    per-sample cache gate keeps each sample's skip decisions independent, so
    mixing fast-converging and still-moving samples in one batch is safe.
    ``x_init`` overrides the initial noise (e.g. to match unbatched runs)."""
    cfg = runner.model.cfg
    img, ch = cfg.dit.image_size, cfg.dit.in_channels
    null_label = cfg.dit.num_classes
    if labels is None:
        labels = jnp.zeros((batch,), jnp.int32)
    use_cfg = guidance_scale != 1.0

    sched = sch.linear_schedule(num_train_steps)
    ts = sch.ddim_timesteps(num_train_steps, num_steps)
    ts_prev = jnp.concatenate([ts[1:], jnp.array([-1], jnp.int32)])

    x = (x_init.astype(F32) if x_init is not None
         else jax.random.normal(key, (batch, img, img, ch), F32))
    eff_batch = 2 * batch if use_cfg else batch
    state = runner.init_state(eff_batch)

    lab = jnp.concatenate([labels, jnp.full((batch,), null_label,
                                            jnp.int32)]) if use_cfg else labels
    off = (jnp.zeros((batch,), jnp.int32) if t_offsets is None
           else t_offsets.astype(jnp.int32))

    step_fn = runner.step
    if jit_step:
        step_fn = jax.jit(step_fn)

    for i in range(num_steps):
        t = jnp.clip(ts[i] + off, 0, num_train_steps - 1)
        t_prev = jnp.where(ts_prev[i] < 0, -1,
                           jnp.clip(ts_prev[i] + off, 0,
                                    num_train_steps - 1))
        if use_cfg:
            x_in = jnp.concatenate([x, x], axis=0)
            t_in = jnp.concatenate([t, t], axis=0)
        else:
            x_in, t_in = x, t
        eps, state = step_fn(params, state, x_in, t_in, lab)
        if use_cfg:
            eps_c, eps_u = jnp.split(eps, 2, axis=0)
            eps = eps_u + guidance_scale * (eps_c - eps_u)
        x = sch.ddim_step(sched, x, eps, t, t_prev)
    return x, state
