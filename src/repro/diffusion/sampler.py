"""DDIM sampler with classifier-free guidance and cache-policy hooks.

The sampler drives a ``CachedDiT`` runner: every denoising step is one
runner.step call, so any cache policy (nocache / fastcache / baselines) slots
in unchanged.  CFG doubles the batch (cond + null label) — the cache state is
sized 2B and cond/uncond streams are cached independently, matching how the
paper runs DiT with guidance enabled (§5.2).

``denoise_step`` is the reusable single-step core: one model evaluation +
guidance + DDIM update over per-sample ``(t, t_prev)`` vectors.  ``sample()``
loops it over a shared schedule; the continuous-batching engine
(``serving/diffusion_engine.py``) jits it with a heterogeneous per-slot
timestep vector so requests at different schedule positions share one batch.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.runner import CachedDiT
from repro.diffusion import schedule as sch

F32 = jnp.float32

GuidanceLike = Union[float, int, jax.Array]


def denoise_step(runner: CachedDiT, params, sched: sch.Schedule, state,
                 x: jax.Array, t: jax.Array, t_prev: jax.Array,
                 labels: jax.Array, *, guidance_scale: GuidanceLike = 4.0,
                 model_eval=None, return_eps: bool = False):
    """One denoising step x_t -> x_{t_prev} for a (possibly heterogeneous)
    batch: per-sample integer timesteps ``t``/``t_prev`` (B,), per-sample
    ``labels`` (B,).  With guidance the model batch is doubled internally
    (cond rows then uncond rows) and ``state`` must be sized 2B; the split
    matches ``CachedDiT.init_state(2 * B)``.  ``t_prev < 0`` marks the final
    step (x0 prediction).  Returns (x_next, new_state).

    ``guidance_scale`` may be a Python scalar (shared across the batch; the
    value 1.0 statically disables CFG, and ``state`` is sized B) or a (B,)
    array of per-sample scales.  The array form ALWAYS materializes the CFG
    rows — heterogeneity is expressed in the blend weights, with
    ``scale == 1.0`` rows selecting the conditional eps outright so they
    stay bitwise-equal to an unguided run of that sample.

    ``model_eval`` replaces ``runner.step`` (same signature) — the audit
    plane (obs/audit.py) uses it to route the identical CFG/guidance/DDIM
    plumbing through the uncached full forward.  ``return_eps`` additionally
    returns the post-guidance-blend eps (B, ...) as a third element, the
    quantity the audit plane compares cached-vs-true."""
    per_sample = not isinstance(guidance_scale, (int, float))
    use_cfg = per_sample or guidance_scale != 1.0
    b = x.shape[0]
    # named_scope phases show up in jax.profiler traces and nest under the
    # serving engine's per-dispatch TraceAnnotation (obs.tracing), so an
    # XLA-level profile attributes time to CFG doubling / model eval /
    # guidance blend / DDIM update by name
    if use_cfg:
        with jax.named_scope("cfg_double"):
            null_label = runner.model.cfg.dit.num_classes
            x_in = jnp.concatenate([x, x], axis=0)
            t_in = jnp.concatenate([t, t], axis=0)
            lab = jnp.concatenate([labels,
                                   jnp.full((b,), null_label, jnp.int32)])
    else:
        x_in, t_in, lab = x, t, labels
    eval_fn = runner.step if model_eval is None else model_eval
    with jax.named_scope("model_eval"):
        eps, state = eval_fn(params, state, x_in, t_in, lab)
    if use_cfg:
        with jax.named_scope("cfg_blend"):
            eps_c, eps_u = jnp.split(eps, 2, axis=0)
            if per_sample:
                g = jnp.broadcast_to(
                    jnp.asarray(guidance_scale, F32), (b,)
                ).reshape((b,) + (1,) * (x.ndim - 1))
                # scale==1.0 must reduce to eps_c EXACTLY: the algebraic
                # form eps_u + 1.0*(eps_c - eps_u) re-associates in float32
                # and would break bitwise parity with an unguided solo run
                eps = jnp.where(g == 1.0, eps_c,
                                eps_u + g * (eps_c - eps_u))
            else:
                eps = eps_u + guidance_scale * (eps_c - eps_u)
    with jax.named_scope("ddim_update"):
        x = sch.ddim_step(sched, x, eps, t, t_prev)
    if return_eps:
        return x, state, eps
    return x, state


def sample(runner: CachedDiT, params, key: jax.Array, *, batch: int,
           labels: Optional[jax.Array] = None, num_steps: int = 50,
           guidance_scale: GuidanceLike = 4.0, num_train_steps: int = 1000,
           jit_step: bool = True, t_offsets: Optional[jax.Array] = None,
           x_init: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """Returns (samples (B, H, W, C) latents, cache stats state).

    The batch may be heterogeneous: per-sample ``labels`` (B,) and per-sample
    integer ``t_offsets`` (B,) that shift each sample's DDIM schedule — the
    per-sample cache gate keeps each sample's skip decisions independent, so
    mixing fast-converging and still-moving samples in one batch is safe.
    ``x_init`` overrides the initial noise (e.g. to match unbatched runs)."""
    cfg = runner.model.cfg
    img, ch = cfg.dit.image_size, cfg.dit.in_channels
    if labels is None:
        labels = jnp.zeros((batch,), jnp.int32)
    # per-sample guidance vectors always run the doubled CFG batch (see
    # denoise_step); scalar 1.0 statically disables CFG
    use_cfg = (not isinstance(guidance_scale, (int, float))
               or guidance_scale != 1.0)

    sched = sch.linear_schedule(num_train_steps)
    ts = sch.ddim_timesteps(num_train_steps, num_steps)
    ts_prev = jnp.concatenate([ts[1:], jnp.array([-1], jnp.int32)])

    x = (x_init.astype(F32) if x_init is not None
         else jax.random.normal(key, (batch, img, img, ch), F32))
    eff_batch = 2 * batch if use_cfg else batch
    state = runner.init_state(eff_batch)

    off = (jnp.zeros((batch,), jnp.int32) if t_offsets is None
           else t_offsets.astype(jnp.int32))

    step_fn = functools.partial(denoise_step, runner,
                                guidance_scale=guidance_scale)
    if jit_step:
        step_fn = jax.jit(step_fn)

    for i in range(num_steps):
        t = jnp.clip(ts[i] + off, 0, num_train_steps - 1)
        t_prev = jnp.where(ts_prev[i] < 0, -1,
                           jnp.clip(ts_prev[i] + off, 0,
                                    num_train_steps - 1))
        x, state = step_fn(params, sched, state, x, t, t_prev, labels)
    return x, state
