"""DDPM noise schedule + DDIM step math (the paper's inference setting:
50 DDIM steps, classifier-free guidance)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class Schedule(NamedTuple):
    betas: jax.Array          # (T,)
    alphas_cum: jax.Array     # (T,) cumulative prod of (1 - beta)


def linear_schedule(num_train_steps: int = 1000, beta_start: float = 1e-4,
                    beta_end: float = 0.02) -> Schedule:
    betas = jnp.linspace(beta_start, beta_end, num_train_steps, dtype=F32)
    return Schedule(betas=betas, alphas_cum=jnp.cumprod(1.0 - betas))


def add_noise(sched: Schedule, x0: jax.Array, noise: jax.Array,
              t: jax.Array) -> jax.Array:
    """q(x_t | x_0): (B,...) with per-sample integer timesteps t."""
    ac = sched.alphas_cum[t]
    shape = (-1,) + (1,) * (x0.ndim - 1)
    return (jnp.sqrt(ac).reshape(shape) * x0.astype(F32)
            + jnp.sqrt(1.0 - ac).reshape(shape) * noise.astype(F32))


def ddim_timesteps(num_train_steps: int, num_inference_steps: int
                   ) -> jax.Array:
    """Descending evenly-spaced timesteps (50-step default)."""
    step = num_train_steps // num_inference_steps
    return jnp.arange(num_train_steps - 1, -1, -step, dtype=jnp.int32)


def ddim_step(sched: Schedule, x_t: jax.Array, eps: jax.Array, t: jax.Array,
              t_prev: jax.Array, eta: float = 0.0) -> jax.Array:
    """Deterministic DDIM update x_t -> x_{t_prev} (eta=0).  ``t``/``t_prev``
    may be scalars (shared schedule) or (B,) per-sample timesteps."""
    ac_t = sched.alphas_cum[t]
    ac_p = jnp.where(t_prev >= 0, sched.alphas_cum[jnp.maximum(t_prev, 0)],
                     jnp.ones_like(ac_t))
    if jnp.ndim(ac_t):                       # (B,) -> broadcast over x_t dims
        shape = (-1,) + (1,) * (x_t.ndim - 1)
        ac_t = ac_t.reshape(shape)
        ac_p = ac_p.reshape(shape)
    x_t = x_t.astype(F32)
    eps = eps.astype(F32)
    x0 = (x_t - jnp.sqrt(1.0 - ac_t) * eps) / jnp.sqrt(ac_t)
    return jnp.sqrt(ac_p) * x0 + jnp.sqrt(1.0 - ac_p) * eps
