from repro.diffusion.sampler import denoise_step, sample  # noqa: F401
from repro.diffusion.schedule import (add_noise, ddim_step,  # noqa: F401
                                      ddim_timesteps, linear_schedule)
