"""Pytree checkpoints: .npz arrays + msgpack tree spec. No orbax dependency;
roundtrip-safe for arbitrary nested dict/tuple pytrees including optimizer
NamedTuples (serialized structurally)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# jax.tree.flatten_with_path only exists from jax 0.4.38 on; the pinned
# 0.4.37 ships it under jax.tree_util.
_flatten_with_path = getattr(jax.tree, "flatten_with_path", None) \
    or jax.tree_util.tree_flatten_with_path


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    paths_leaves, treedef = _flatten_with_path(tree)
    arrays = {}
    keys = []
    for i, (path, leaf) in enumerate(paths_leaves):
        key = f"leaf_{i}"
        arrays[key] = np.asarray(leaf)
        keys.append(jax.tree_util.keystr(path))
    return arrays, (treedef, keys)


def save(path: str, tree, metadata: Dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays, (treedef, keys) = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    meta = {
        "keys": keys,
        "treedef": str(treedef),
        "metadata": metadata or {},
        "num_leaves": len(keys),
    }
    with open(_meta_path(path), "w") as f:
        json.dump(meta, f)


def _meta_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".meta.json"


def load(path: str, like) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_like, treedef = jax.tree.flatten(like)
    n = len(leaves_like)
    if len(npz.files) != n:
        raise ValueError(f"checkpoint {path!r} holds {len(npz.files)} "
                         f"leaves; the target pytree expects {n}")
    leaves = []
    for i, ref in enumerate(leaves_like):
        arr = npz[f"leaf_{i}"]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"checkpoint {path!r} leaf {i}: stored shape "
                             f"{tuple(arr.shape)} != expected "
                             f"{tuple(ref.shape)}")
        leaves.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, leaves)


def load_metadata(path: str) -> Dict:
    with open(_meta_path(path)) as f:
        return json.load(f)
