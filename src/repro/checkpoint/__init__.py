from repro.checkpoint.io import load, load_metadata, save  # noqa: F401
