"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304 [hf:stabilityai/stablelm-2-1_6b family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50_304,
    rope_theta=10_000.0,
    norm_eps=1e-5,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="stablelm-3b-smoke", num_layers=2, d_model=256,
                          num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=512)
