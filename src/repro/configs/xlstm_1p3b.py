"""xlstm-1.3b [ssm] — 48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304.
sLSTM + mLSTM blocks [arXiv:2405.04517]. One sLSTM block every 8 layers
(xLSTM[7:1]-style); mLSTM uses a 2x up-projection with matrix memory, so there
is no separate FFN (d_ff=0)."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    rope_kind="none",
    block_pattern=("mlstm",) * 7 + ("slstm",),
    ssm=SSMConfig(slstm_every=8, proj_factor=2.0, conv_kernel=4, chunk_size=64),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="xlstm-1.3b-smoke", num_layers=2, d_model=256, num_heads=2,
        num_kv_heads=2, vocab_size=512, block_pattern=("mlstm", "slstm"),
        ssm=SSMConfig(slstm_every=2, chunk_size=16),
    )
