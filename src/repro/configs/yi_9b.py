"""yi-9b [dense] — 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-architecture GQA [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11_008,
    vocab_size=64_000,
    rope_theta=10_000.0,
    norm_eps=1e-5,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="yi-9b-smoke", num_layers=2, d_model=256,
                          num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512)
