"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
M-RoPE, dynamic resolution [arXiv:2409.12191].

The ViT vision encoder + projector frontend is a STUB per the brief:
``input_specs`` provides ``vision_embeds`` (B, vision_tokens, d_model) already
projected into the LM embedding space, scattered into the token stream at the
positions flagged by ``vision_mask``. The language backbone (this config) is
fully implemented, including 3-axis M-RoPE over (t, h, w) position ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    vision_tokens=256,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="qwen2-vl-2b-smoke", num_layers=2, d_model=256,
                          num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
                          mrope_sections=(8, 12, 12), vision_tokens=16)
