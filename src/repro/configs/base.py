"""Config dataclasses for models, shapes, and runtime.

Every assigned architecture gets one module in this package defining
``CONFIG: ModelConfig`` with the exact published numbers (source cited in the
module docstring) plus ``reduced()`` returning the smoke-test variant.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0      # DeepSeek/Kimi-style always-on experts
    dense_ff_parallel: int = 0       # Arctic-style dense FFN residual branch
    capacity_factor: float = 1.25
    min_capacity: int = 4
    router_aux_weight: float = 0.01
    moe_layer_period: int = 1        # MoE every k-th FFN (Jamba: 2)


@dataclass(frozen=True)
class SSMConfig:
    # xLSTM
    slstm_every: int = 8             # every k-th block is sLSTM (rest mLSTM)
    proj_factor: float = 2.0         # mLSTM up-projection factor
    conv_kernel: int = 4
    chunk_size: int = 64             # chunkwise-parallel mLSTM chunk
    # Mamba (Jamba mixers)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                 # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class DiTConfig:
    patch_size: int = 2
    in_channels: int = 4             # SD VAE latent channels
    num_classes: int = 1000
    learn_sigma: bool = False
    image_size: int = 32             # latent spatial size (256px/8)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio | dit
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    rope_kind: str = "default"       # default | mrope | none
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # per-axis half-dims (t,h,w)
    is_encoder: bool = False         # bidirectional attention, no decode step
    tie_embeddings: bool = False
    sliding_window: int = 0          # 0 = full attention; >0 enables SWA variant
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    dit: Optional[DiTConfig] = None
    # Hybrid layout: pattern of one period, tiled over num_layers.
    # entries: "attn" | "mamba" | "mlstm" | "slstm"
    block_pattern: Tuple[str, ...] = ()
    # Audio/VLM frontends are stubbed: inputs are precomputed embeddings.
    frontend_dim: int = 0            # e.g. hubert conv-feature dim (512)
    vision_tokens: int = 0           # VLM: number of image-patch embeddings
    dtype: str = "bfloat16"
    # Training
    optimizer: str = "adamw"         # adamw | adafactor
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixer kind, length == num_layers."""
        if not self.block_pattern:
            base = "attn"
            return tuple(base for _ in range(self.num_layers))
        p = self.block_pattern
        reps = -(-self.num_layers // len(p))
        return (p * reps)[: self.num_layers]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


@dataclass(frozen=True)
class FastCacheConfig:
    """Paper defaults (§5.2 / Appendix E.1)."""
    enabled: bool = True
    # STR — spatial token reduction
    motion_threshold: float = 0.05   # tau_s / tau_m
    motion_capacity: float = 0.5     # static top-C fraction (TPU adaptation)
    # SC — statistical caching
    alpha: float = 0.05              # significance level of the chi^2 gate
    # MB — motion-aware blending
    blend_gamma: float = 0.5
    background_momentum: float = 0.7
    # CTM — token merging
    merge_enabled: bool = False
    merge_window: int = 16
    merge_ratio: float = 0.5         # kept-token fraction per window
    knn_k: int = 5
    merge_lambda: float = 1.0        # lambda in Eq. 12
    # module toggles for ablations
    use_str: bool = True
    use_sc: bool = True
    use_mb: bool = True
    # gating granularity: "per_sample" gates each batch element independently
    # (one moving sample no longer forces recompute for the whole batch);
    # "global" reduces the statistic over the batch (the pre-refactor
    # whole-batch behaviour, kept for ablation/benchmark baselines)
    gate_mode: str = "per_sample"
    # route the saliency-delta -> chi^2 -> gate -> linear-blend hot path
    # through the fused Pallas kernel (kernels/fused_gate.py).  ``None``
    # auto-selects by backend: the compiled Mosaic kernel on TPU, the
    # pure-JAX reference path (kernels/ref.fused_gate — the kernel's ground
    # truth) on CPU/GPU.  Set True/False to override the auto-selection.
    use_fused_gate: Optional[bool] = None
