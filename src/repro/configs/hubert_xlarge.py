"""hubert-xlarge [audio] — encoder-only transformer backbone of HuBERT X-Large
(same architecture family as wav2vec 2.0) [arXiv:2106.07447].

48L, d_model=1280, 16 heads (kv=16, i.e. MHA), d_ff=5120, vocab=504 (k-means
target codebook). The mel-spectrogram + conv feature extractor frontend is a
STUB per the brief: ``input_specs`` provides precomputed frame embeddings of
shape (B, S, frontend_dim=512).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    rope_kind="none",
    is_encoder=True,
    frontend_dim=512,
    norm_eps=1e-5,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="hubert-xlarge-smoke", num_layers=2, d_model=256,
                          num_heads=4, num_kv_heads=4, d_ff=512, frontend_dim=64)
