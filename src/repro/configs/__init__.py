"""Architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``.

``--arch`` ids use dashes (as assigned); module names use underscores.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict

from repro.configs.base import (DiTConfig, FastCacheConfig, InputShape,
                                ModelConfig, MoEConfig, SSMConfig)
from repro.configs.shapes import SHAPES

_MODULES: Dict[str, str] = {
    "hubert-xlarge": "hubert_xlarge",
    "qwen3-0.6b": "qwen3_0p6b",
    "stablelm-3b": "stablelm_3b",
    "arctic-480b": "arctic_480b",
    "xlstm-1.3b": "xlstm_1p3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "qwen3-14b": "qwen3_14b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "jamba-v0.1-52b": "jamba_52b",
    "yi-9b": "yi_9b",
}

ASSIGNED_ARCHS = tuple(_MODULES)

_DIT_IDS = ("dit-s2", "dit-b2", "dit-l2", "dit-xl2")
ALL_ARCHS = ASSIGNED_ARCHS + _DIT_IDS


def get_config(arch: str) -> ModelConfig:
    if arch in _DIT_IDS:
        mod = importlib.import_module("repro.configs.dit")
        return getattr(mod, arch.replace("-", "_").upper())
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALL_ARCHS)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}").CONFIG


def get_reduced(arch: str) -> ModelConfig:
    if arch in _DIT_IDS:
        return importlib.import_module("repro.configs.dit").reduced()
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}").reduced()


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "DiTConfig", "InputShape",
    "FastCacheConfig", "SHAPES", "ASSIGNED_ARCHS", "ALL_ARCHS",
    "get_config", "get_reduced",
]
