"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba:attn 7:1 interleave, MoE 16 experts top-2 on every other
FFN [arXiv:2403.19887].

Layer period of 8: [mamba x3, attn, mamba x4]; FFN follows every mixer, MoE on
odd layer indices (moe_layer_period=2). long_500k runs natively: Mamba state is
O(1) in sequence length and the 4 attention layers shard their KV cache over
the ``data`` mesh axis on the sequence dimension.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=65_536,
    rope_kind="none",               # Jamba uses no positional embedding
    block_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14_336,
                  moe_layer_period=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    optimizer="adafactor",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="jamba-52b-smoke", num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=2, d_ff=512, vocab_size=512,
        block_pattern=("mamba", "attn", "mamba", "mamba"),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=512,
                      moe_layer_period=2),
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
    )
