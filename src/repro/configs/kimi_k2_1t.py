"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) per-expert
d_ff=2048, vocab=163840, MoE 384 experts top-8 + 1 shared expert
(DeepSeek-V3-style routing) [arXiv:2501.kimi2 paper table].

~1.04T total params, ~32B active. Optimizer: adafactor (factored second
moment) — AdamW f32 moments (8 TB) cannot fit 256x16GB HBM.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163_840,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1),
    optimizer="adafactor",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="kimi-k2-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=256, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256,
                      num_shared_experts=1),
    )
