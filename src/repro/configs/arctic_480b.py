"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 WITH a parallel dense FFN residual branch
(Arctic's dense-MoE hybrid) [hf:Snowflake/snowflake-arctic-base].

Optimizer: adafactor — factored second moment so ~480B params of optimizer
state fit the 256/512-chip HBM budget (see DESIGN.md §6).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32_000,
    moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864,
                  dense_ff_parallel=4864),
    optimizer="adafactor",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="arctic-480b-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=512,
                      dense_ff_parallel=512),
    )
