"""The paper's own backbones: DiT-S/2, DiT-B/2, DiT-L/2, DiT-XL/2
(Peebles & Xie 2023; FastCache paper Table 4).

| Model    | Layers | Hidden | Heads | Params (M) |
| DiT-S/2  |   6*   |  384   |   6   |  49        |  (*paper Table 4 lists 6)
| DiT-B/2  |  12    |  768   |  12   | 126        |
| DiT-L/2  |  24    | 1024   |  16   | 284        |
| DiT-XL/2 |  28    | 1152   |  18   | 354        |

DiT blocks: full bidirectional attention over latent patch tokens, adaLN-zero
conditioning on (timestep, class), MLP ratio 4. vocab_size is unused (no token
embedding; patchified VAE latents in, noise prediction out).
"""
from repro.configs.base import DiTConfig, ModelConfig


def _dit(name: str, layers: int, d: int, heads: int) -> ModelConfig:
    return ModelConfig(
        name=name,
        family="dit",
        num_layers=layers,
        d_model=d,
        num_heads=heads,
        num_kv_heads=heads,
        d_ff=4 * d,
        vocab_size=0,
        rope_kind="none",
        is_encoder=True,
        dit=DiTConfig(patch_size=2, in_channels=4, num_classes=1000,
                      image_size=32),
    )


DIT_S2 = _dit("dit-s2", 6, 384, 6)
DIT_B2 = _dit("dit-b2", 12, 768, 12)
DIT_L2 = _dit("dit-l2", 24, 1024, 16)
DIT_XL2 = _dit("dit-xl2", 28, 1152, 18)

CONFIG = DIT_XL2


def reduced(name: str = "dit-smoke") -> ModelConfig:
    return _dit(name, 2, 128, 4).replace(
        dit=DiTConfig(patch_size=2, in_channels=4, num_classes=10, image_size=8))
