"""Train-step builder + host training loop.

``make_train_step(model, opt)`` returns the pure (params, opt_state, batch)
-> (params, opt_state, metrics) function that the launcher jits with explicit
in/out shardings — the same function the multi-pod dry-run lowers.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.training.optimizer import clip_by_global_norm

F32 = jnp.float32


def make_train_step(model, opt, lr_fn: Callable, max_grad_norm: float = 1.0):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn,
                                                    has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = lr_fn(opt_state.step)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        out = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out.update({k: v for k, v in metrics.items()
                    if jnp.ndim(v) == 0})
        return params, opt_state, out

    return train_step


def train(model, params, opt, lr_fn, data_iter, *, steps: int,
          log_every: int = 10, max_grad_norm: float = 1.0,
          callback: Optional[Callable[[int, Dict], None]] = None):
    """Host loop for CPU-scale runs (examples / tests)."""
    step_fn = jax.jit(make_train_step(model, opt, lr_fn, max_grad_norm))
    opt_state = opt.init(params)
    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["elapsed_s"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(i, m)
    return params, opt_state, history
