from repro.training.loop import make_train_step, train  # noqa: F401
from repro.training.optimizer import (AdamW, Adafactor,  # noqa: F401
                                      cosine_schedule, make_optimizer)
