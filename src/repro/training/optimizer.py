"""Optimizers from scratch: AdamW and Adafactor (factored second moment).

Adafactor is mandatory for the trillion-parameter MoE configs — AdamW's f32
moments for kimi-k2 (8 TB) cannot fit 256 x 16 GB HBM, while Adafactor's
row/col factored statistics are ~D+F per (D,F) matrix (DESIGN.md §6).
Both are pure pytree transforms: ``init(params) -> state``,
``update(grads, state, params, lr) -> (new_params, new_state)``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


# --------------------------------------------------------------------------
# LR schedule
# --------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, F32)
        warm = base_lr * (step + 1.0) / max(1, warmup)
        prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype),
                        grads), norm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object


class AdamW:
    def __init__(self, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
        self.b1, self.b2, self.eps, self.wd = b1, b2, eps, weight_decay

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, F32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params, lr):
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(F32)
        c2 = 1.0 - b2 ** step.astype(F32)

        def upd(g, m, v, p):
            g = g.astype(F32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            decay = self.wd if p.ndim >= 2 else 0.0
            new_p = p.astype(F32) - lr * (u + decay * p.astype(F32))
            return new_p.astype(p.dtype), m, v

        flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_p = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


# --------------------------------------------------------------------------
# Adafactor
# --------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jax.Array
    vr: object     # row statistics (or full v for <2D leaves)
    vc: object     # col statistics (or None sentinel)


class Adafactor:
    """Factored second-moment RMS optimizer (Shazeer & Stern 2018), no
    momentum, update-clipping d=1.0."""

    def __init__(self, eps: float = 1e-30, clip: float = 1.0,
                 decay_pow: float = 0.8, weight_decay: float = 0.0):
        self.eps, self.clip, self.decay_pow = eps, clip, decay_pow
        self.wd = weight_decay

    def init(self, params) -> AdafactorState:
        def vr(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], F32)
            return jnp.zeros(p.shape, F32)

        def vc(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)
            return jnp.zeros((1,), F32)

        return AdafactorState(step=jnp.zeros((), jnp.int32),
                              vr=jax.tree.map(vr, params),
                              vc=jax.tree.map(vc, params))

    def update(self, grads, state: AdafactorState, params, lr):
        step = state.step + 1
        beta = 1.0 - (step.astype(F32) + 1.0) ** -self.decay_pow

        def upd(g, vr, vc, p):
            g = g.astype(F32)
            g2 = g * g + self.eps
            if p.ndim >= 2:
                vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    self.eps)
                vhat = (vr[..., :, None] * vc[..., None, :]
                        / denom[..., None])
                u = g / jnp.sqrt(vhat + self.eps)
            else:
                vr = beta * vr + (1 - beta) * g2
                u = g / jnp.sqrt(vr + self.eps)
            # update clipping on RMS
            rms = jnp.sqrt(jnp.mean(u * u) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip)
            decay = self.wd if p.ndim >= 2 else 0.0
            new_p = p.astype(F32) - lr * u - lr * decay * p.astype(F32)
            return new_p.astype(p.dtype), vr, vc

        flat = jax.tree.map(upd, grads, state.vr, state.vc, params)
        pick = lambda i: jax.tree.map(lambda t: t[i], flat,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), AdafactorState(step=step, vr=pick(1), vc=pick(2))


def make_optimizer(name: str, **kw):
    if name == "adamw":
        return AdamW(**kw)
    if name == "adafactor":
        return Adafactor(**kw)
    raise KeyError(name)
