"""xLSTM blocks: chunkwise-parallel mLSTM (matrix memory, exponential gating)
and recurrent sLSTM (scalar memory, per-head recurrence) [arXiv:2405.04517].

TPU adaptation: the mLSTM runs in its chunkwise-parallel form — intra-chunk
terms are dense (c x c) matmuls on the MXU, inter-chunk state is carried by a
short ``lax.scan`` (S/c steps). The recurrent single-step form is used for
decode and serves as the test oracle (tests/test_ssm.py checks chunkwise ==
recurrent). All state math in f32 with running-max stabilization.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, flags
from repro.models.params import ParamDef

F32 = jnp.float32


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def mlstm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    inner = int(cfg.ssm.proj_factor * d)
    h = cfg.num_heads
    k = cfg.ssm.conv_kernel
    return {
        "norm": ParamDef((d,), ("embed",), "ones", dtype="float32"),
        "w_up": ParamDef((d, 2 * inner), ("embed", "inner"), "fan_in"),
        "conv_w": ParamDef((k, inner), (None, "inner"), "fan_in"),
        "wq": ParamDef((inner, inner), ("inner", None), "fan_in"),
        "wk": ParamDef((inner, inner), ("inner", None), "fan_in"),
        "wv": ParamDef((inner, inner), ("inner", None), "fan_in"),
        "w_igate": ParamDef((inner, h), ("inner", None), "fan_in", dtype="float32"),
        "b_igate": ParamDef((h,), (None,), "zeros", dtype="float32"),
        "w_fgate": ParamDef((inner, h), ("inner", None), "fan_in", dtype="float32"),
        "b_fgate": ParamDef((h,), (None,), "ones", dtype="float32"),
        "out_norm": ParamDef((inner,), ("inner",), "ones", dtype="float32"),
        "w_down": ParamDef((inner, d), ("inner", "embed"), "fan_in",
                           scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }


def mlstm_state_defs(cfg: ModelConfig, batch: int) -> Dict[str, ParamDef]:
    inner = int(cfg.ssm.proj_factor * cfg.d_model)
    h = cfg.num_heads
    dh = inner // h
    k = cfg.ssm.conv_kernel
    ab = ("act_batch",)
    return {
        "C": ParamDef((batch, h, dh, dh), ab + (None, "act_inner", None),
                      "zeros", dtype="float32"),
        "n": ParamDef((batch, h, dh), ab + (None, "act_inner"), "zeros",
                      dtype="float32"),
        "m": ParamDef((batch, h), ab + (None,), "zeros", dtype="float32"),
        "conv": ParamDef((batch, k - 1, inner), ab + (None, "act_inner"),
                         "zeros", dtype="float32"),
    }


def _mlstm_chunk(q, k, v, li, lf, state):
    """One chunk. q,k,v: (B,H,c,dh) f32; li,lf: (B,H,c) log-gates f32;
    state: (C (B,H,dh,dh), n (B,H,dh), m (B,H))."""
    c0, n0, m0 = state
    dh = q.shape[-1]
    c = q.shape[2]
    fcum = jnp.cumsum(lf, axis=-1)                     # (B,H,c) inclusive
    g_total = fcum[..., -1]

    # log weight of source s for target t (s <= t): fcum_t - fcum_s + li_s
    log_w = (fcum[..., :, None] - fcum[..., None, :]
             + li[..., None, :])                       # (B,H,c,c)
    tri = jnp.tril(jnp.ones((c, c), bool))
    log_w = jnp.where(tri, log_w, -jnp.inf)
    m_intra = jnp.max(log_w, axis=-1)                  # (B,H,c)
    m_inter = fcum + m0[..., None]
    m_t = jnp.maximum(m_intra, m_inter)                # (B,H,c)
    m_t = jnp.maximum(m_t, -1e30)                      # guard -inf

    d_mat = jnp.exp(log_w - m_t[..., None])
    d_mat = jnp.where(tri, d_mat, 0.0)                 # (B,H,c,c)
    scale = dh ** -0.5                                 # k-scaling (xLSTM conv.)
    s_qk = jnp.einsum("bhtd,bhsd->bhts", q, k * scale) * d_mat
    h_intra = jnp.einsum("bhts,bhsd->bhtd", s_qk, v)
    n_intra = jnp.einsum("bhts,bhsd->bhtd", d_mat,
                         k * scale)                    # sum of weighted k
    w_inter = jnp.exp(m_inter - m_t)                   # (B,H,c)
    h_inter = jnp.einsum("bhtd,bhde->bhte", q, c0) * w_inter[..., None]
    n_inter = n0[..., None, :] * w_inter[..., None]

    num = h_intra + h_inter
    nvec = n_intra + n_inter                           # (B,H,c,dh)
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhtd,bhtd->bht", q, nvec)),
        jnp.exp(-m_t))
    h_out = num / denom[..., None]

    # ---- state update to end of chunk
    lw_end = g_total[..., None] - fcum + li            # (B,H,c)
    m_next = jnp.maximum(g_total + m0, jnp.max(lw_end, axis=-1))
    w_end = jnp.exp(lw_end - m_next[..., None])        # (B,H,c)
    decay = jnp.exp(g_total + m0 - m_next)             # (B,H)
    c_next = (c0 * decay[..., None, None]
              + jnp.einsum("bhs,bhsd,bhse->bhde", w_end, k * scale, v))
    n_next = n0 * decay[..., None] + jnp.einsum("bhs,bhsd->bhd", w_end,
                                                k * scale)
    return h_out, (c_next, n_next, m_next)


def mlstm_sequence(q, k, v, li, lf, state, chunk: int):
    """q,k,v: (B,S,H,dh); li,lf: (B,S,H). Returns h (B,S,H,dh), state."""
    b, s, h, dh = q.shape
    if s % chunk:
        raise ValueError(f"seq {s} not divisible by chunk {chunk}")
    nc = s // chunk

    def to_chunks(x):
        x = x.astype(F32)
        if x.ndim == 4:
            return (x.reshape(b, nc, chunk, h, dh)
                    .transpose(1, 0, 3, 2, 4))          # (nc,B,H,c,dh)
        return x.reshape(b, nc, chunk, h).transpose(1, 0, 3, 2)  # (nc,B,H,c)

    qs, ks, vs = to_chunks(q), to_chunks(k), to_chunks(v)
    lis, lfs = to_chunks(li), to_chunks(lf)

    def step(carry, xs):
        qq, kk, vv, ii, ff = xs
        h_out, carry = _mlstm_chunk(qq, kk, vv, ii, ff, carry)
        return carry, h_out

    state, hs = jax.lax.scan(step, state, (qs, ks, vs, lis, lfs),
                             unroll=flags.scan_unroll(nc))
    hs = hs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dh)  # (B,S,H,dh)
    return hs, state


def mlstm_step(q, k, v, li, lf, state):
    """Single recurrent step. q,k,v: (B,H,dh) f32; li,lf: (B,H)."""
    c0, n0, m0 = state
    dh = q.shape[-1]
    scale = dh ** -0.5
    m_new = jnp.maximum(lf + m0, li)
    fg = jnp.exp(lf + m0 - m_new)
    ig = jnp.exp(li - m_new)
    c1 = c0 * fg[..., None, None] + ig[..., None, None] * (
        (k * scale)[..., :, None] * v[..., None, :])
    n1 = n0 * fg[..., None] + ig[..., None] * (k * scale)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n1)),
                        jnp.exp(-m_new))
    h = jnp.einsum("bhd,bhde->bhe", q, c1) / denom[..., None]
    return h, (c1, n1, m_new)


def _mlstm_qkv_gates(p, x, cfg: ModelConfig, conv_state=None):
    """Shared pre-processing: up-proj, conv, heads, gates.

    x: (B,S,D). Returns q,k,v (B,S,H,dh), li,lf (B,S,H), z (B,S,inner),
    new conv state (B,K-1,inner)."""
    inner = p["conv_w"].shape[1]
    up = common.fdot(x, p["w_up"])
    xi, z = jnp.split(up, 2, axis=-1)
    kk = cfg.ssm.conv_kernel
    conv_out = common.causal_conv1d(xi, p["conv_w"], conv_state)
    new_conv = jnp.concatenate(
        [conv_state if conv_state is not None
         else jnp.zeros(xi.shape[:1] + (kk - 1,) + xi.shape[2:], F32),
         xi.astype(F32)], axis=1)[:, -(kk - 1):]
    xc = jax.nn.silu(conv_out.astype(F32)).astype(x.dtype)
    h = cfg.num_heads
    b, s = x.shape[:2]

    def heads(t):
        return t.reshape(b, s, h, inner // h)

    q = heads(common.fdot(xc, p["wq"]))
    k = heads(common.fdot(xc, p["wk"]))
    v = heads(common.fdot(xi, p["wv"]))
    li = jnp.einsum("bsi,ih->bsh", xc.astype(F32), p["w_igate"]) + p["b_igate"]
    lf_raw = jnp.einsum("bsi,ih->bsh", xc.astype(F32), p["w_fgate"]) + p["b_fgate"]
    lf = jax.nn.log_sigmoid(lf_raw)
    return q, k, v, li, lf, z, new_conv


def mlstm_apply(p, x, *, cfg: ModelConfig, state: Optional[dict] = None,
                decode: bool = False) -> Tuple[jax.Array, Optional[dict]]:
    """Pre-norm mLSTM block with residual. state: see mlstm_state_defs."""
    res = x
    xn = common.rms_norm(x, p["norm"], cfg.norm_eps)
    conv_state = state["conv"] if state is not None else None
    q, k, v, li, lf, z, new_conv = _mlstm_qkv_gates(p, xn, cfg, conv_state)
    b, s = x.shape[:2]
    h = cfg.num_heads
    inner = p["conv_w"].shape[1]
    dh = inner // h
    if state is not None:
        st = (state["C"], state["n"], state["m"])
    else:
        st = (jnp.zeros((b, h, dh, dh), F32), jnp.zeros((b, h, dh), F32),
              jnp.zeros((b, h), F32))
    if decode:
        if s != 1:
            raise ValueError(f"mlstm decode step expects seq len 1, got {s}")
        hs, st = mlstm_step(q[:, 0].astype(F32), k[:, 0].astype(F32),
                            v[:, 0].astype(F32), li[:, 0], lf[:, 0], st)
        hs = hs[:, None]                               # (B,1,H,dh)
    else:
        chunk = min(cfg.ssm.chunk_size, s)
        while s % chunk:                             # largest divisor <= chunk
            chunk -= 1
        hs, st = mlstm_sequence(q, k, v, li, lf, st, chunk)
    hs = hs.reshape(b, s, inner)
    hs = common.rms_norm(hs.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    out = hs * jax.nn.silu(z.astype(F32)).astype(x.dtype)
    out = common.fdot(out, p["w_down"])
    new_state = {"C": st[0], "n": st[1], "m": st[2], "conv": new_conv}
    return res + out, new_state


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def slstm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    ff = int(4 * d / 3 + 63) // 64 * 64
    return {
        "norm": ParamDef((d,), ("embed",), "ones", dtype="float32"),
        # gates order: z, i, f, o
        "w_gates": ParamDef((d, 4 * d), ("embed", "inner"), "fan_in",
                            dtype="float32"),
        "r_gates": ParamDef((h, dh, 4 * dh), (None, None, "inner"), "fan_in",
                            dtype="float32"),
        "b_gates": ParamDef((4 * d,), ("inner",), "zeros", dtype="float32"),
        "out_norm": ParamDef((d,), ("embed",), "ones", dtype="float32"),
        "w_out": ParamDef((d, d), ("embed", "embed"), "fan_in"),
        # post-FFN
        "ffn_norm": ParamDef((d,), ("embed",), "ones", dtype="float32"),
        "w_ff_in": ParamDef((d, ff), ("embed", "ffn"), "fan_in"),
        "w_ff_out": ParamDef((ff, d), ("ffn", "embed"), "fan_in",
                             scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }


def slstm_state_defs(cfg: ModelConfig, batch: int) -> Dict[str, ParamDef]:
    d, h = cfg.d_model, cfg.num_heads
    dh = d // h
    ab = ("act_batch",)
    return {
        "c": ParamDef((batch, h, dh), ab + (None, None), "zeros", dtype="float32"),
        "n": ParamDef((batch, h, dh), ab + (None, None), "zeros", dtype="float32"),
        "m": ParamDef((batch, h, dh), ab + (None, None), "zeros", dtype="float32"),
        "h": ParamDef((batch, h, dh), ab + (None, None), "zeros", dtype="float32"),
    }


def _slstm_cell(p, xw, state):
    """xw: (B, 4D) input contribution (pre-computed). state: (c,n,m,h)."""
    c0, n0, m0, h0 = state
    b = xw.shape[0]
    hh, dh = h0.shape[1], h0.shape[2]
    rec = jnp.einsum("bhd,hde->bhe", h0, p["r_gates"])      # (B,H,4dh)
    gates = xw.reshape(b, hh, 4 * dh) + rec
    z, i_raw, f_raw, o_raw = jnp.split(gates, 4, axis=-1)   # (B,H,dh) each
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o_raw)
    m_new = jnp.maximum(f_raw + m0, i_raw)
    ig = jnp.exp(i_raw - m_new)
    fg = jnp.exp(f_raw + m0 - m_new)
    c1 = fg * c0 + ig * z
    n1 = jnp.maximum(fg * n0 + ig, jnp.exp(-m_new))
    h1 = o * c1 / n1
    return (c1, n1, m_new, h1)


def slstm_apply(p, x, *, cfg: ModelConfig, state: Optional[dict] = None,
                decode: bool = False) -> Tuple[jax.Array, Optional[dict]]:
    res = x
    b, s, d = x.shape
    h, dh = cfg.num_heads, d // cfg.num_heads
    xn = common.rms_norm(x, p["norm"], cfg.norm_eps)
    xw = (jnp.einsum("bsd,de->bse", xn.astype(F32), p["w_gates"])
          + p["b_gates"])                                    # (B,S,4D)
    if state is not None:
        st = (state["c"], state["n"], state["m"], state["h"])
    else:
        z0 = jnp.zeros((b, h, dh), F32)
        st = (z0, z0, z0, z0)

    if decode:
        if s != 1:
            raise ValueError(f"slstm decode step expects seq len 1, got {s}")
        st = _slstm_cell(p, xw[:, 0], st)
        hs = st[3][:, None]                                  # (B,1,H,dh)
    else:
        def step(carry, xw_t):
            carry = _slstm_cell(p, xw_t, carry)
            return carry, carry[3]

        st, hs = jax.lax.scan(step, st, xw.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2, 3)                        # (B,S,H,dh)

    hs = hs.reshape(b, s, d).astype(x.dtype)
    hs = common.rms_norm(hs, p["out_norm"], cfg.norm_eps)
    out = common.fdot(hs, p["w_out"])
    x = res + out
    # post-FFN (GeLU)
    hf = common.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    hf = jax.nn.gelu(common.fdot(hf, p["w_ff_in"]).astype(F32),
                     approximate=True).astype(x.dtype)
    x = x + common.fdot(hf, p["w_ff_out"])
    new_state = {"c": st[0], "n": st[1], "m": st[2], "h": st[3]}
    return x, new_state
