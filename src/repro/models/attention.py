"""Attention: GQA, causal / bidirectional / sliding-window, decode-with-cache.

Two XLA execution paths (the Pallas flash kernel in ``repro.kernels`` is the
TPU-target hot path; these are the portable references that the dry-run lowers):

* ``attend_direct`` — materializes (Sq, Skv) logits; used for short sequences.
* ``attend_chunked`` — online-softmax scan over KV chunks; O(Sq * chunk)
  memory; used for long sequences (prefill_32k and up).

``prefix_grouped_causal`` is a beyond-paper compute optimization: causal
attention computed as G independent rectangular attends, q-group g attending
only its prefix — cuts the fully-masked upper-triangle FLOPs from ~2x useful
to (G+1)/G of useful. See EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import flags

F32 = jnp.float32
NEG_INF = -1e30


def _split_gqa(q: jax.Array, kvh: int) -> jax.Array:
    """(B, S, H, dh) -> (B, S, KVH, G, dh)."""
    b, s, h, dh = q.shape
    return q.reshape(b, s, kvh, h // kvh, dh)


def _mask(q_pos: jax.Array, kv_pos: jax.Array, causal: bool,
          window: int, kv_valid: Optional[jax.Array]) -> jax.Array:
    """Bool mask (..., Sq, Skv) from position arrays (..., Sq) / (..., Skv)."""
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        m &= kp <= qp
    if window > 0:
        m &= kp > qp - window
    m &= kp >= 0  # invalid cache slots are marked pos=-1
    if kv_valid is not None:
        m &= kp < kv_valid[..., None, None]
    return m


def attend_direct(q: jax.Array, k: jax.Array, v: jax.Array,
                  q_pos: jax.Array, kv_pos: jax.Array, *, causal: bool,
                  window: int = 0,
                  kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """q: (B,Sq,H,dh); k/v: (B,Skv,KVH,dh); positions (B,S*) or (S*,)."""
    kvh = k.shape[2]
    scale = q.shape[-1] ** -0.5
    qg = _split_gqa(q, kvh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=F32) * scale
    if q_pos.ndim == 1:
        q_pos = q_pos[None]
    if kv_pos.ndim == 1:
        kv_pos = kv_pos[None]
    m = _mask(q_pos, kv_pos, causal, window, kv_valid)     # (B,Sq,Skv)
    s = jnp.where(m[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=F32).astype(q.dtype)
    return out.reshape(q.shape)


def attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                   q_pos: jax.Array, kv_pos: jax.Array, *, causal: bool,
                   window: int = 0, chunk_kv: int = 1024) -> jax.Array:
    """Online-softmax scan over KV chunks. Memory O(Sq * chunk_kv)."""
    b, sq, h, dh = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    if skv % chunk_kv:
        pad = chunk_kv - skv % chunk_kv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_pos.ndim == 1:
            kv_pos = kv_pos[None]
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        skv += pad
    if q_pos.ndim == 1:
        q_pos = q_pos[None]
    if kv_pos.ndim == 1:
        kv_pos = kv_pos[None]
    q_pos = jnp.broadcast_to(q_pos, (b, sq))
    kv_pos = jnp.broadcast_to(kv_pos, (b, skv))
    n_chunks = skv // chunk_kv
    qg = _split_gqa(q, kvh)
    scale = dh ** -0.5

    kc = k.reshape(b, n_chunks, chunk_kv, kvh, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk_kv, kvh, dh).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(b, n_chunks, chunk_kv).transpose(1, 0, 2)

    def step(carry, xs):
        m_run, l_run, acc = carry
        kj, vj, pj = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kj,
                       preferred_element_type=F32) * scale
        msk = _mask(q_pos, pj, causal, window, None)       # (B,Sq,ck)
        s = jnp.where(msk[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vj.dtype), vj,
                        preferred_element_type=F32)
        acc = acc * corr[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kvh, g, sq), NEG_INF, F32)
    l0 = jnp.zeros((b, kvh, g, sq), F32)
    acc0 = jnp.zeros((b, kvh, g, sq, dh), F32)
    (m_f, l_f, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kc, vc, pc),
                                      unroll=flags.scan_unroll(n_chunks))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


def prefix_grouped_causal(q, k, v, q_pos, kv_pos, *, window: int = 0,
                          groups: int = 1, chunk_kv: int = 1024):
    """Causal self-attention as `groups` prefix attends (Sq == Skv)."""
    sq = q.shape[1]
    if groups <= 1 or sq % groups:
        return attend_chunked(q, k, v, q_pos, kv_pos, causal=True,
                              window=window, chunk_kv=chunk_kv)
    gs = sq // groups
    chunk_kv = min(chunk_kv, gs)
    outs = []
    for gidx in range(groups):
        lo, hi = gidx * gs, (gidx + 1) * gs
        qp = q_pos[..., lo:hi]
        kv_hi = hi
        kv_lo = 0 if window <= 0 else max(0, lo - window + 1)
        kp = kv_pos[..., kv_lo:kv_hi]
        outs.append(attend_chunked(
            q[:, lo:hi], k[:, kv_lo:kv_hi], v[:, kv_lo:kv_hi], qp, kp,
            causal=True, window=window, chunk_kv=min(chunk_kv, kv_hi - kv_lo)))
    return jnp.concatenate(outs, axis=1)


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              q_pos: jax.Array, kv_pos: jax.Array, *, causal: bool,
              window: int = 0, kv_valid: Optional[jax.Array] = None,
              impl: str = "auto", chunk_kv: int = 1024,
              prefix_groups: int = 1) -> jax.Array:
    """Dispatcher. q (B,Sq,H,dh); k/v (B,Skv,KVH,dh)."""
    sq, skv = q.shape[1], k.shape[1]
    if impl == "auto":
        impl = "direct" if sq * skv <= flags.DIRECT_MAX_ELEMS else "chunked"
    if impl == "direct":
        return attend_direct(q, k, v, q_pos, kv_pos, causal=causal,
                             window=window, kv_valid=kv_valid)
    if causal and sq == skv and prefix_groups > 1:
        return prefix_grouped_causal(q, k, v, q_pos, kv_pos, window=window,
                                     groups=prefix_groups, chunk_kv=chunk_kv)
    return attend_chunked(q, k, v, q_pos, kv_pos, causal=causal,
                          window=window, chunk_kv=chunk_kv)


def decode_attend(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                  q_pos: jax.Array, cache_pos: jax.Array) -> jax.Array:
    """One-token decode. q: (B,1,H,dh); caches (B,W,KVH,dh);
    q_pos (B,); cache_pos (B,W) absolute positions (-1 = empty)."""
    k_cache = constrain(k_cache, "act_batch", "act_kv_seq", None, None)
    v_cache = constrain(v_cache, "act_batch", "act_kv_seq", None, None)
    return attend_direct(q, k_cache, v_cache, q_pos[:, None], cache_pos,
                         causal=True, window=0)
