"""Parameter declaration: models describe params as a pytree of ``ParamDef``
(shape + logical axes + initializer); ``init_params`` materializes them with
per-leaf folded PRNG keys, and the same tree drives sharding-spec construction
(`repro.distributed.sharding.param_shardings`) and abstract dry-run inputs.
"""
from __future__ import annotations

import zlib
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

# jax.tree.flatten_with_path only exists from jax 0.4.38 on; the pinned
# 0.4.37 ships it under jax.tree_util.
_flatten_with_path = getattr(jax.tree, "flatten_with_path", None) \
    or jax.tree_util.tree_flatten_with_path


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | fan_in
    scale: float = 1.0
    dtype: Optional[str] = None  # override model default (e.g. f32 norms)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs, key: jax.Array, default_dtype: str):
    """Materialize a ParamDef tree. Key is folded per tree-path (order-stable)."""
    paths_defs, treedef = _flatten_with_path(defs, is_leaf=_is_def)

    leaves = []
    for path, d in paths_defs:
        if len(d.shape) != len(d.axes):
            raise ValueError(
                f"ParamDef at {jax.tree_util.keystr(path)}: shape {d.shape} "
                f"has {len(d.shape)} dims but axes {d.axes} names "
                f"{len(d.axes)}")
        dtype = jnp.dtype(d.dtype or default_dtype)
        k = jax.random.fold_in(key, zlib.crc32(jax.tree_util.keystr(path).encode()))
        if d.init == "zeros":
            leaf = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            leaf = jnp.ones(d.shape, dtype)
        elif d.init == "fan_in":
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale / (fan_in ** 0.5)
            leaf = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype)
        else:  # normal
            leaf = (jax.random.normal(k, d.shape, jnp.float32)
                    * 0.02 * d.scale).astype(dtype)
        leaves.append(leaf)
    return jax.tree.unflatten(treedef, leaves)


def abstract_params(defs, default_dtype: str):
    """ShapeDtypeStruct tree matching ``init_params`` output (for dry-run)."""
    def one(d: ParamDef):
        return jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or default_dtype))
    return jax.tree.map(one, defs, is_leaf=_is_def)


def count_params(defs) -> int:
    total = 0
    for d in jax.tree.leaves(defs, is_leaf=_is_def):
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total
