"""Shared model building blocks: norms, RoPE / M-RoPE, MLPs, embeddings."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def fdot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Matmul with f32 accumulation, result in a.dtype."""
    return jnp.matmul(a, b, preferred_element_type=F32).astype(a.dtype)


def feinsum(eq: str, *xs: jax.Array) -> jax.Array:
    return jnp.einsum(eq, *xs, preferred_element_type=F32).astype(xs[0].dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(F32)).astype(x.dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(F32) + b.astype(F32)
    return out.astype(x.dtype)


def modulate(x: jax.Array, shift: jax.Array, scale: jax.Array) -> jax.Array:
    """adaLN modulation; shift/scale are (B, D), x is (B, N, D)."""
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


# --------------------------------------------------------------------------
# Rotary embeddings
# --------------------------------------------------------------------------

def _rope_angles(positions: jax.Array, half_dim: int,
                 theta: float) -> jax.Array:
    """positions (...,) -> angles (..., half_dim), f32."""
    inv_freq = 1.0 / (theta ** (jnp.arange(half_dim, dtype=F32) / half_dim))
    return positions.astype(F32)[..., None] * inv_freq


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10_000.0) -> jax.Array:
    """x: (B, S, H, dh); positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = _rope_angles(positions, dh // 2, theta)          # (B, S, dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array,
                sections: Tuple[int, ...], theta: float) -> jax.Array:
    """Qwen2-VL multi-axis RoPE.

    x: (B, S, H, dh); positions: (B, S, A) with A == len(sections); the rotary
    half-dims are split into `sections` (summing to dh//2), each section
    rotated with its own position axis (t, h, w).
    """
    dh = x.shape[-1]
    if sum(sections) != dh // 2:
        raise ValueError(f"mrope sections {sections} must sum to half the "
                         f"head dim ({dh} // 2 = {dh // 2})")
    axis_of_freq = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)])
    # pos_per_freq: (B, S, dh/2)
    pos = jnp.take_along_axis(
        positions.astype(F32),
        jnp.broadcast_to(axis_of_freq[None, None, :],
                         positions.shape[:2] + (dh // 2,)),
        axis=-1)
    inv_freq = 1.0 / (theta ** (jnp.arange(dh // 2, dtype=F32) / (dh // 2)))
    ang = pos * inv_freq                                   # (B, S, dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def rope_dispatch(x: jax.Array, positions: Optional[jax.Array], kind: str,
                  theta: float, sections: Tuple[int, ...]) -> jax.Array:
    if kind == "none" or positions is None:
        return x
    if kind == "mrope":
        if positions.ndim == 2:  # text-only: broadcast to all axes
            positions = jnp.repeat(positions[..., None], len(sections), -1)
        return apply_mrope(x, positions, sections, theta)
    return apply_rope(x, positions, theta)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = fdot(x, w_gate)
    u = fdot(x, w_up)
    return fdot(jax.nn.silu(g.astype(F32)).astype(x.dtype) * u, w_down)


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
             w_out: jax.Array, b_out: jax.Array) -> jax.Array:
    h = fdot(x, w_in) + b_in
    h = jax.nn.gelu(h.astype(F32), approximate=True).astype(x.dtype)
    return fdot(h, w_out) + b_out


# --------------------------------------------------------------------------
# Embeddings
# --------------------------------------------------------------------------

def timestep_embedding(t: jax.Array, dim: int,
                       max_period: float = 10_000.0) -> jax.Array:
    """Sinusoidal timestep embedding (DiT). t: (B,) -> (B, dim) f32."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period)
                    * jnp.arange(half, dtype=F32) / half)
    args = t.astype(F32)[:, None] * freqs[None]
    emb = jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)
    if dim % 2:
        emb = jnp.pad(emb, ((0, 0), (0, 1)))
    return emb


def patchify(latents: jax.Array, patch: int) -> jax.Array:
    """(B, H, W, C) -> (B, (H/p)*(W/p), p*p*C)."""
    b, h, w, c = latents.shape
    x = latents.reshape(b, h // patch, patch, w // patch, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, (h // patch) * (w // patch), patch * patch * c)


def unpatchify(tokens: jax.Array, patch: int, grid: int) -> jax.Array:
    """(B, g*g, p*p*C) -> (B, g*p, g*p, C)."""
    b, n, d = tokens.shape
    c = d // (patch * patch)
    x = tokens.reshape(b, grid, grid, patch, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, grid * patch, grid * patch, c)


def causal_conv1d(x: jax.Array, w: jax.Array,
                  state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv along seq. x: (B, S, C); w: (K, C).

    If `state` (B, K-1, C) is given, it is the trailing context (decode)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (k - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x, dtype=F32)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1]].astype(F32) * w[i].astype(F32)
    return out.astype(x.dtype)
