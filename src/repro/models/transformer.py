"""The unified stacked-block model covering the dense / moe / vlm / audio /
ssm / hybrid families. Layers are grouped into *super-blocks* of one
``block_pattern`` period and scanned (``lax.scan``) over the stack — HLO size
is O(period), independent of depth (61-layer Kimi lowers as one scanned body).

API (shared with DiTModel):
    init(key) -> params                 param_defs() -> ParamDef tree
    apply(params, batch, train)  -> (hidden, aux)
    loss(params, batch)          -> (scalar, metrics)
    prefill(params, batch, window) -> (last_logits, cache)
    init_cache(batch, window)    -> zeroed cache pytree (or ParamDef tree)
    decode_step(params, tokens, cache) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import common, flags, layers, mamba, ssm
from repro.models.params import ParamDef, abstract_params, init_params

F32 = jnp.float32


def _moe_at(cfg: ModelConfig, pos: int) -> bool:
    if cfg.moe is None:
        return False
    if cfg.family == "moe":
        return True
    return pos % cfg.moe.moe_layer_period == 1


class TransformerModel:
    def __init__(self, cfg: ModelConfig, *, prefix_groups: int = 1):
        self.cfg = cfg
        self.kinds = cfg.block_pattern or ("attn",)
        self.period = len(self.kinds)
        if cfg.num_layers % self.period != 0:
            raise ValueError(
                f"{cfg.name}: {cfg.num_layers} layers not divisible by "
                f"pattern period {self.period}")
        self.n_super = cfg.num_layers // self.period
        self.prefix_groups = prefix_groups

    # ------------------------------------------------------------------
    # Params
    # ------------------------------------------------------------------

    def _block_defs(self, pos: int) -> Dict[str, dict]:
        cfg = self.cfg
        kind = self.kinds[pos]
        d: Dict[str, dict] = {}
        if kind == "attn":
            d["attn"] = layers.attn_defs(cfg)
        elif kind == "mamba":
            d["mamba"] = mamba.mamba_defs(cfg)
        elif kind == "mlstm":
            d["mlstm"] = ssm.mlstm_defs(cfg)
        elif kind == "slstm":
            d["slstm"] = ssm.slstm_defs(cfg)
        else:
            raise ValueError(kind)
        if kind != "mlstm" and kind != "slstm" and cfg.d_ff > 0:
            if _moe_at(cfg, pos):
                d["moe"] = layers.moe_defs(cfg)
            else:
                mlp_kind = "gelu" if cfg.family == "audio" else "swiglu"
                d["ffn"] = layers.ffn_defs(cfg, kind=mlp_kind)
        return d

    def param_defs(self):
        cfg = self.cfg
        defs: Dict[str, object] = {
            "final_norm": ParamDef((cfg.d_model,), ("embed",), "ones",
                                   dtype="float32"),
            "blocks": {f"pos{i}": layers.stack_defs(self._block_defs(i),
                                                    self.n_super)
                       for i in range(self.period)},
        }
        if cfg.family == "audio":
            defs["feat_proj"] = ParamDef((cfg.frontend_dim, cfg.d_model),
                                         (None, "embed"), "fan_in")
            defs["feat_bias"] = ParamDef((cfg.d_model,), ("embed",), "zeros")
            defs["pos_conv"] = ParamDef((15, cfg.d_model), (None, "embed"),
                                        "fan_in")
            defs["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size),
                                       ("embed", "vocab"), "fan_in")
        else:
            defs["embed"] = ParamDef((cfg.vocab_size, cfg.d_model),
                                     ("vocab", "embed"), "normal")
            if not cfg.tie_embeddings:
                defs["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size),
                                           ("embed", "vocab"), "fan_in")
        return defs

    def init(self, key: jax.Array):
        return init_params(self.param_defs(), key, self.cfg.dtype)

    def abstract_params(self):
        return abstract_params(self.param_defs(), self.cfg.dtype)

    # ------------------------------------------------------------------
    # Embedding / head
    # ------------------------------------------------------------------

    def embed(self, params, batch) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "audio":
            x = common.fdot(batch["features"].astype(jnp.dtype(cfg.dtype)),
                            params["feat_proj"]) + params["feat_bias"]
            # symmetric depthwise positional conv
            w = params["pos_conv"]
            k = w.shape[0]
            xp = jnp.pad(x, ((0, 0), (k // 2, k - 1 - k // 2), (0, 0)))
            pos = jnp.zeros_like(x, dtype=F32)
            for i in range(k):
                pos = pos + xp[:, i:i + x.shape[1]].astype(F32) * w[i].astype(F32)
            return x + jax.nn.gelu(pos).astype(x.dtype)
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        if cfg.family == "vlm" and "vision_embeds" in batch:
            vis, msk = batch["vision_embeds"], batch["vision_mask"]
            # associative_scan: cost analysis counts plain cumsum (reduce-
            # window) quadratically in S, which would pollute the roofline
            csum = jax.lax.associative_scan(jnp.add,
                                            msk.astype(jnp.int32), axis=1)
            idx = jnp.clip(csum - 1, 0, vis.shape[1] - 1)
            scattered = jnp.take_along_axis(vis.astype(x.dtype),
                                            idx[..., None], axis=1)
            x = jnp.where(msk[..., None], scattered, x)
        return x

    def unembed(self, params, hidden) -> jax.Array:
        if self.cfg.tie_embeddings:
            return common.feinsum("...d,vd->...v", hidden, params["embed"])
        return common.fdot(hidden, params["lm_head"])

    def _head_matrix(self, params):
        """(V, D) regardless of tie/untie."""
        if self.cfg.family == "audio" or not self.cfg.tie_embeddings:
            return params["lm_head"].T
        return params["embed"]

    # ------------------------------------------------------------------
    # Block application
    # ------------------------------------------------------------------

    def block_apply(self, pos: int, bp, x, *, positions=None, cache=None,
                    decode_pos=None, window=0, decode=False):
        """Apply super-block position `pos`. Returns (x, new_cache, aux)."""
        cfg = self.cfg
        kind = self.kinds[pos]
        aux = jnp.zeros((), F32)
        new_cache = {}
        if kind == "attn":
            x, c = layers.attn_apply(
                bp["attn"], x, cfg=cfg, positions=positions, cache=cache,
                decode_pos=decode_pos, window=window,
                prefix_groups=self.prefix_groups)
            if c is not None:
                new_cache = c
        elif kind == "mamba":
            x, st = mamba.mamba_apply(bp["mamba"], x, cfg=cfg, state=cache,
                                      decode=decode)
            new_cache = st
        elif kind == "mlstm":
            x, st = ssm.mlstm_apply(bp["mlstm"], x, cfg=cfg, state=cache,
                                    decode=decode)
            new_cache = st
        elif kind == "slstm":
            x, st = ssm.slstm_apply(bp["slstm"], x, cfg=cfg, state=cache,
                                    decode=decode)
            new_cache = st
        if "moe" in bp:
            x, moe_aux = layers.moe_apply(bp["moe"], x, cfg)
            aux = aux + moe_aux
        elif "ffn" in bp:
            x = layers.ffn_apply(bp["ffn"], x, cfg)
        return x, new_cache, aux

    # ------------------------------------------------------------------
    # Full-sequence forward (train / encode)
    # ------------------------------------------------------------------

    def apply(self, params, batch, train: bool = False):
        cfg = self.cfg
        x = self.embed(params, batch)
        x = constrain(x, "act_batch", "act_seq", "act_embed")
        positions = batch.get("positions")

        def super_block(x, bps):
            aux = jnp.zeros((), F32)
            for i in range(self.period):
                x, _, a = self.block_apply(i, bps[f"pos{i}"], x,
                                           positions=positions)
                aux = aux + a
            x = constrain(x, "act_batch", "act_seq", "act_embed")
            return x, aux

        body = super_block
        if train and cfg.remat:
            body = jax.checkpoint(
                super_block,
                policy=jax.checkpoint_policies.save_only_these_names())

        def scan_body(carry, bps):
            x, aux = carry
            x, a = body(x, bps)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), F32)),
                                   params["blocks"],
                                   unroll=flags.scan_unroll(self.n_super))
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, {"moe_aux": aux}

    # ------------------------------------------------------------------
    # Loss (chunked cross-entropy over the vocab head)
    # ------------------------------------------------------------------

    def loss(self, params, batch) -> Tuple[jax.Array, dict]:
        cfg = self.cfg
        hidden, aux = self.apply(params, batch, train=True)
        head = self._head_matrix(params)                     # (V, D)
        if cfg.family == "audio":
            targets = batch["targets"]
            mask = batch.get("mask_indices",
                             jnp.ones(targets.shape, bool)).astype(F32)
            h = hidden
        else:
            tokens = batch["tokens"]
            targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
            mask = jnp.pad(jnp.ones_like(tokens[:, 1:], dtype=F32),
                           ((0, 0), (0, 1)))
            if "loss_mask" in batch:
                mask = mask * batch["loss_mask"].astype(F32)
            h = hidden
        nll, denom = chunked_ce(h, head, targets, mask)
        loss = nll / jnp.maximum(denom, 1.0) + aux["moe_aux"]
        return loss, {"nll": nll / jnp.maximum(denom, 1.0),
                      "moe_aux": aux["moe_aux"], "tokens": denom}

    # ------------------------------------------------------------------
    # Caching / decode
    # ------------------------------------------------------------------

    def cache_defs(self, batch: int, window: int):
        cfg = self.cfg
        out = {}
        for i, kind in enumerate(self.kinds):
            if kind == "attn":
                d = layers.attn_cache_defs(cfg, batch, window, cfg.dtype)
            elif kind == "mamba":
                d = mamba.mamba_state_defs(cfg, batch)
            elif kind == "mlstm":
                d = ssm.mlstm_state_defs(cfg, batch)
            else:
                d = ssm.slstm_state_defs(cfg, batch)
            out[f"pos{i}"] = layers.stack_defs(d, self.n_super)
        return {"blocks": out,
                "step": ParamDef((batch,), ("act_batch",), "zeros",
                                 dtype="int32")}

    def init_cache(self, batch: int, window: int):
        defs = self.cache_defs(batch, window)
        zeros = init_params(defs, jax.random.PRNGKey(0), self.cfg.dtype)
        # empty attn slots are pos=-1
        for i, kind in enumerate(self.kinds):
            if kind == "attn":
                blk = zeros["blocks"][f"pos{i}"]
                blk["pos"] = blk["pos"] - 1
        return zeros

    def abstract_cache(self, batch: int, window: int):
        return abstract_params(self.cache_defs(batch, window), self.cfg.dtype)

    def prefill(self, params, batch, window: int):
        """Full forward that also builds the decode cache."""
        cfg = self.cfg
        x = self.embed(params, batch)
        x = constrain(x, "act_batch", "act_seq", "act_embed")
        positions = batch.get("positions")
        b = x.shape[0]

        def scan_body(carry, bps):
            x, aux = carry
            caches = {}
            for i in range(self.period):
                kind = self.kinds[i]
                cache_in = None
                if kind == "attn":
                    # template for shape only; attn prefill builds its own
                    cache_in = {"k": jnp.zeros(
                        (b, window, cfg.num_kv_heads, cfg.resolved_head_dim),
                        jnp.dtype(cfg.dtype)), "v": None, "pos": None}
                    cache_in["v"] = cache_in["k"]
                    cache_in["pos"] = jnp.zeros((b, window), jnp.int32)
                x, c, a = self.block_apply(i, bps[f"pos{i}"], x,
                                           positions=positions,
                                           cache=cache_in, window=window)
                caches[f"pos{i}"] = c
                aux = aux + a
            return (x, aux), caches

        (x, _aux), blocks_cache = jax.lax.scan(
            scan_body, (x, jnp.zeros((), F32)), params["blocks"],
            unroll=flags.scan_unroll(self.n_super))
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self.unembed(params, x[:, -1])
        s = batch["tokens"].shape[1] if "tokens" in batch \
            else batch["features"].shape[1]
        cache = {"blocks": blocks_cache,
                 "step": jnp.full((b,), s, jnp.int32)}
        return logits, cache

    def decode_step(self, params, tokens: jax.Array, cache,
                    extra: Optional[dict] = None):
        """tokens: (B,) int32. Returns (logits (B, V), new cache)."""
        cfg = self.cfg
        step = cache["step"]                                 # (B,)
        batch = {"tokens": tokens[:, None]}
        if extra:
            batch.update(extra)
        x = self.embed(params, batch)
        if cfg.rope_kind == "mrope":
            positions = jnp.repeat(step[:, None, None], 3, axis=-1)
        else:
            positions = step[:, None]

        def scan_body(x, xs):
            bps, blk_cache = xs
            new_caches = {}
            for i in range(self.period):
                x, c, _ = self.block_apply(
                    i, bps[f"pos{i}"], x, positions=positions,
                    cache=blk_cache[f"pos{i}"],
                    decode_pos=step if self.kinds[i] == "attn" else None,
                    decode=True)
                new_caches[f"pos{i}"] = c
            return x, new_caches

        x, new_blocks = jax.lax.scan(
            scan_body, x, (params["blocks"], cache["blocks"]),
            unroll=flags.scan_unroll(self.n_super))
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self.unembed(params, x[:, 0])
        return logits, {"blocks": new_blocks, "step": step + 1}


# --------------------------------------------------------------------------
# Chunked cross-entropy
# --------------------------------------------------------------------------

def chunked_ce(hidden: jax.Array, head: jax.Array, targets: jax.Array,
               mask: jax.Array, chunk: int = 512):
    """Cross-entropy without materializing (B, S, V) — scans S in chunks.

    hidden: (B,S,D); head: (V,D); targets/mask: (B,S).
    Returns (sum nll, sum mask)."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    if s % chunk:
        pad = chunk - s % chunk
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        s += pad
    nc = s // chunk
    hs = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(b, nc, chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    def step(carry, xs):
        h, t, m = xs
        logits = jnp.einsum("bcd,vd->bcv", h.astype(F32), head.astype(F32))
        logits = constrain(logits, "act_batch", "act_seq", "act_vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * m
        return (carry[0] + nll.sum(), carry[1] + m.sum()), None

    if flags.CE_REMAT:  # drop per-chunk logits; recompute in backward
        step = jax.checkpoint(step)
    (nll, denom), _ = jax.lax.scan(step, (jnp.zeros((), F32),
                                          jnp.zeros((), F32)), (hs, ts, ms),
                                   unroll=flags.scan_unroll(nc))
    return nll, denom
