"""Model construction from config."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.dit import DiTModel
from repro.models.transformer import TransformerModel


def build_model(cfg: ModelConfig, **kw):
    if cfg.family == "dit":
        return DiTModel(cfg)
    return TransformerModel(cfg, **kw)
