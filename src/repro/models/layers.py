"""Attention + FFN + MoE layer bodies and their ParamDefs.

Every ``*_defs`` returns a dict of ParamDef with logical axes; the matching
``*_apply`` consumes the materialized params. Layer stacks add a leading
``layers`` axis via ``stack_defs`` and scan over it.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.distributed.sharding import constrain
from repro.models import common, flags
from repro.models.attention import attention, decode_attend
from repro.models.params import ParamDef

F32 = jnp.float32


def stack_defs(defs, n: int):
    """Add a leading stacking dim of size n to every ParamDef."""
    def one(d: ParamDef) -> ParamDef:
        return ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init,
                        d.scale, d.dtype)
    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


# --------------------------------------------------------------------------
# Attention layer
# --------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, h, kvh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    out = {
        "norm": ParamDef((d,), ("embed",), "ones", dtype="float32"),
        "wq": ParamDef((d, h, dh), ("embed", "heads", "head_dim"), "fan_in"),
        "wk": ParamDef((d, kvh, dh), ("embed", "kv_heads", "head_dim"), "fan_in"),
        "wv": ParamDef((d, kvh, dh), ("embed", "kv_heads", "head_dim"), "fan_in"),
        "wo": ParamDef((h, dh, d), ("heads", "head_dim", "embed"), "fan_in",
                       scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }
    if cfg.qk_norm:
        out["q_norm"] = ParamDef((dh,), ("head_dim",), "ones", dtype="float32")
        out["k_norm"] = ParamDef((dh,), ("head_dim",), "ones", dtype="float32")
    if cfg.is_encoder and cfg.family in ("audio",):
        out["norm_b"] = ParamDef((d,), ("embed",), "zeros", dtype="float32")
    return out


def _qkv(p, x, cfg: ModelConfig, positions):
    q = common.feinsum("bsd,dhk->bshk", x, p["wq"])
    k = common.feinsum("bsd,dhk->bshk", x, p["wk"])
    v = common.feinsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = common.rope_dispatch(q, positions, cfg.rope_kind, cfg.rope_theta,
                             cfg.mrope_sections)
    k = common.rope_dispatch(k, positions, cfg.rope_kind, cfg.rope_theta,
                             cfg.mrope_sections)
    q = constrain(q, "act_batch", "act_attn_seq", "act_heads", None)
    return q, k, v


def attn_apply(p, x: jax.Array, *, cfg: ModelConfig,
               positions: Optional[jax.Array],
               cache: Optional[dict] = None,
               decode_pos: Optional[jax.Array] = None,
               window: int = 0, prefix_groups: int = 1,
               ) -> Tuple[jax.Array, Optional[dict]]:
    """Pre-norm attention sublayer with residual.

    * train/encode: ``cache=None, decode_pos=None`` — full self-attention.
    * prefill:      ``cache`` is a zeroed cache dict to fill, decode_pos None.
    * decode:       ``cache`` holds K/V; ``decode_pos`` (B,) current positions.
    """
    if "norm_b" in p:
        h_in = common.layer_norm(x, p["norm"], p["norm_b"], cfg.norm_eps)
    else:
        h_in = common.rms_norm(x, p["norm"], cfg.norm_eps)

    causal = not cfg.is_encoder
    new_cache = None
    if decode_pos is not None:                       # ---- decode (Sq == 1)
        if cache is None:
            raise ValueError("attention decode step (decode_pos set) "
                             "requires a KV cache; got cache=None")
        q, k, v = _qkv(p, h_in, cfg, positions)
        w = cache["k"].shape[1]
        slot = decode_pos % w                        # (B,)
        bidx = jnp.arange(x.shape[0])
        k_cache = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
        pos_cache = cache["pos"].at[bidx, slot].set(decode_pos)
        out = decode_attend(q, k_cache.astype(x.dtype),
                            v_cache.astype(x.dtype), decode_pos, pos_cache)
        new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache}
    else:                                            # ---- full sequence
        s = x.shape[1]
        if positions is None:
            positions = jnp.arange(s)[None]          # (1, S): rope + mask
        q, k, v = _qkv(p, h_in, cfg, positions)
        pos1d = positions
        if pos1d is not None and pos1d.ndim == 3:    # mrope: use t axis
            pos1d = pos1d[..., 0]
        out = attention(q, k, v, pos1d, pos1d, causal=causal, window=window,
                        prefix_groups=prefix_groups)
        if cache is not None:                        # prefill: fill the cache
            w = cache["k"].shape[1]
            kd = k.astype(cache["k"].dtype)
            vd = v.astype(cache["v"].dtype)
            pc = jnp.broadcast_to(pos1d, (x.shape[0], s)).astype(jnp.int32)
            if s >= w:                               # keep the last w entries
                kd, vd, pc = kd[:, s - w:], vd[:, s - w:], pc[:, s - w:]
                # rotate so that slot == pos % w
                shift = (s - w) % w
                idx = (jnp.arange(w) - shift) % w
                inv = jnp.argsort(idx)
                new_cache = {"k": kd[:, inv], "v": vd[:, inv],
                             "pos": pc[:, inv]}
            else:
                pad = w - s
                new_cache = {
                    "k": jnp.pad(kd, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    "v": jnp.pad(vd, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    "pos": jnp.pad(pc, ((0, 0), (0, pad)), constant_values=-1),
                }
    proj = common.feinsum("bshk,hkd->bsd", out, p["wo"])
    proj = constrain(proj, "act_batch", "act_seq", "act_embed")
    return x + proj, new_cache


def attn_cache_defs(cfg: ModelConfig, batch: int, window: int,
                    dtype: str) -> Dict[str, ParamDef]:
    kvh, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": ParamDef((batch, window, kvh, dh),
                      ("act_batch", "act_kv_seq", None, None), "zeros", dtype=dtype),
        "v": ParamDef((batch, window, kvh, dh),
                      ("act_batch", "act_kv_seq", None, None), "zeros", dtype=dtype),
        "pos": ParamDef((batch, window), ("act_batch", "act_kv_seq"),
                        "zeros", dtype="int32"),
    }


# --------------------------------------------------------------------------
# Dense FFN
# --------------------------------------------------------------------------

def ffn_defs(cfg: ModelConfig, d_ff: Optional[int] = None,
             kind: str = "swiglu") -> Dict[str, ParamDef]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    out = {"norm": ParamDef((d,), ("embed",), "ones", dtype="float32")}
    if kind == "swiglu":
        out.update({
            "w_gate": ParamDef((d, f), ("embed", "ffn"), "fan_in"),
            "w_up": ParamDef((d, f), ("embed", "ffn"), "fan_in"),
            "w_down": ParamDef((f, d), ("ffn", "embed"), "fan_in",
                               scale=1.0 / max(1, cfg.num_layers) ** 0.5),
        })
    else:  # gelu
        out.update({
            "norm_b": ParamDef((d,), ("embed",), "zeros", dtype="float32"),
            "w_in": ParamDef((d, f), ("embed", "ffn"), "fan_in"),
            "b_in": ParamDef((f,), ("ffn",), "zeros"),
            "w_out": ParamDef((f, d), ("ffn", "embed"), "fan_in",
                              scale=1.0 / max(1, cfg.num_layers) ** 0.5),
            "b_out": ParamDef((d,), ("embed",), "zeros"),
        })
    return out


def ffn_apply(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "w_in" in p:
        h = common.layer_norm(x, p["norm"], p["norm_b"], cfg.norm_eps)
        out = common.gelu_mlp(h, p["w_in"], p["b_in"], p["w_out"], p["b_out"])
    else:
        h = common.rms_norm(x, p["norm"], cfg.norm_eps)
        h = constrain(h, "act_batch", "act_seq", "act_embed")
        out = common.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    out = constrain(out, "act_batch", "act_seq", "act_embed")
    return x + out


# --------------------------------------------------------------------------
# Mixture of Experts
# --------------------------------------------------------------------------

def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    m = cfg.moe
    if m is None:
        raise ValueError(f"{cfg.name}: moe block requested but cfg.moe is "
                         "None")
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    out = {
        "norm": ParamDef((d,), ("embed",), "ones", dtype="float32"),
        "router": ParamDef((d, e), ("embed", None), "fan_in", dtype="float32"),
        "we_gate": ParamDef((e, d, f), ("expert", "expert_embed", None), "fan_in"),
        "we_up": ParamDef((e, d, f), ("expert", "expert_embed", None), "fan_in"),
        "we_down": ParamDef((e, f, d), ("expert", None, "expert_embed"), "fan_in",
                            scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }
    if m.num_shared_experts:
        fs = f * m.num_shared_experts
        out.update({
            "ws_gate": ParamDef((d, fs), ("embed", "ffn"), "fan_in"),
            "ws_up": ParamDef((d, fs), ("embed", "ffn"), "fan_in"),
            "ws_down": ParamDef((fs, d), ("ffn", "embed"), "fan_in"),
        })
    if m.dense_ff_parallel:
        fd = m.dense_ff_parallel
        out.update({
            "wd_gate": ParamDef((d, fd), ("embed", "ffn"), "fan_in"),
            "wd_up": ParamDef((d, fd), ("embed", "ffn"), "fan_in"),
            "wd_down": ParamDef((fd, d), ("ffn", "embed"), "fan_in"),
        })
    return out


def moe_capacity(m: MoEConfig, tokens: int) -> int:
    c = int(m.capacity_factor * m.top_k * tokens / m.num_experts)
    return max(m.min_capacity, c)


def moe_gather_apply(p, x: jax.Array, cfg: ModelConfig
                     ) -> Tuple[jax.Array, jax.Array]:
    """Decode-path MoE: gather the top-k experts' weights per token and run
    per-token GEMVs — exact active-parameter FLOPs, no capacity padding.
    Used when tokens*top_k <= num_experts (decode steps), where the
    capacity dispatch would waste E*min_capacity slots on a handful of
    tokens (the dominant compute term of MoE decode otherwise)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    h = common.rms_norm(x, p["norm"], cfg.norm_eps)
    xt = h.reshape(t, d)
    logits = jnp.matmul(xt.astype(F32), p["router"])         # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    wg = jnp.take(p["we_gate"], top_i, axis=0)               # (T,k,D,F)
    wu = jnp.take(p["we_up"], top_i, axis=0)
    wd = jnp.take(p["we_down"], top_i, axis=0)               # (T,k,F,D)
    g = common.feinsum("td,tkdf->tkf", xt, wg)
    u = common.feinsum("td,tkdf->tkf", xt, wu)
    act = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    out = common.feinsum("tkf,tkfd->tkd", act, wd)           # (T,k,D)
    y = jnp.einsum("tkd,tk->td", out.astype(F32),
                   top_w.astype(F32)).astype(x.dtype)

    frac_tokens = jnp.mean(jax.nn.one_hot(top_i[:, 0], m.num_experts,
                                          dtype=F32), axis=0)
    aux = (m.num_experts * jnp.sum(frac_tokens * jnp.mean(probs, axis=0))
           * m.router_aux_weight)
    if m.num_shared_experts:
        y = y + common.swiglu(xt, p["ws_gate"], p["ws_up"],
                              p["ws_down"]).astype(F32).astype(x.dtype)
    if m.dense_ff_parallel:
        y = y + common.swiglu(xt, p["wd_gate"], p["wd_up"],
                              p["wd_down"]).astype(F32).astype(x.dtype)
    return x + y.reshape(b, s, d), aux


def moe_apply(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based top-k dispatch (scatter, not one-hot einsum) with
    expert-parallel GEMMs. Returns (residual-added output, aux load loss)."""
    m = cfg.moe
    if m is None:
        raise ValueError(f"{cfg.name}: moe block requested but cfg.moe is "
                         "None")
    b, s, d = x.shape
    t = b * s
    k, e = m.top_k, m.num_experts
    if flags.MOE_GATHER_DECODE and t * k <= e:
        # decode: gather path, no capacity padding (perf opt, see §Perf)
        return moe_gather_apply(p, x, cfg)
    cap = moe_capacity(m, t)

    h = common.rms_norm(x, p["norm"], cfg.norm_eps)
    xt = h.reshape(t, d)
    xt = constrain(xt, "act_batch", "act_embed")

    logits = jnp.matmul(xt.astype(F32), p["router"])         # (T, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                   # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- slot assignment: sort copies by expert (MegaBlocks-style); the
    # slot of a copy is its rank within its expert's contiguous run.  This
    # is O(Tk log Tk) — no (Tk, E) one-hot cumsum.
    flat_e = top_i.reshape(t * k)                            # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e))       # (E,)
    slot_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    flat_slot = jnp.zeros((t * k,), jnp.int32).at[order].set(slot_sorted)
    valid = flat_slot < cap
    dump = jnp.where(valid, flat_slot, cap)                  # overflow slot

    # ---- dispatch: scatter tokens into (E, cap+1, D)
    xk = jnp.repeat(xt[:, None, :], k, axis=1).reshape(t * k, d)
    if flags.MOE_CONSTRAIN_DISPATCH:
        xk = constrain(xk, "act_batch", "act_embed")
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[flat_e, dump].set(xk, mode="drop")
    buf = buf[:, :cap]
    buf = constrain(buf, "act_expert", None, "act_embed")

    # ---- expert GEMMs (E-parallel over `model`)
    g = common.feinsum("ecd,edf->ecf", buf, p["we_gate"])
    u = common.feinsum("ecd,edf->ecf", buf, p["we_up"])
    act = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    out_e = common.feinsum("ecf,efd->ecd", act, p["we_down"])
    out_e = jnp.pad(out_e, ((0, 0), (0, 1), (0, 0)))         # dump slot = 0

    # ---- combine
    gathered = out_e[flat_e, dump]                           # (T*k, D)
    if flags.MOE_CONSTRAIN_DISPATCH:
        gathered = constrain(gathered, "act_batch", "act_embed")
    gathered = gathered * (valid[:, None] & True).astype(x.dtype)
    gathered = gathered.reshape(t, k, d)
    y = jnp.einsum("tkd,tk->td", gathered.astype(F32),
                   top_w.astype(F32)).astype(x.dtype)

    # ---- auxiliary load-balancing loss (Switch-style)
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(top_i[:, 0], e, dtype=F32)), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs) * m.router_aux_weight

    if m.num_shared_experts:
        y = y + common.swiglu(h.reshape(t, d), p["ws_gate"], p["ws_up"],
                              p["ws_down"]).astype(F32).astype(x.dtype)
    if m.dense_ff_parallel:
        y = y + common.swiglu(h.reshape(t, d), p["wd_gate"], p["wd_up"],
                              p["wd_down"]).astype(F32).astype(x.dtype)

    y = y.reshape(b, s, d)
    y = constrain(y, "act_batch", "act_seq", "act_embed")
    return x + y, aux
