"""DiT (Peebles & Xie 2023): patchified latent tokens, adaLN-zero blocks.

This is the paper's backbone. ``block_apply`` exposes single-block execution
so the FastCache runner (repro.core.runner) can gate each block with the
statistical cache test and substitute the learnable linear approximation.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import common, flags
from repro.models.attention import attention
from repro.models.params import ParamDef, abstract_params, init_params

F32 = jnp.float32


def _ln(x):
    """LayerNorm without affine params (DiT uses modulate instead)."""
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)


class DiTModel:
    def __init__(self, cfg: ModelConfig):
        if cfg.family != "dit" or cfg.dit is None:
            raise ValueError(f"DiTModel requires a dit-family config with "
                             f"cfg.dit set; got family={cfg.family!r}")
        self.cfg = cfg
        dit = cfg.dit
        self.grid = dit.image_size // dit.patch_size
        self.num_tokens = self.grid * self.grid
        self.patch_dim = dit.patch_size ** 2 * dit.in_channels
        self.out_dim = self.patch_dim * (2 if dit.learn_sigma else 1)

    # ------------------------------------------------------------------

    def _block_defs(self) -> Dict[str, ParamDef]:
        cfg = self.cfg
        d, h = cfg.d_model, cfg.num_heads
        dh = cfg.resolved_head_dim
        f = cfg.d_ff
        return {
            "ada_w": ParamDef((d, 6 * d), ("embed", None), "zeros"),
            "ada_b": ParamDef((6 * d,), (None,), "zeros"),
            "wq": ParamDef((d, h, dh), ("embed", "heads", "head_dim"), "fan_in"),
            "wk": ParamDef((d, h, dh), ("embed", "heads", "head_dim"), "fan_in"),
            "wv": ParamDef((d, h, dh), ("embed", "heads", "head_dim"), "fan_in"),
            "wo": ParamDef((h, dh, d), ("heads", "head_dim", "embed"), "fan_in"),
            "w_in": ParamDef((d, f), ("embed", "ffn"), "fan_in"),
            "b_in": ParamDef((f,), ("ffn",), "zeros"),
            "w_out": ParamDef((f, d), ("ffn", "embed"), "fan_in"),
            "b_out": ParamDef((d,), ("embed",), "zeros"),
        }

    def param_defs(self):
        cfg = self.cfg
        d = cfg.d_model
        from repro.models.layers import stack_defs
        return {
            "patch_w": ParamDef((self.patch_dim, d), (None, "embed"), "fan_in"),
            "patch_b": ParamDef((d,), ("embed",), "zeros"),
            "pos_emb": ParamDef((self.num_tokens, d), (None, "embed"),
                                "normal"),
            "t_w1": ParamDef((256, d), (None, "embed"), "fan_in"),
            "t_b1": ParamDef((d,), ("embed",), "zeros"),
            "t_w2": ParamDef((d, d), ("embed", "embed"), "fan_in"),
            "t_b2": ParamDef((d,), ("embed",), "zeros"),
            "label_emb": ParamDef((cfg.dit.num_classes + 1, d),
                                  (None, "embed"), "normal"),
            "blocks": stack_defs(self._block_defs(), cfg.num_layers),
            "final_ada_w": ParamDef((d, 2 * d), ("embed", None), "zeros"),
            "final_ada_b": ParamDef((2 * d,), (None,), "zeros"),
            "final_w": ParamDef((d, self.out_dim), ("embed", None), "zeros"),
            "final_b": ParamDef((self.out_dim,), (None,), "zeros"),
        }

    def init(self, key):
        return init_params(self.param_defs(), key, self.cfg.dtype)

    def abstract_params(self):
        return abstract_params(self.param_defs(), self.cfg.dtype)

    # ------------------------------------------------------------------

    def conditioning(self, params, t: jax.Array, labels: jax.Array):
        """(B,) timesteps + (B,) labels -> (B, D) conditioning vector."""
        temb = common.timestep_embedding(t, 256)
        temb = common.fdot(temb.astype(jnp.dtype(self.cfg.dtype)),
                           params["t_w1"]) + params["t_b1"]
        temb = jax.nn.silu(temb.astype(F32)).astype(temb.dtype)
        temb = common.fdot(temb, params["t_w2"]) + params["t_b2"]
        yemb = jnp.take(params["label_emb"], labels, axis=0)
        return temb + yemb

    def block_apply(self, bp, x: jax.Array, c: jax.Array) -> jax.Array:
        """One DiT block. x: (B,N,D); c: (B,D)."""
        cfg = self.cfg
        mod = common.fdot(jax.nn.silu(c.astype(F32)).astype(x.dtype),
                          bp["ada_w"]) + bp["ada_b"]
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        h = common.modulate(_ln(x), sh1, sc1)
        q = common.feinsum("bnd,dhk->bnhk", h, bp["wq"])
        k = common.feinsum("bnd,dhk->bnhk", h, bp["wk"])
        v = common.feinsum("bnd,dhk->bnhk", h, bp["wv"])
        pos = jnp.arange(x.shape[1])
        o = attention(q, k, v, pos, pos, causal=False)
        o = common.feinsum("bnhk,hkd->bnd", o, bp["wo"])
        x = x + g1[:, None, :] * o
        h = common.modulate(_ln(x), sh2, sc2)
        h = common.gelu_mlp(h, bp["w_in"], bp["b_in"], bp["w_out"], bp["b_out"])
        x = x + g2[:, None, :] * h
        return constrain(x, "act_batch", "act_seq", "act_embed")

    def final_layer(self, params, x: jax.Array, c: jax.Array) -> jax.Array:
        mod = common.fdot(jax.nn.silu(c.astype(F32)).astype(x.dtype),
                          params["final_ada_w"]) + params["final_ada_b"]
        sh, sc = jnp.split(mod, 2, axis=-1)
        x = common.modulate(_ln(x), sh, sc)
        return common.fdot(x, params["final_w"]) + params["final_b"]

    # ------------------------------------------------------------------

    def tokens_in(self, params, latents: jax.Array) -> jax.Array:
        """(B, Hs, Ws, C) -> (B, N, D) with positional embedding."""
        p = self.cfg.dit.patch_size
        tok = common.patchify(latents.astype(jnp.dtype(self.cfg.dtype)), p)
        x = common.fdot(tok, params["patch_w"]) + params["patch_b"]
        return x + params["pos_emb"][None]

    def apply(self, params, batch, train: bool = False):
        """batch: latents (B,Hs,Ws,C), t (B,), labels (B,). -> (eps, aux)."""
        cfg = self.cfg
        x = self.tokens_in(params, batch["latents"])
        c = self.conditioning(params, batch["t"], batch["labels"])

        def scan_body(x, bp):
            return self.block_apply(bp, x, c), None

        body = scan_body
        if train and cfg.remat:
            body = jax.checkpoint(scan_body)
        x, _ = jax.lax.scan(body, x, params["blocks"],
                            unroll=flags.scan_unroll(cfg.num_layers))
        out = self.final_layer(params, x, c)
        eps = common.unpatchify(out[..., :self.patch_dim] if
                                cfg.dit.learn_sigma else out,
                                cfg.dit.patch_size, self.grid)
        return eps, {"moe_aux": jnp.zeros((), F32)}

    def loss(self, params, batch) -> Tuple[jax.Array, dict]:
        """Denoising MSE: predict the noise added to clean latents."""
        eps_hat, _ = self.apply(params, batch, train=True)
        mse = jnp.mean(jnp.square(eps_hat.astype(F32)
                                  - batch["noise"].astype(F32)))
        return mse, {"mse": mse}
