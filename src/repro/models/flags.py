"""Trace-time flags.

UNROLL_INNER: when True, every lax.scan in the model bodies (layer stack, KV
chunk loops, SSM chunk loops, CE loss chunks) is fully unrolled.  XLA's
HloCostAnalysis counts a while-loop body ONCE regardless of trip count, so
the dry-run's cost-measurement compiles (reduced-depth variants, see
launch/dryrun.py) run with this flag to get exact FLOP/byte/collective
counts; the production-shape compile keeps rolled scans (small HLO, real
memory analysis).
"""
from __future__ import annotations

import contextlib

UNROLL_INNER = False

# Perf opt (EXPERIMENTS.md §Perf): gather-based MoE when tokens*top_k <=
# num_experts (decode) instead of capacity dispatch. Off by default so the
# paper-faithful baseline measurements stay stable.
MOE_GATHER_DECODE = False

# Perf opt: largest Sq*Skv for which attention materializes full logits;
# above it the online-softmax chunked path bounds the working set.
DIRECT_MAX_ELEMS = 4096 * 4096

# Perf opt: sharding constraints on the MoE dispatch intermediates (the
# (T*k, D) token copies and routing arrays). Without them GSPMD replicates
# the dispatch tensors (kimi train: ~120 GB bf16 per copy, per device).
MOE_CONSTRAIN_DISPATCH = False

# Perf opt: rematerialize the chunked-CE loss head in backward instead of
# saving each chunk's (B, c, V) f32 logits (qwen3-14b: ~5 GB per chunk).
CE_REMAT = False


def scan_unroll(n: int) -> int:
    """Value for lax.scan(..., unroll=...)."""
    return max(1, n) if UNROLL_INNER else 1


@contextlib.contextmanager
def unroll_inner():
    global UNROLL_INNER
    prev = UNROLL_INNER
    UNROLL_INNER = True
    try:
        yield
    finally:
        UNROLL_INNER = prev
