"""Mamba-1 selective-scan mixer (Jamba's SSM layers) [arXiv:2403.19887].

TPU adaptation: the selective scan runs chunked — an outer ``lax.scan`` over
sequence chunks carries the (B, d_inner, d_state) state; within a chunk the
diagonal recurrence ``h_t = a_t * h_{t-1} + b_t`` is a ``lax.associative_scan``
(log-depth, parallel). Single-step recurrent form for decode; the naive
recurrence is the test oracle.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, flags
from repro.models.params import ParamDef

F32 = jnp.float32


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d = cfg.d_model
    di = cfg.ssm.expand * d
    ds = cfg.ssm.d_state
    dtr = cfg.ssm.dt_rank or math.ceil(d / 16)
    return d, di, ds, dtr


def mamba_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, di, ds, dtr = _dims(cfg)
    k = cfg.ssm.d_conv
    return {
        "norm": ParamDef((d,), ("embed",), "ones", dtype="float32"),
        "w_in": ParamDef((d, 2 * di), ("embed", "inner"), "fan_in"),
        "conv_w": ParamDef((k, di), (None, "inner"), "fan_in"),
        "conv_b": ParamDef((di,), ("inner",), "zeros"),
        "w_x_proj": ParamDef((di, dtr + 2 * ds), ("inner", None), "fan_in"),
        "w_dt": ParamDef((dtr, di), (None, "inner"), "fan_in"),
        "b_dt": ParamDef((di,), ("inner",), "ones", dtype="float32"),
        "a_log": ParamDef((di, ds), ("inner", "state"), "ones", dtype="float32"),
        "d_skip": ParamDef((di,), ("inner",), "ones", dtype="float32"),
        "w_out": ParamDef((di, d), ("inner", "embed"), "fan_in",
                          scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }


def mamba_state_defs(cfg: ModelConfig, batch: int) -> Dict[str, ParamDef]:
    _, di, ds, _ = _dims(cfg)
    k = cfg.ssm.d_conv
    ab = ("act_batch",)
    return {
        "ssm": ParamDef((batch, di, ds), ab + ("act_inner", None), "zeros",
                        dtype="float32"),
        "conv": ParamDef((batch, k - 1, di), ab + (None, "act_inner"), "zeros",
                         dtype="float32"),
    }


def _ssm_params(p, xc, cfg: ModelConfig):
    """xc: (B, L, di) post-conv activations. Returns dA, dBx, C for the span."""
    _, di, ds, dtr = _dims(cfg)
    dbc = common.fdot(xc, p["w_x_proj"])                     # (B,L,dtr+2ds)
    dt_r = dbc[..., :dtr]
    b_mat = dbc[..., dtr:dtr + ds].astype(F32)               # (B,L,ds)
    c_mat = dbc[..., dtr + ds:].astype(F32)                  # (B,L,ds)
    dt = jax.nn.softplus(
        jnp.einsum("blr,ri->bli", dt_r.astype(F32), p["w_dt"]) + p["b_dt"])
    a = -jnp.exp(p["a_log"])                                 # (di,ds)
    da = jnp.exp(dt[..., None] * a)                          # (B,L,di,ds)
    dbx = (dt[..., None] * b_mat[:, :, None, :]
           * xc.astype(F32)[..., None])                      # (B,L,di,ds)
    return da, dbx, c_mat


def _chunk_scan(da, dbx, c_mat, h0):
    """Associative scan within a chunk. da/dbx: (B,L,di,ds); h0: (B,di,ds)."""
    # fold initial state into the first step
    dbx = dbx.at[:, 0].add(da[:, 0] * h0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (da, dbx), axis=1)
    y = jnp.einsum("blis,bls->bli", hs, c_mat)               # (B,L,di)
    return y, hs[:, -1]


def mamba_apply(p, x, *, cfg: ModelConfig, state: Optional[dict] = None,
                decode: bool = False, chunk: int = 256,
                ) -> Tuple[jax.Array, Optional[dict]]:
    """Pre-norm Mamba block with residual."""
    res = x
    b, s, d = x.shape
    _, di, ds, _ = _dims(cfg)
    kk = cfg.ssm.d_conv
    xn = common.rms_norm(x, p["norm"], cfg.norm_eps)
    xz = common.fdot(xn, p["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)                        # (B,S,di)

    conv_state = state["conv"] if state is not None else None
    conv_out = common.causal_conv1d(xi, p["conv_w"], conv_state) + p["conv_b"]
    new_conv = jnp.concatenate(
        [conv_state if conv_state is not None
         else jnp.zeros((b, kk - 1, di), F32), xi.astype(F32)],
        axis=1)[:, -(kk - 1):]
    xc = jax.nn.silu(conv_out.astype(F32)).astype(x.dtype)

    h0 = state["ssm"] if state is not None else jnp.zeros((b, di, ds), F32)

    if decode:
        if s != 1:
            raise ValueError(f"mamba decode step expects seq len 1, got {s}")
        da, dbx, c_mat = _ssm_params(p, xc, cfg)
        h1 = da[:, 0] * h0 + dbx[:, 0]
        y = jnp.einsum("bis,bs->bi", h1, c_mat[:, 0])[:, None]  # (B,1,di)
        h_last = h1
    else:
        cs = min(chunk, s)
        while s % cs:                                # largest divisor <= chunk
            cs -= 1
        nc = s // cs

        def step(h, xc_chunk):
            da, dbx, c_mat = _ssm_params(p, xc_chunk, cfg)
            y, h1 = _chunk_scan(da, dbx, c_mat, h)
            return h1, y

        xcs = xc.reshape(b, nc, cs, di).transpose(1, 0, 2, 3)
        h_last, ys = jax.lax.scan(step, h0, xcs,
                                  unroll=flags.scan_unroll(nc))
        y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)

    y = y + p["d_skip"] * xc.astype(F32)
    y = (y * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    out = common.fdot(y, p["w_out"])
    new_state = {"ssm": h_last, "conv": new_conv}
    return res + out, new_state
