"""Spatial-temporal token saliency + static/motion partition (Eqs. 1-3).

TPU adaptation (DESIGN.md §3): the paper's threshold split produces ragged
shapes; here the motion set has a *static capacity* C = ceil(r * N).  Tokens
are ranked by temporal saliency; the top-C that also exceed tau_s are motion,
everything else takes the learnable-linear bypass.  Capacity overflow sends
would-be-motion tokens to the *cheap* path, degrading speed never shape.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def token_saliency(x_t: jax.Array, x_prev: jax.Array) -> jax.Array:
    """Eq. 1: per-token squared L2 temporal difference. (B,N,D) -> (B,N)."""
    d = (x_t.astype(F32) - x_prev.astype(F32))
    return jnp.sum(d * d, axis=-1)


class Partition(NamedTuple):
    motion_idx: jax.Array    # (B, C) token indices, saliency-descending
    is_motion: jax.Array     # (B, N) bool — in top-C AND above tau_s
    saliency: jax.Array      # (B, N)


def partition_tokens(saliency: jax.Array, tau_s: float,
                     capacity: int) -> Partition:
    """Select motion tokens: top-`capacity` by saliency, gated by tau_s."""
    n = saliency.shape[-1]
    capacity = min(capacity, n)
    _, idx = jax.lax.top_k(saliency, capacity)              # (B, C)
    above = jnp.take_along_axis(saliency, idx, axis=-1) > tau_s
    is_motion = jnp.zeros(saliency.shape, bool).at[
        jnp.arange(saliency.shape[0])[:, None], idx].set(above)
    return Partition(motion_idx=idx, is_motion=is_motion, saliency=saliency)


def gather_motion(x: jax.Array, part: Partition) -> jax.Array:
    """(B,N,D) -> (B,C,D) motion-token stream (saliency-descending order)."""
    return jnp.take_along_axis(x, part.motion_idx[..., None], axis=1)


def scatter_motion(base: jax.Array, motion: jax.Array,
                   part: Partition) -> jax.Array:
    """Write the motion stream back over `base` at its token positions,
    but only where the tau_s gate marked the token as true motion."""
    b = base.shape[0]
    keep = jnp.take_along_axis(part.is_motion, part.motion_idx, axis=-1)
    updated = base.at[jnp.arange(b)[:, None], part.motion_idx].set(
        jnp.where(keep[..., None], motion,
                  jnp.take_along_axis(base, part.motion_idx[..., None],
                                      axis=1)))
    return updated


def motion_fraction(part: Partition) -> jax.Array:
    """Per-sample fraction of tokens marked motion. (B, N) -> (B,)."""
    return jnp.mean(part.is_motion.astype(F32), axis=-1)
