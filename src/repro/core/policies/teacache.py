"""teacache: accumulated input-change gate — skip whole steps while the
accumulated relative change of the token embeddings stays under a
threshold (TeaCache).

State: the previous step's token embeddings (the statistic's reference),
the cached eps, the per-sample change accumulator and the warm-up flag.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.core.policies.base import F32, CachePolicy, register


@register("teacache")
class TeaCache(CachePolicy):
    def __init__(self, model, fc, fc_params, *, tea_threshold: float = 0.15,
                 **kw):
        super().__init__(model, fc, fc_params, **kw)
        self.threshold = tea_threshold

    def init_state(self, batch: int) -> Dict:
        m = self.model
        dt = self._state_dtype()
        return {
            "prev_tokens_in": jnp.zeros((batch, self.n_tokens,
                                         m.cfg.d_model), dt),
            "prev_eps": jnp.zeros(self._eps_shape(batch), dt),
            "tea_acc": jnp.zeros((batch,), F32),
            "have_cache": jnp.zeros((batch,), bool),
            "stats": self.init_stats(batch),
        }

    def reset_rows(self, state, rows):
        st = dict(state)
        st["prev_tokens_in"] = state["prev_tokens_in"].at[rows].set(0.0)
        st["prev_eps"] = state["prev_eps"].at[rows].set(0.0)
        st["tea_acc"] = state["tea_acc"].at[rows].set(0.0)
        st["have_cache"] = state["have_cache"].at[rows].set(False)
        return st

    def step(self, params, state, x_in, c):
        rel = self._rel_change(x_in, state["prev_tokens_in"])
        acc = state["tea_acc"] + rel
        skip = (acc < self.threshold) & state["have_cache"]

        def store(out, st, inputs, x_out):
            out["prev_tokens_in"] = jnp.where(skip[:, None, None],
                                              st["prev_tokens_in"], x_in)

        eps, st = self.masked_step(params, state, x_in, c, skip,
                                   store=store)
        st["tea_acc"] = jnp.where(skip, acc, 0.0)
        return eps, st
