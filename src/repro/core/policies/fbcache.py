"""fbcache: first-block gate — run block 0 as a probe; if its output moved
less than ``rdt`` relative to the previous step, reuse the previous step's
model output (FBCache / ParaAttention).

State: block 0's previous output (the probe reference — NOT the full
(L+1, B, N, D) hidden stack the monolith carried), the cached eps and the
warm-up flag.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.policies.base import CachePolicy, register


@register("fbcache")
class FirstBlockCache(CachePolicy):
    def __init__(self, model, fc, fc_params, *, fb_rdt: float = 0.08, **kw):
        super().__init__(model, fc, fc_params, **kw)
        self.rdt = fb_rdt

    def init_state(self, batch: int) -> Dict:
        m = self.model
        dt = self._state_dtype()
        return {
            "prev_h1": jnp.zeros((batch, self.n_tokens, m.cfg.d_model), dt),
            "prev_eps": jnp.zeros(self._eps_shape(batch), dt),
            "have_cache": jnp.zeros((batch,), bool),
            "stats": self.init_stats(batch),
        }

    def reset_rows(self, state, rows):
        st = dict(state)
        st["prev_h1"] = state["prev_h1"].at[rows].set(0.0)
        st["prev_eps"] = state["prev_eps"].at[rows].set(0.0)
        st["have_cache"] = state["have_cache"].at[rows].set(False)
        return st

    def step(self, params, state, x_in, c):
        bp0 = jax.tree.map(lambda a: a[0], params["blocks"])
        h1 = self.model.block_apply(bp0, x_in, c)
        rel = self._rel_change(h1, state["prev_h1"])
        skip = (rel < self.rdt) & state["have_cache"]

        def store(out, st, inputs, x_out):
            # block 0's output = block 1's input (or the final output when
            # the stack is a single block)
            h1_new = inputs[1] if self.L > 1 else x_out
            out["prev_h1"] = jnp.where(skip[:, None, None], st["prev_h1"],
                                       h1_new)

        return self.masked_step(params, state, x_in, c, skip,
                                computed_on_skip=1.0, store=store)
