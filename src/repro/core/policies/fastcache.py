"""fastcache: the paper's method (Alg. 1) — STR token partition + per-block
chi^2 statistical gate + learnable linear approximation + motion-aware
blending, with per-sample block gates.

State: the previous step's token embeddings (Eq. 1 saliency reference),
the full per-block input-hidden stack (H_{t-1,l-1} of Eq. 4 — the cache
payload the linear approximators blend against), the chi^2 sliding-window
variance trackers, and the warm-up flag.  No cached eps: fastcache gates
per-block, never per-step.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import chi2, linear_approx, saliency, statcache
from repro.core.policies.base import F32, CachePolicy, register
from repro.distributed.sharding import constrain
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref


@register("fastcache")
class FastCache(CachePolicy):
    def __init__(self, model, fc, fc_params, **kw):
        super().__init__(model, fc, fc_params, **kw)
        n = self.n_tokens      # reduced grid when token compression is on
        self.capacity = max(1, int(round(fc.motion_capacity * n)))

    def init_state(self, batch: int) -> Dict:
        m = self.model
        n, d = self.n_tokens, m.cfg.d_model
        dt = self._state_dtype()
        return {
            "prev_tokens_in": jnp.zeros((batch, n, d), dt),
            "prev_hidden": jnp.zeros((self.L + 1, batch, n, d), dt),
            "gate": statcache.init_gate_state(self.L, batch),
            "have_cache": jnp.zeros((batch,), bool),
            "stats": self.init_stats(batch),
        }

    def reset_rows(self, state, rows):
        st = dict(state)
        st["prev_tokens_in"] = state["prev_tokens_in"].at[rows].set(0.0)
        st["prev_hidden"] = state["prev_hidden"].at[:, rows].set(0.0)
        st["gate"] = statcache.reset_gate_slot(state["gate"], rows)
        st["have_cache"] = state["have_cache"].at[rows].set(False)
        return st

    # -- audit plane ---------------------------------------------------

    def audit_hidden(self, state):
        """After ``step``, ``prev_hidden`` IS this step's hidden stack —
        block inputs plus the reassembled final hidden, in exactly
        ``audit_forward``'s (L+1, B, N, D) layout — so the audit plane can
        compare it against the true stack layer by layer."""
        return state["prev_hidden"]

    def predicted_error_bound(self):
        """Eq. 9 bound from the chi^2 gate: the per-step relative error the
        hypothesis test guarantees for a cached block, with the df the gate
        actually uses (motion capacity x d_model — one sample's observed
        elements, matching ``nd`` in ``_gated_step``)."""
        nd = self.capacity * self.model.cfg.d_model
        return chi2.error_bound(self.fc.alpha, nd)

    # ------------------------------------------------------------------

    def step(self, params, state, x_in, c):
        # Per-block gating needs a sample's cache payload.  All-warm
        # batches take the pure gated path; all-cold batches (the first
        # sampling step) take one full forward.  A MIXED batch — a request
        # admitted into a running serving batch — warms up the cold
        # samples with a full forward while the warm samples keep their
        # per-sample gate decisions, cache payloads and trackers (their
        # outputs and state match an admission-free run exactly).
        have = state["have_cache"]
        return jax.lax.cond(
            jnp.all(have),
            lambda s: self._gated_step(params, s, x_in, c),
            lambda s: jax.lax.cond(
                jnp.any(have),
                lambda s2: self._mixed_step(params, s2, x_in, c, have),
                lambda s2: self._cold_step(params, s2, x_in, c),
                s),
            state)

    def _cold_step(self, params, state, x_in, c):
        """Warm-up: one full forward installing the cache payload (the STR
        static bypass is only valid against a real payload)."""
        x_out, inputs = self._full_forward(params, x_in, c)
        hidden = jnp.concatenate([inputs, x_out[None]], axis=0)
        eps = self._eps(params, x_out, c)
        st = dict(state)
        st["prev_tokens_in"] = x_in
        st["prev_hidden"] = hidden
        st["have_cache"] = jnp.ones_like(state["have_cache"])
        stats = dict(st["stats"])
        stats["blocks_computed"] = stats["blocks_computed"] + float(self.L)
        stats["motion_frac_sum"] = stats["motion_frac_sum"] + 1.0
        st["stats"] = stats
        return eps, st

    # ------------------------------------------------------------------
    # FastCache proper (Alg. 1), per-sample block gates
    # ------------------------------------------------------------------

    def _gated_step(self, params, state, x_in, c):
        fc = self.fc
        fcp = self.fc_params
        b, n, d = x_in.shape

        # ---- STR: token partition (Eqs. 1-2), per-sample
        if fc.use_str:
            sal = saliency.token_saliency(x_in, state["prev_tokens_in"])
            part = saliency.partition_tokens(sal, fc.motion_threshold,
                                             self.capacity)
        else:
            sal = jnp.full((b, n), jnp.inf, F32)
            part = saliency.partition_tokens(sal, -1.0, n)
        mfrac = saliency.motion_fraction(part)               # (B,)

        # ---- static bypass (Eq. 3) + MB blend with previous final hidden
        h_static = linear_approx.apply_linear(fcp["W_c"], fcp["b_c"], x_in)
        if fc.use_mb:
            h_static = linear_approx.blend(h_static,
                                           state["prev_hidden"][-1],
                                           fc.blend_gamma)

        # ---- motion stream through gated blocks
        xm = saliency.gather_motion(x_in, part)              # (B,C,D)
        gate = state["gate"]
        # df of the chi^2 statistic = observed elements of ONE sample
        # (static at trace time; the paper's ND with the motion capacity
        # applied)
        nd = int(xm.shape[1] * xm.shape[2])
        threshold = statcache.make_threshold(fc.alpha, nd)
        if self.gate_mode == "global":
            threshold_g = statcache.make_threshold(fc.alpha, nd * b)
        use_sc = bool(fc.use_sc)

        def body(carry, xs):
            xm, sig, ini, comp, skip = carry
            bp, w_l, b_l, prev_in, prev_out, lidx = xs
            prev_m = saliency.gather_motion(prev_in, part)
            prev_om = saliency.gather_motion(prev_out, part)
            eligible = ini[lidx] & use_sc                    # (B,)

            if self.gate_mode == "global":
                diff, prevsq = statcache.delta_stats_per_sample(xm, prev_m)
                do_cache = jnp.broadcast_to(
                    statcache.gate_decision_global(diff, sig[lidx], nd * b,
                                                   threshold_g)
                    & jnp.all(eligible), (b,))
                approx = linear_approx.apply_linear(w_l, b_l, xm)
                if fc.use_mb:
                    approx = linear_approx.blend(approx, prev_om,
                                                 fc.blend_gamma)
                out = jnp.where(do_cache[:, None, None], approx, xm)
            elif self.use_fused:
                out, do_cache, diff, prevsq = kernel_ops.fused_gate(
                    xm, prev_m, prev_om, w_l, b_l, sig[lidx], eligible,
                    threshold=threshold, gamma=fc.blend_gamma,
                    use_blend=fc.use_mb)
            else:
                out, do_cache, diff, prevsq = kernel_ref.fused_gate(
                    xm, prev_m, prev_om, w_l, b_l, sig[lidx], eligible,
                    threshold=threshold, gamma=fc.blend_gamma,
                    use_blend=fc.use_mb)

            # skip the MXU block entirely when every sample caches;
            # otherwise compute it once for the batch and keep cached
            # samples' approx
            xm_new = jax.lax.cond(
                jnp.all(do_cache),
                lambda ops_: ops_[0],
                lambda ops_: jnp.where(do_cache[:, None, None], ops_[0],
                                       self.model.block_apply(bp, ops_[1],
                                                              c)),
                (out, xm))
            # keep the motion-stream carry on its slot shards (serving
            # runs this scan under a (data, model) mesh; without the
            # constraint GSPMD is free to gather the carry onto one device
            # per layer)
            xm_new = constrain(xm_new, "act_batch", "act_seq", "act_embed")
            # sliding-window variance tracker updates on recompute,
            # per-sample
            new_sig, _ = statcache.update_sigma(
                sig[lidx], ini[lidx], diff, nd, fc.background_momentum)
            sig = sig.at[lidx].set(jnp.where(do_cache, sig[lidx], new_sig))
            ini = ini.at[lidx].set(jnp.ones_like(ini[lidx]))
            dc = do_cache.astype(F32)
            comp = comp + (1.0 - dc)
            skip = skip + dc
            # cache payload: this block's input scattered over prev grid
            new_prev_in = saliency.scatter_motion(prev_in, xm, part)
            return (xm_new, sig, ini, comp, skip), new_prev_in

        lidx = jnp.arange(self.L)
        prev_in_stack = state["prev_hidden"][:-1]            # (L,B,N,D)
        prev_out_stack = state["prev_hidden"][1:]            # (L,B,N,D)
        carry0 = (xm, gate.sigma2, gate.initialized,
                  jnp.zeros((b,), F32), jnp.zeros((b,), F32))
        (xm, sig, ini, comp, skip), new_prev_in = jax.lax.scan(
            body, carry0,
            (params["blocks"], fcp["W_l"], fcp["b_l"], prev_in_stack,
             prev_out_stack, lidx))

        # ---- reassemble full grid (concat of Eq. 2 sets)
        h_final = saliency.scatter_motion(h_static, xm, part)
        eps = self._eps(params, h_final, c)

        st = dict(state)
        st["prev_tokens_in"] = x_in
        st["prev_hidden"] = jnp.concatenate([new_prev_in, h_final[None]], 0)
        st["gate"] = statcache.GateState(sigma2=sig, initialized=ini)
        stats = dict(st["stats"])
        stats["blocks_computed"] = stats["blocks_computed"] + comp
        stats["blocks_skipped"] = stats["blocks_skipped"] + skip
        stats["motion_frac_sum"] = stats["motion_frac_sum"] + mfrac
        st["stats"] = stats
        return eps, st

    def _mixed_step(self, params, state, x_in, c, have):
        """Mixed warm/cold batch (a request admitted mid-flight): cold
        samples take a full forward (their warm-up step), warm samples take
        the gated fastcache path.  Results and state are selected
        per-sample, so a warm sample's outputs, cache payload, variance
        trackers and stats are bit-identical to a run where the admission
        never happened, and a cold sample's match its own solo warm-up
        step."""
        warm = have                                          # (B,)
        x_out, inputs = self._full_forward(params, x_in, c)
        hidden = jnp.concatenate([inputs, x_out[None]], axis=0)
        eps_full = self._eps(params, x_out, c)
        eps_fc, st_fc = self._gated_step(params, state, x_in, c)

        w3 = warm[:, None, None]
        w4 = warm[:, None, None, None]
        eps = jnp.where(w4, eps_fc, eps_full.astype(eps_fc.dtype))
        st = dict(st_fc)
        st["prev_tokens_in"] = jnp.where(w3, st_fc["prev_tokens_in"], x_in)
        st["prev_hidden"] = jnp.where(
            warm[None, :, None, None], st_fc["prev_hidden"],
            hidden.astype(st_fc["prev_hidden"].dtype))
        # cold samples' warm-up leaves the gate untouched (matching
        # _cold_step): trackers first observe a delta on the NEXT step,
        # against the real payload installed here
        st["gate"] = statcache.GateState(
            sigma2=jnp.where(warm[None, :], st_fc["gate"].sigma2,
                             state["gate"].sigma2),
            initialized=jnp.where(warm[None, :], st_fc["gate"].initialized,
                                  state["gate"].initialized))
        st["have_cache"] = jnp.ones_like(have)
        old = state["stats"]
        stats = dict(st_fc["stats"])
        stats["blocks_computed"] = jnp.where(
            warm, stats["blocks_computed"], old["blocks_computed"] + self.L)
        for k in ("blocks_skipped", "steps_reused"):
            stats[k] = jnp.where(warm, stats[k], old[k])
        stats["motion_frac_sum"] = jnp.where(
            warm, stats["motion_frac_sum"], old["motion_frac_sum"] + 1.0)
        st["stats"] = stats
        return eps, st
