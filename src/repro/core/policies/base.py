"""The CachePolicy plugin protocol, registry, and shared machinery.

A *cache policy* is one method for skipping DiT compute across denoising
steps (the paper's FastCache, or one of the baselines it compares against).
Each policy lives in its own module under ``core/policies/``, registers
itself by name, and owns a **minimal, policy-specific state pytree** — a
dict of arrays whose batch rows are the serving slots.  ``CachedDiT``
(core/runner.py) is a thin shell that resolves a policy from the registry
and forwards to it; the serving engines and the sharding walker treat the
state as an opaque pytree, so a new policy module is the ONLY file a new
cache method needs.

Protocol (all four methods must be jit-compatible):

  init_state(batch) -> dict
      Allocate the policy's state for ``batch`` samples.  Only this
      policy's buffers — plus the standard ``stats`` block (see
      ``init_stats``) that the engines and ``summarize_stats`` consume.
  reset_rows(state, rows) -> dict
      Re-arm the given sample rows (an int or index array — e.g. a serving
      slot's CFG cond/uncond pair) for a new request without disturbing
      batchmates.  Stats stay cumulative (engine-lifetime counters).
  snapshot_rows(state, rows) -> dict
      The preemption half of the contract: extract the given sample rows
      into a same-treedef pytree (per-slot leaves row-sliced, replicated
      leaves passed through) — what the serving engines checkpoint when a
      half-denoised request is preempted.  The generic base implementation
      walks the state with the sharding walker's ``_slot_axis`` rank rule,
      so policies only override it when their state breaks that rule.
  restore_rows(state, snap, rows) -> dict
      Scatter a snapshot back into the given rows of a live state —
      re-admission after requeue rarely lands in the donor slot, so
      ``rows`` at restore time may differ from the snapshot's.  Must be
      bitwise: ``restore_rows(state, snapshot_rows(state, rows), rows)``
      is the identity (reprolint's policy-contract check enforces treedef/
      shape/dtype preservation plus this round-trip).  Replicated leaves
      keep the LIVE value — engine-global scalars are not rewound.
  step(params, state, x_in, c) -> (eps, state)
      One denoising-model evaluation: ``x_in`` (B, N, D) are the patch
      tokens, ``c`` the per-sample conditioning.  Every data-dependent
      cache decision must be per-sample ((B,) gates + ``jnp.where``
      masking) so one sample never disturbs a batchmate — the serving
      engines' bitwise mid-flight-admission contract rests on this.
  stats(state) -> dict
      Host-side summary; the default forwards to ``summarize_stats``.

State-pytree contract with the engines / sharding walker:

  - the sample-batch dim is either the LEADING axis of a leaf, or — for
    layer-stacked trackers — axis 1 behind a leading axis of extent
    ``num_layers`` or ``num_layers + 1`` (``serve_state_specs`` in
    distributed/sharding.py uses exactly this rank rule to shard slot rows
    over the mesh ``data`` axis; anything else replicates);
  - ``state["stats"]`` holds per-sample ``(B,)`` float32 counters; every
    key present is accumulated per-request by the serving engines.  The
    standard keys are ``blocks_computed / blocks_skipped / steps_reused /
    motion_frac_sum`` plus the scalar ``steps`` (bumped by the
    ``CachedDiT`` shell, not by policies);
  - arrays only — the engines donate the whole pytree buffer-for-buffer;
  - ``tokred`` is RESERVED: when the token-compression stage is on,
    ``CachedDiT`` rides the TokenReducer's per-sample rows (previous
    full-resolution tokens + warm flag; core/token_reduce.py) under that
    key of the same state dict — policies must pass unknown keys through
    untouched (every ``dict(state)`` copy-through does), and the stats
    block gains the (B,) ``tokens_kept / tokens_merged`` counters.

Registering:

    from repro.core.policies.base import CachePolicy, register

    @register("mycache")
    class MyCache(CachePolicy):
        ...

Import the module from ``core/policies/__init__.py`` (registration import
order defines the ``POLICIES`` tuple order).  Constructor knobs arrive via
``CachedDiT(..., **policy_kwargs)``; every policy receives the full kwarg
set and keeps what it knows (unknown keys are ignored, so policies can
coexist without sharing a signature).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core import statcache
from repro.distributed.sharding import _slot_axis
from repro.models.dit import DiTModel

F32 = jnp.float32

# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, Type["CachePolicy"]] = {}


def register(name: str) -> Callable[[Type["CachePolicy"]],
                                    Type["CachePolicy"]]:
    """Class decorator: register a CachePolicy under ``name``."""
    def deco(cls: Type["CachePolicy"]) -> Type["CachePolicy"]:
        if name in _REGISTRY and _REGISTRY[name] is not cls:
            raise ValueError(f"cache policy {name!r} already registered "
                             f"({_REGISTRY[name].__qualname__})")
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def registered_policies() -> Tuple[str, ...]:
    """Names of all registered policies, in registration order.  This IS
    the source of ``repro.core.POLICIES`` — the tuple cannot drift from the
    registry because it is derived from it on access."""
    return tuple(_REGISTRY)


def get_policy_class(name: str) -> Type["CachePolicy"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown cache policy {name!r}; registered policies: "
            f"{', '.join(registered_policies()) or '(none)'}") from None


# --------------------------------------------------------------------------
# Base class: shared DiT plumbing + the step-level masked-step helper
# --------------------------------------------------------------------------

class CachePolicy:
    """Base class for cache policies.  Holds the host model and FastCache
    config and provides the shared forward/eps/statistics helpers; see the
    module docstring for the protocol and the state-pytree contract."""

    name: str = ""

    def __init__(self, model: DiTModel, fc, fc_params, *,
                 gate_mode: str = "per_sample", use_fused: bool = False,
                 token_reducer=None, **_unused):
        self.model = model
        self.fc = fc
        self.fc_params = fc_params
        self.gate_mode = gate_mode
        self.use_fused = use_fused
        self.L = model.cfg.num_layers
        # token-compression stage (core/token_reduce.py): when CachedDiT
        # hands a reducer in, the policy's whole transformer path runs on
        # the statically reduced grid — policies size their token-axis
        # buffers with ``self.n_tokens`` and everything else composes
        # untouched (``_eps`` unmerges back to full resolution, so cached
        # eps / image-space buffers never see the reduced grid)
        self.reducer = token_reducer
        self.n_tokens = (token_reducer.reduced_tokens
                         if token_reducer is not None else model.num_tokens)

    # -- protocol ------------------------------------------------------

    def init_state(self, batch: int) -> Dict:
        raise NotImplementedError

    def reset_rows(self, state: Dict, rows) -> Dict:
        """Default: nothing policy-specific to re-arm (stateless policies
        like nocache/l2c)."""
        return dict(state)

    def snapshot_rows(self, state: Dict, rows) -> Dict:
        """Extract ``rows`` into a same-treedef snapshot (the preemption
        checkpoint).  Generic: every leaf whose shape carries the sample
        batch under the ``_slot_axis`` rank rule is row-sliced along that
        axis; replicated leaves (the scalar ``steps``, global trackers)
        pass through so the treedef — which the engines' jitted restore
        programs are traced against — never changes shape."""
        batch = self._state_batch(state)

        def take(leaf):
            axis = _slot_axis(jnp.shape(leaf), batch, self.L)
            return leaf if axis is None else jnp.take(leaf, rows, axis=axis)

        return jax.tree.map(take, state)

    def restore_rows(self, state: Dict, snap: Dict, rows) -> Dict:
        """Scatter a ``snapshot_rows`` pytree back into ``rows`` of a live
        state.  Per-slot leaves are written bitwise; replicated leaves keep
        the LIVE value (engine-global scalars like ``stats["steps"]`` are
        not rewound to preemption time — they are engine-lifetime, not
        request-scoped)."""
        batch = self._state_batch(state)

        def put(leaf, sleaf):
            axis = _slot_axis(jnp.shape(leaf), batch, self.L)
            if axis is None:
                return leaf
            if axis == 0:
                return leaf.at[rows].set(sleaf)
            return leaf.at[:, rows].set(sleaf)

        return jax.tree.map(put, state, snap)

    def step(self, params, state: Dict, x_in: jax.Array, c
             ) -> Tuple[jax.Array, Dict]:
        raise NotImplementedError

    def stats(self, state: Dict) -> Dict[str, float]:
        return summarize_stats(state)

    # -- shared state pieces -------------------------------------------

    def init_stats(self, batch: int) -> Dict[str, jax.Array]:
        """The standard per-sample stat accumulators every policy carries
        (the serving engines accumulate every (B,) key per request).  With
        an active TokenReducer the merge stage's token counters join the
        set — (B,) like every stat key, so the engines' per-request
        accumulation and the obs token counters pick them up with no
        policy or engine edits."""
        out = {
            "blocks_computed": jnp.zeros((batch,), F32),
            "blocks_skipped": jnp.zeros((batch,), F32),
            "steps_reused": jnp.zeros((batch,), F32),
            "motion_frac_sum": jnp.zeros((batch,), F32),
            "steps": jnp.zeros((), F32),
        }
        if self.reducer is not None:
            out["tokens_kept"] = jnp.zeros((batch,), F32)
            out["tokens_merged"] = jnp.zeros((batch,), F32)
        return out

    def _state_batch(self, state: Dict) -> int:
        """The state's sample-row count, read off the mandatory ``stats``
        block (its (B,) per-sample counters are part of the contract) —
        the anchor the generic snapshot/restore walkers classify every
        other leaf against."""
        for k, v in state.get("stats", {}).items():
            if k != "steps" and jnp.ndim(v) == 1:
                return int(jnp.shape(v)[0])
        raise ValueError(
            f"policy {self.name or type(self).__name__!r}: state carries no "
            "(B,) stats counter to infer the sample batch from — override "
            "snapshot_rows/restore_rows or add a per-sample stats key")

    def _state_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.model.cfg.dtype)

    def _eps_shape(self, batch: int) -> Tuple[int, ...]:
        cfg = self.model.cfg
        img = cfg.dit.image_size
        return (batch, img, img, cfg.dit.in_channels)

    # -- shared forward helpers ----------------------------------------

    def _full_forward(self, params, x, c):
        """Full block-stack forward.  Returns ``(x_out, inputs)`` where
        ``inputs`` (L, B, N, D) stacks each block's input (``inputs[l]`` is
        block l's input; block l's output is ``inputs[l+1]``, and the final
        output is ``x_out``)."""
        def body(x, bp):
            return self.model.block_apply(bp, x, c), x

        x_out, inputs = jax.lax.scan(body, x, params["blocks"])
        return x_out, inputs

    def _eps(self, params, hidden_final, c) -> jax.Array:
        # token-compression unmerge: a reduced-grid hidden (the cached
        # path under an active TokenReducer) is scattered back to full
        # resolution before the final layer; full-resolution hiddens
        # (merge off, or the audit plane's shadow forward) pass through —
        # the dispatch is on static shape, so both cases stay one trace
        # each with no runtime branching
        if (self.reducer is not None
                and hidden_final.shape[-2] != self.model.num_tokens):
            hidden_final = self.reducer.unmerge(hidden_final)
        out = self.model.final_layer(params, hidden_final, c)
        p = self.model.cfg.dit.patch_size
        from repro.models.common import unpatchify
        return unpatchify(out[..., :self.model.patch_dim], p,
                          self.model.grid)

    # -- audit plane (obs.audit) ---------------------------------------

    def audit_forward(self, params, x_in: jax.Array, c
                      ) -> Tuple[jax.Array, jax.Array]:
        """The full-forward twin the shadow-compute audit plane runs
        alongside the cached path: an uncached evaluation of the SAME
        inputs, returning ``(eps_true, hidden)`` where ``hidden``
        (L+1, B, N, D) stacks each block's input plus the final hidden —
        the layout ``audit_hidden`` mirrors, so per-layer cached-vs-true
        errors compare like with like.  Stateless and side-effect-free:
        it must never touch the policy's cache payloads or counters."""
        x_out, inputs = self._full_forward(params, x_in, c)
        hidden = jnp.concatenate([inputs, x_out[None]], axis=0)
        return self._eps(params, x_out, c), hidden

    def audit_hidden(self, state: Dict) -> Optional[jax.Array]:
        """The per-layer hidden stack the cached path produced this step,
        (L+1, B, N, D) in ``audit_forward``'s layout — or None when the
        policy keeps no such payload (step-level policies cache eps, not
        hiddens).  None statically disables the audit plane's per-layer
        error accumulation for this policy; end-to-end eps error is
        always audited."""
        return None

    def predicted_error_bound(self) -> Optional[float]:
        """The per-step relative approximation error this policy claims
        for its cached outputs, or None for policies that make no bound
        claim (None never trips ``bound_violations_total``).  FastCache
        derives it from the chi^2 gate (Eq. 9); see ``core/chi2.py``."""
        return None

    def _rel_change(self, x: jax.Array, prev: jax.Array) -> jax.Array:
        """Per-sample relative Frobenius change, (B,).  In global mode the
        statistic is reduced over the batch and broadcast."""
        diff, prevsq = statcache.delta_stats_per_sample(x, prev)
        if self.gate_mode == "global":
            rel = jnp.sqrt(jnp.sum(diff)
                           / jnp.maximum(jnp.sum(prevsq), 1e-12))
            return jnp.broadcast_to(rel, diff.shape)
        return jnp.sqrt(diff / jnp.maximum(prevsq, 1e-12))

    # -- step-level gate core ------------------------------------------

    def masked_step(self, params, state: Dict, x_in: jax.Array, c,
                    skip: jax.Array, *, computed_on_skip: float = 0.0,
                    store: Optional[Callable] = None
                    ) -> Tuple[jax.Array, Dict]:
        """One step under a per-sample step-level gate, for policies that
        reuse the previous step's model output (``state["prev_eps"]``).
        ``skip`` (B,) bool: True reuses that sample's cached eps and leaves
        its cache payload untouched; False recomputes and refreshes it.
        The block stack only runs when at least one sample recomputes.
        ``computed_on_skip`` counts probe blocks (fbcache's block 0)
        charged to skipped samples.  ``store(out, st, inputs, x_out)``
        writes the policy's own payloads into the ``out`` state dict on the
        recompute path (must mask with ``skip`` itself)."""
        def reuse_all(st):
            return st["prev_eps"].astype(F32).astype(x_in.dtype), dict(st)

        def mixed(st):
            x_out, inputs = self._full_forward(params, x_in, c)
            eps = self._eps(params, x_out, c)
            out = dict(st)
            if store is not None:
                store(out, st, inputs, x_out)
            eps_sel = jnp.where(skip[:, None, None, None],
                                st["prev_eps"].astype(eps.dtype), eps)
            out["prev_eps"] = eps_sel.astype(st["prev_eps"].dtype)
            return eps_sel, out

        eps, st = jax.lax.cond(jnp.all(skip), reuse_all, mixed, state)
        st["have_cache"] = jnp.ones_like(state["have_cache"])
        skf = skip.astype(F32)
        stats = dict(st["stats"])
        stats["blocks_computed"] = (stats["blocks_computed"]
                                    + (1.0 - skf) * self.L
                                    + skf * computed_on_skip)
        stats["blocks_skipped"] = (stats["blocks_skipped"]
                                   + skf * (self.L - computed_on_skip))
        stats["steps_reused"] = stats["steps_reused"] + skf
        stats["motion_frac_sum"] = stats["motion_frac_sum"] + (1.0 - skf)
        st["stats"] = stats
        return eps, st


# --------------------------------------------------------------------------
# Host-side stats summary (tolerant: any policy's stats pytree)
# --------------------------------------------------------------------------

def summarize_stats(state) -> Dict[str, float]:
    """Batch-mean view of the (batch,) per-sample accumulators, so the
    reported numbers stay in per-sample units (steps reused per sample,
    blocks skipped per sample, ...) regardless of batch size.  The raw
    per-sample counts are under ``per_sample``.

    Tolerant of any policy's state pytree: counters a policy does not
    carry read as 0.0 rather than raising (the plugin API makes the stats
    block policy-owned; only the keys a policy tracks exist)."""
    s = state.get("stats", {})

    def mean(k):
        v = s.get(k)
        return 0.0 if v is None else float(jnp.mean(jnp.asarray(v, F32)))

    steps = float(s.get("steps", 0.0))
    computed = mean("blocks_computed")
    skipped = mean("blocks_skipped")
    reused = mean("steps_reused")
    total = computed + skipped
    out = {
        "steps": steps,
        "steps_reused": reused,
        "blocks_computed": computed,
        "blocks_skipped": skipped,
        "block_cache_ratio": skipped / total if total else 0.0,
        "mean_motion_fraction": (mean("motion_frac_sum")
                                 / max(1.0, steps - reused)),
    }
    per_sample_keys = [k for k in ("blocks_computed", "blocks_skipped",
                                   "steps_reused", "motion_frac_sum")
                       if jnp.ndim(s.get(k, 0.0))]
    if per_sample_keys:
        out["per_sample"] = {
            k: [float(v) for v in jnp.asarray(s[k])]
            for k in per_sample_keys}
    return out
