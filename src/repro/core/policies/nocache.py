"""nocache: full compute every step (the exact reference sampler).

Carries no cache state at all — just the standard stats block.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.policies.base import CachePolicy, register


@register("nocache")
class NoCache(CachePolicy):
    def init_state(self, batch: int) -> Dict:
        return {"stats": self.init_stats(batch)}

    def step(self, params, state, x_in, c):
        x_out, _ = self._full_forward(params, x_in, c)
        eps = self._eps(params, x_out, c)
        st = dict(state)
        stats = dict(st["stats"])
        stats["blocks_computed"] = stats["blocks_computed"] + float(self.L)
        stats["motion_frac_sum"] = stats["motion_frac_sum"] + 1.0
        st["stats"] = stats
        return eps, st
