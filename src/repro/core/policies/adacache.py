"""adacache: content-adaptive step-skip schedule — the input distance picks
a skip budget (large change: recompute now; small change: coast for the
next few steps on the cached output) (AdaCache).

State: the previous step's token embeddings, the cached eps, the per-sample
remaining-skip budget and the warm-up flag.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from repro.core.policies.base import CachePolicy, register


@register("adacache")
class AdaCache(CachePolicy):
    def __init__(self, model, fc, fc_params, *,
                 ada_thresholds: Tuple[float, float] = (0.05, 0.15), **kw):
        super().__init__(model, fc, fc_params, **kw)
        self.thresholds = ada_thresholds

    def init_state(self, batch: int) -> Dict:
        m = self.model
        dt = self._state_dtype()
        return {
            "prev_tokens_in": jnp.zeros((batch, self.n_tokens,
                                         m.cfg.d_model), dt),
            "prev_eps": jnp.zeros(self._eps_shape(batch), dt),
            "ada_skip_left": jnp.zeros((batch,), jnp.int32),
            "have_cache": jnp.zeros((batch,), bool),
            "stats": self.init_stats(batch),
        }

    def reset_rows(self, state, rows):
        st = dict(state)
        st["prev_tokens_in"] = state["prev_tokens_in"].at[rows].set(0.0)
        st["prev_eps"] = state["prev_eps"].at[rows].set(0.0)
        st["ada_skip_left"] = state["ada_skip_left"].at[rows].set(0)
        st["have_cache"] = state["have_cache"].at[rows].set(False)
        return st

    def step(self, params, state, x_in, c):
        rel = self._rel_change(x_in, state["prev_tokens_in"])
        lo, hi = self.thresholds
        budget = jnp.where(rel < lo, 3, jnp.where(rel < hi, 1, 0))
        skip = (state["ada_skip_left"] > 0) & state["have_cache"]

        def store(out, st, inputs, x_out):
            out["prev_tokens_in"] = jnp.where(skip[:, None, None],
                                              st["prev_tokens_in"], x_in)

        eps, st = self.masked_step(params, state, x_in, c, skip,
                                   store=store)
        st["ada_skip_left"] = jnp.where(
            skip, state["ada_skip_left"] - 1, budget).astype(jnp.int32)
        return eps, st
