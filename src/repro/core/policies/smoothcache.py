"""smoothcache: precomputed layer-schedule caching (SmoothCache-style).

SmoothCache observes that a DiT layer's output changes smoothly over
adjacent denoising steps, calibrates per-layer per-step representation
errors offline, and precomputes a *schedule* of (layer, step) pairs whose
block output can be replaced by reusing the layer's cached **residual**
(output minus input) from its last computed step.  At serve time the gate
is a pure table lookup — no statistics, no thresholds.

This policy is the plugin API's front-door proof: it was added as one new
module (registered here, imported from ``core/policies/__init__.py``) and
runs through the sampler, both serving engines and the sharded state
walker without a single edit to ``serving/`` or ``distributed/sharding.py``.

State: the per-layer cached residuals (L, B, N, D), a per-sample step
counter (the schedule position — per-request, so serving slots admitted
mid-flight index the schedule from THEIR step 0) and the warm-up flag.

Construct via the front door::

    CachedDiT(model, fc, policy="smoothcache",
              smooth_schedule=smooth_schedule_from_errors(errors, 0.03))

``smooth_schedule`` is an (L, T) bool table — True at (l, s) reuses layer
l's cached residual on that sample's step s.  Steps beyond T clamp to the
last column.  The default reuses every layer on every other step (a 50%
block-cache ratio), which is SmoothCache's uniform-interval baseline.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.policies.base import F32, CachePolicy, register
from repro.distributed.sharding import constrain

DEFAULT_TABLE_STEPS = 1000


def default_smooth_schedule(num_layers: int, *, interval: int = 2,
                            table_steps: int = DEFAULT_TABLE_STEPS
                            ) -> jax.Array:
    """Uniform-interval schedule: every layer recomputes on step s when
    ``s % interval == 0`` and reuses its cached residual otherwise."""
    s = jnp.arange(table_steps)
    return jnp.broadcast_to(s % interval != 0, (num_layers, table_steps))


def smooth_schedule_from_errors(errors, threshold: float) -> jax.Array:
    """SmoothCache's calibration: ``errors`` (L, T) holds the relative
    change of layer l's output between steps s-1 and s measured on a
    calibration run; (l, s) is cacheable when the observed change stays
    under ``threshold``.  Column 0 always computes (nothing cached yet)."""
    sched = jnp.asarray(errors) < threshold
    return sched.at[:, 0].set(False)


@register("smoothcache")
class SmoothCache(CachePolicy):
    def __init__(self, model, fc, fc_params, *,
                 smooth_schedule: Optional[jax.Array] = None, **kw):
        super().__init__(model, fc, fc_params, **kw)
        self.schedule = (jnp.asarray(smooth_schedule, bool)
                         if smooth_schedule is not None
                         else default_smooth_schedule(self.L))
        if self.schedule.shape[0] != self.L:
            raise ValueError(
                f"smooth_schedule has {self.schedule.shape[0]} layer rows; "
                f"model has {self.L} layers")

    def init_state(self, batch: int) -> Dict:
        m = self.model
        return {
            "prev_delta": jnp.zeros((self.L, batch, self.n_tokens,
                                     m.cfg.d_model), self._state_dtype()),
            "step_count": jnp.zeros((batch,), jnp.int32),
            "have_cache": jnp.zeros((batch,), bool),
            "stats": self.init_stats(batch),
        }

    def reset_rows(self, state, rows):
        st = dict(state)
        st["prev_delta"] = state["prev_delta"].at[:, rows].set(0.0)
        st["step_count"] = state["step_count"].at[rows].set(0)
        st["have_cache"] = state["have_cache"].at[rows].set(False)
        return st

    def step(self, params, state, x_in, c):
        b = x_in.shape[0]
        have = state["have_cache"]                           # (B,)
        pos = jnp.clip(state["step_count"], 0,
                       self.schedule.shape[1] - 1)
        mask = self.schedule[:, pos]                         # (L, B)

        def body(carry, xs):
            x, comp, skip = carry
            bp, delta_prev, m_l = xs
            skip_l = m_l & have                              # (B,)
            reuse = x + delta_prev
            # skip the block entirely when every sample reuses; a mixed
            # batch computes it once and keeps reusing samples' residual
            # sum (bitwise-equal to the all-skip branch for those samples)
            x_new = jax.lax.cond(
                jnp.all(skip_l),
                lambda ops_: ops_[0],
                lambda ops_: jnp.where(skip_l[:, None, None], ops_[0],
                                       self.model.block_apply(bp, ops_[1],
                                                              c)),
                (reuse, x))
            x_new = constrain(x_new, "act_batch", "act_seq", "act_embed")
            delta_new = jnp.where(skip_l[:, None, None], delta_prev,
                                  x_new - x)
            sk = skip_l.astype(F32)
            return (x_new, comp + (1.0 - sk), skip + sk), delta_new

        (x_out, comp, skip), new_delta = jax.lax.scan(
            body, (x_in, jnp.zeros((b,), F32), jnp.zeros((b,), F32)),
            (params["blocks"], state["prev_delta"], mask))
        eps = self._eps(params, x_out, c)

        st = dict(state)
        st["prev_delta"] = new_delta
        st["step_count"] = state["step_count"] + 1
        st["have_cache"] = jnp.ones_like(have)
        stats = dict(st["stats"])
        stats["blocks_computed"] = stats["blocks_computed"] + comp
        stats["blocks_skipped"] = stats["blocks_skipped"] + skip
        stats["motion_frac_sum"] = stats["motion_frac_sum"] + 1.0
        st["stats"] = stats
        return eps, st
