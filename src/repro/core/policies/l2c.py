"""l2c: learned static layer subset replaced by linear approximations
(Learning-to-Cache, offline-calibrated mask).

The mask is static (calibrated offline via ``l2c_mask_from_deltas``), so
the policy carries no cache state at all — masked blocks are *replaced* by
their linear approximators every step, nothing is reused across steps.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import linear_approx
from repro.core.policies.base import F32, CachePolicy, register
from repro.distributed.sharding import constrain


@register("l2c")
class LearnedLayerCache(CachePolicy):
    def __init__(self, model, fc, fc_params, *,
                 l2c_mask: Optional[jax.Array] = None, **kw):
        super().__init__(model, fc, fc_params, **kw)
        self.mask = (l2c_mask if l2c_mask is not None
                     else jnp.zeros((self.L,), bool))

    def init_state(self, batch: int) -> Dict:
        return {"stats": self.init_stats(batch)}

    def step(self, params, state, x_in, c):
        fcp = self.fc_params

        def body(carry, xs):
            x, comp, skip = carry
            bp, w_l, b_l, masked = xs

            x_new = jax.lax.cond(
                masked,
                lambda x: linear_approx.apply_linear(w_l, b_l, x),
                lambda x: self.model.block_apply(bp, x, c), x)
            x_new = constrain(x_new, "act_batch", "act_seq", "act_embed")
            comp = comp + jnp.where(masked, 0.0, 1.0)
            skip = skip + jnp.where(masked, 1.0, 0.0)
            return (x_new, comp, skip), None

        (x_out, comp, skip), _ = jax.lax.scan(
            body, (x_in, jnp.zeros((), F32), jnp.zeros((), F32)),
            (params["blocks"], fcp["W_l"], fcp["b_l"], self.mask))
        eps = self._eps(params, x_out, c)
        st = dict(state)
        stats = dict(st["stats"])
        stats["blocks_computed"] = stats["blocks_computed"] + comp
        stats["blocks_skipped"] = stats["blocks_skipped"] + skip
        stats["motion_frac_sum"] = stats["motion_frac_sum"] + 1.0
        st["stats"] = stats
        return eps, st


def l2c_mask_from_deltas(deltas: jax.Array, n_skip: int) -> jax.Array:
    """Learning-to-Cache proxy: skip the n layers whose outputs move the
    residual stream least (offline calibration)."""
    order = jnp.argsort(deltas)
    mask = jnp.zeros(deltas.shape, bool)
    return mask.at[order[:n_skip]].set(True)
