"""fora: static-interval step cache — recompute every N-th step, else reuse
the previous step's model output (FORA).

State: the cached eps, a per-sample step counter (the interval counts from
0 for every request, so serving slots admitted mid-flight keep their own
schedule phase) and the warm-up flag.  No hidden stacks, no chi^2 sigma
trackers — the gate is purely positional.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.core.policies.base import CachePolicy, register


@register("fora")
class FORA(CachePolicy):
    def __init__(self, model, fc, fc_params, *, fora_interval: int = 3,
                 **kw):
        super().__init__(model, fc, fc_params, **kw)
        self.interval = fora_interval

    def init_state(self, batch: int) -> Dict:
        return {
            "prev_eps": jnp.zeros(self._eps_shape(batch),
                                  self._state_dtype()),
            "step_count": jnp.zeros((batch,), jnp.int32),
            "have_cache": jnp.zeros((batch,), bool),
            "stats": self.init_stats(batch),
        }

    def reset_rows(self, state, rows):
        st = dict(state)
        st["prev_eps"] = state["prev_eps"].at[rows].set(0.0)
        st["step_count"] = state["step_count"].at[rows].set(0)
        st["have_cache"] = state["have_cache"].at[rows].set(False)
        return st

    def step(self, params, state, x_in, c):
        recompute = state["step_count"] % self.interval == 0      # (B,)
        skip = ~recompute & state["have_cache"]
        eps, st = self.masked_step(params, state, x_in, c, skip)
        st["step_count"] = st["step_count"] + 1
        return eps, st
