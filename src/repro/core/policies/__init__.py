"""Cache-policy plugin registry.

Each module below registers one policy; the import order here IS the
``repro.core.POLICIES`` order (the tuple is derived from the registry, so
it can never drift from what is actually registered).  Adding a cache
method = adding one module here — the ``CachedDiT`` shell, the serving
engines and the sharding state walker pick it up unchanged (see
``base.py`` for the protocol and README "Writing a cache policy").
"""
from repro.core.policies.base import (CachePolicy, get_policy_class,  # noqa: F401
                                      register, registered_policies,
                                      summarize_stats)
from repro.core.policies import nocache  # noqa: F401,E402
from repro.core.policies import fora  # noqa: F401,E402
from repro.core.policies import teacache  # noqa: F401,E402
from repro.core.policies import adacache  # noqa: F401,E402
from repro.core.policies import fbcache  # noqa: F401,E402
from repro.core.policies import l2c  # noqa: F401,E402
from repro.core.policies import fastcache  # noqa: F401,E402
from repro.core.policies import smoothcache  # noqa: F401,E402
