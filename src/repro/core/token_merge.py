"""Spatial-temporal token merging — Local Clustering Token Merge (Eqs. 10-13,
Alg. 2) with static shapes.

TPU adaptation (DESIGN.md §3): the paper's global kNN density is O(N^2); here
tokens are processed in fixed windows of `w`, the kNN density rho_sp uses the
K nearest neighbours *within the window* (a (w, w) distance matrix — VMEM
tile-sized; Pallas kernel `knn_density` is the TPU hot path), and each window
keeps a static number of cluster centers M = ceil(r * w).  Every token is
assigned to its nearest kept center; merged tokens are the importance-weighted
cluster means (Eq. 13); ``unmerge`` restores resolution via the stored
assignment (Alg. 2's M mapping).

The center-selection / assignment / weighted-mean core lives in
``kernels/ref.py:merge_assign`` (the pure-jnp ground truth of the fused
Pallas kernel ``kernels/token_merge.py``); ``merge_tokens`` routes through
the kernel when ``use_fused`` is set (TPU serving path) and the reference
otherwise, so both paths share one canonical definition.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref

F32 = jnp.float32


def _check_k(k: int, w: int) -> None:
    """The shared k-validation of every knn-density path (pure jnp here,
    ``kernels/ref.py``, and the Pallas kernel's static-k unroll): a window
    of ``w`` tokens has exactly ``w - 1`` off-diagonal neighbours, so any
    ``k`` outside [1, w-1] is a caller bug.  All three paths raise the
    SAME error instead of silently clamping — a clamp here while the
    kernel unrolled the requested k (or vice versa) is exactly the
    divergence the parity tests pin down."""
    if not 1 <= k <= w - 1:
        raise ValueError(f"knn_density k={k} out of range for window "
                         f"w={w}; need 1 <= k <= w-1 = {w - 1}")


def knn_density(h: jax.Array, k: int) -> jax.Array:
    """Eq. 10 within windows. h: (..., w, D) -> rho_sp (..., w)."""
    w = h.shape[-2]
    _check_k(k, w)
    hf = h.astype(F32)
    sq = jnp.sum(hf * hf, axis=-1)
    dist = (sq[..., :, None] + sq[..., None, :]
            - 2.0 * jnp.einsum("...id,...jd->...ij", hf, hf))
    dist = jnp.maximum(dist, 0.0)
    # exclude self-distance (0) by pushing the diagonal to +inf
    eye = jnp.eye(w, dtype=bool)
    dist = jnp.where(eye, jnp.inf, dist)
    neg_topk, _ = jax.lax.top_k(-dist, k)                  # k smallest
    mean_knn = jnp.mean(-neg_topk, axis=-1)
    # normalize by feature dim: Eq. 10's exp(-dist) underflows for D >> 1
    # (pairwise sq-dist ~ 2D for unit-variance tokens); per-dim distance
    # keeps rho_sp scale-invariant across model widths
    return jnp.exp(-mean_knn / h.shape[-1])


def importance(h_t: jax.Array, h_prev: jax.Array, k: int,
               lam: float) -> jax.Array:
    """Eq. 12: S_i = rho_sp * (1 + lambda * rho_tm). (..., w, D) -> (..., w)."""
    rho_sp = knn_density(h_t, k)
    rho_tm = jnp.linalg.norm(h_t.astype(F32) - h_prev.astype(F32), axis=-1)
    return rho_sp * (1.0 + lam * rho_tm)


class MergeMap(NamedTuple):
    assign: jax.Array     # (B, n_win, w) int32 — cluster id of each token
    centers: jax.Array    # (B, n_win, M) int32 — window-local center indices
    scores: jax.Array     # (B, n_win, w) importance


def keep_count(window: int, keep_ratio: float) -> int:
    """Static centers per window, M = ceil(r * w) clamped to [1, w] —
    a ratio at or above 1.0 keeps every token (``merge_tokens`` is then
    the bitwise-identity map), a tiny ratio still keeps one center so the
    reduced grid never collapses (capacity overflow degrades speed, never
    shape)."""
    return min(window, max(1, math.ceil(keep_ratio * window)))


def _identity_map(b: int, n_win: int, window: int) -> MergeMap:
    idx = jnp.broadcast_to(jnp.arange(window, dtype=jnp.int32),
                           (b, n_win, window))
    return MergeMap(assign=idx, centers=idx,
                    scores=jnp.ones((b, n_win, window), F32))


def merge_tokens(h_t: jax.Array, h_prev: jax.Array, *, window: int,
                 keep_ratio: float, k: int, lam: float,
                 use_fused: bool = False):
    """(B, N, D) -> merged (B, N_keep, D), MergeMap.  N % window == 0.
    ``keep_ratio >= 1.0`` (M == w) short-circuits to the bitwise-identity
    map: the weighted-mean reconstruction of singleton clusters is only
    allclose-identical, and the r=1.0 contract is exact."""
    b, n, d = h_t.shape
    if n % window != 0:
        raise ValueError(f"token count {n} must be divisible by the merge "
                         f"window {window}")
    _check_k(k, window)
    n_win = n // window
    m = keep_count(window, keep_ratio)
    if m >= window:
        return h_t, _identity_map(b, n_win, window)
    hw = h_t.reshape(b, n_win, window, d)
    pw = h_prev.reshape(b, n_win, window, d)
    flat = hw.reshape(b * n_win, window, d)
    if use_fused:
        rho_sp = kernel_ops.knn_density(flat, k=k).reshape(b, n_win, window)
        rho_tm = jnp.linalg.norm(hw.astype(F32) - pw.astype(F32), axis=-1)
        s = rho_sp * (1.0 + lam * rho_tm)                  # (B,n_win,w)
    else:
        s = importance(hw, pw, k, lam)                     # (B,n_win,w)
    # normalize scores per window: the weighted mean (Eq. 13) is invariant
    # to per-window scaling and this avoids denominator underflow
    s = s / jnp.maximum(jnp.max(s, axis=-1, keepdims=True), 1e-30)

    sflat = s.reshape(b * n_win, window)
    if use_fused:
        merged, assign, centers = kernel_ops.merge_assign(flat, sflat, m=m)
    else:
        merged, assign, centers = kernel_ref.merge_assign(flat, sflat, m)
    merged = merged.reshape(b, n_win * m, d)
    return merged, MergeMap(assign=assign.reshape(b, n_win, window),
                            centers=centers.reshape(b, n_win, m),
                            scores=s)


def unmerge_tokens(merged: jax.Array, mm: MergeMap, *, window: int,
                   n_tokens: int, use_fused: bool = False) -> jax.Array:
    """Restore (B, N, D): each token takes its cluster representative."""
    b, nk, d = merged.shape
    n_win = n_tokens // window
    m = nk // n_win
    flat = merged.reshape(b * n_win, m, d)
    aflat = mm.assign.reshape(b * n_win, window)
    if use_fused:
        out = kernel_ops.unmerge_scatter(flat, aflat)
    else:
        out = kernel_ref.unmerge_scatter(flat, aflat)
    return out.reshape(b, n_tokens, d)
