"""Spatial-temporal token merging — Local Clustering Token Merge (Eqs. 10-13,
Alg. 2) with static shapes.

TPU adaptation (DESIGN.md §3): the paper's global kNN density is O(N^2); here
tokens are processed in fixed windows of `w`, the kNN density rho_sp uses the
K nearest neighbours *within the window* (a (w, w) distance matrix — VMEM
tile-sized; Pallas kernel `knn_density` is the TPU hot path), and each window
keeps a static number of cluster centers M = ceil(r * w).  Every token is
assigned to its nearest kept center; merged tokens are the importance-weighted
cluster means (Eq. 13); ``unmerge`` restores resolution via the stored
assignment (Alg. 2's M mapping).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def knn_density(h: jax.Array, k: int) -> jax.Array:
    """Eq. 10 within windows. h: (..., w, D) -> rho_sp (..., w)."""
    hf = h.astype(F32)
    sq = jnp.sum(hf * hf, axis=-1)
    dist = (sq[..., :, None] + sq[..., None, :]
            - 2.0 * jnp.einsum("...id,...jd->...ij", hf, hf))
    dist = jnp.maximum(dist, 0.0)
    w = h.shape[-2]
    # exclude self-distance (0) by pushing the diagonal to +inf
    eye = jnp.eye(w, dtype=bool)
    dist = jnp.where(eye, jnp.inf, dist)
    k = min(k, w - 1)
    neg_topk, _ = jax.lax.top_k(-dist, k)                  # k smallest
    mean_knn = jnp.mean(-neg_topk, axis=-1)
    # normalize by feature dim: Eq. 10's exp(-dist) underflows for D >> 1
    # (pairwise sq-dist ~ 2D for unit-variance tokens); per-dim distance
    # keeps rho_sp scale-invariant across model widths
    return jnp.exp(-mean_knn / h.shape[-1])


def importance(h_t: jax.Array, h_prev: jax.Array, k: int,
               lam: float) -> jax.Array:
    """Eq. 12: S_i = rho_sp * (1 + lambda * rho_tm). (..., w, D) -> (..., w)."""
    rho_sp = knn_density(h_t, k)
    rho_tm = jnp.linalg.norm(h_t.astype(F32) - h_prev.astype(F32), axis=-1)
    return rho_sp * (1.0 + lam * rho_tm)


class MergeMap(NamedTuple):
    assign: jax.Array     # (B, n_win, w) int32 — cluster id of each token
    centers: jax.Array    # (B, n_win, M) int32 — window-local center indices
    scores: jax.Array     # (B, n_win, w) importance


def merge_tokens(h_t: jax.Array, h_prev: jax.Array, *, window: int,
                 keep_ratio: float, k: int, lam: float):
    """(B, N, D) -> merged (B, N_keep, D), MergeMap.  N % window == 0."""
    b, n, d = h_t.shape
    if n % window != 0:
        raise ValueError(f"token count {n} must be divisible by the merge "
                         f"window {window}")
    n_win = n // window
    m = max(1, int(round(keep_ratio * window)))
    hw = h_t.reshape(b, n_win, window, d)
    pw = h_prev.reshape(b, n_win, window, d)
    s = importance(hw, pw, k, lam)                         # (B,n_win,w)
    # normalize scores per window: the weighted mean (Eq. 13) is invariant
    # to per-window scaling and this avoids denominator underflow
    s = s / jnp.maximum(jnp.max(s, axis=-1, keepdims=True), 1e-30)

    _, centers = jax.lax.top_k(s, m)                       # (B,n_win,M)
    ch = jnp.take_along_axis(hw, centers[..., None], axis=2)  # (B,n_win,M,D)

    # assign every token to its nearest center (L2)
    d2 = (jnp.sum(jnp.square(hw.astype(F32)), -1)[..., :, None]
          + jnp.sum(jnp.square(ch.astype(F32)), -1)[..., None, :]
          - 2.0 * jnp.einsum("bwid,bwjd->bwij", hw.astype(F32),
                             ch.astype(F32)))              # (B,n_win,w,M)
    assign = jnp.argmin(d2, axis=-1).astype(jnp.int32)     # (B,n_win,w)

    # merged token = importance-weighted mean of its cluster (Eq. 13)
    onehot = jax.nn.one_hot(assign, m, dtype=F32)          # (B,n_win,w,M)
    wgt = onehot * s[..., None]
    num = jnp.einsum("bwim,bwid->bwmd", wgt, hw.astype(F32))
    den = jnp.maximum(jnp.sum(wgt, axis=2), 1e-9)          # (B,n_win,M)
    merged = (num / den[..., None]).astype(h_t.dtype)      # (B,n_win,M,D)
    merged = merged.reshape(b, n_win * m, d)
    return merged, MergeMap(assign=assign, centers=centers, scores=s)


def unmerge_tokens(merged: jax.Array, mm: MergeMap, *, window: int,
                   n_tokens: int) -> jax.Array:
    """Restore (B, N, D): each token takes its cluster representative."""
    b, nk, d = merged.shape
    n_win = n_tokens // window
    m = nk // n_win
    mw = merged.reshape(b, n_win, m, d)
    out = jnp.take_along_axis(mw, mm.assign[..., None], axis=2)
    return out.reshape(b, n_tokens, d)
