"""Learnable linear approximators (Eqs. 3, 6) + least-squares calibration.

Two approximator families:
  * token bypass:  H^s = W_c X^s + b_c           (one global map, Eq. 3)
  * block cache:   H_l = W_l H_{l-1} + b_l       (one map per block, Eq. 6)

Initialization is the identity map — skipping block l with the identity is
exactly "reuse the residual-stream input", the degenerate cache of prior
work; calibration (``fit_linear`` / ``calibrate_dit``) then learns the
first-order correction that gives FastCache its quality edge (paper §2
"Zero-Shot Redundancy Reduction").
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def init_linear_params(num_blocks: int, d_model: int,
                       dtype: str = "float32") -> Dict[str, jax.Array]:
    eye = jnp.eye(d_model, dtype=jnp.dtype(dtype))
    return {
        "W_c": eye,
        "b_c": jnp.zeros((d_model,), jnp.dtype(dtype)),
        "W_l": jnp.broadcast_to(eye, (num_blocks, d_model, d_model)).copy(),
        "b_l": jnp.zeros((num_blocks, d_model), jnp.dtype(dtype)),
    }


def apply_linear(w: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    return (jnp.matmul(x.astype(F32), w.astype(F32))
            + b.astype(F32)).astype(x.dtype)


def blend(approx: jax.Array, prev_out: jax.Array, gamma: float) -> jax.Array:
    """Motion-aware blending (MB): gamma * linear-approx + (1-gamma) * cached
    previous-step output of the same block."""
    return (gamma * approx.astype(F32)
            + (1.0 - gamma) * prev_out.astype(F32)).astype(approx.dtype)


def fit_linear(x: jax.Array, y: jax.Array,
               ridge: float = 1e-4) -> Tuple[jax.Array, jax.Array]:
    """Ridge least-squares fit of y ~ x W + b.  x, y: (samples, D)."""
    x = x.astype(F32)
    y = y.astype(F32)
    mu_x = x.mean(0)
    mu_y = y.mean(0)
    xc = x - mu_x
    yc = y - mu_y
    d = x.shape[1]
    g = xc.T @ xc + ridge * x.shape[0] * jnp.eye(d, dtype=F32)
    w = jnp.linalg.solve(g, xc.T @ yc)                     # (D, D)
    b = mu_y - mu_x @ w
    return w, b


def calibrate_dit(model, params, fc_params, sample_batches,
                  ridge: float = 1e-4) -> Dict[str, jax.Array]:
    """Fit per-block linear maps from (block input, block output) pairs
    collected over calibration batches (each: latents, t, labels).

    Returns a new fc_params dict; also fits the token-bypass map W_c from
    (token embedding, final hidden) pairs — the bypass must approximate the
    whole stack for static tokens (Eq. 3).
    """
    n_blocks = model.cfg.num_layers
    xs = [[] for _ in range(n_blocks)]
    ys = [[] for _ in range(n_blocks)]
    xs_c, ys_c = [], []

    for batch in sample_batches:
        x = model.tokens_in(params, batch["latents"])
        c = model.conditioning(params, batch["t"], batch["labels"])
        xs_c.append(x.reshape(-1, x.shape[-1]))
        for l in range(n_blocks):
            bp = jax.tree.map(lambda a: a[l], params["blocks"])
            y = model.block_apply(bp, x, c)
            xs[l].append(x.reshape(-1, x.shape[-1]))
            ys[l].append(y.reshape(-1, y.shape[-1]))
            x = y
        ys_c.append(x.reshape(-1, x.shape[-1]))

    w_l, b_l = [], []
    for l in range(n_blocks):
        w, b = fit_linear(jnp.concatenate(xs[l]), jnp.concatenate(ys[l]),
                          ridge)
        w_l.append(w)
        b_l.append(b)
    w_c, b_c = fit_linear(jnp.concatenate(xs_c), jnp.concatenate(ys_c), ridge)
    return {"W_c": w_c, "b_c": b_c, "W_l": jnp.stack(w_l),
            "b_l": jnp.stack(b_l)}
