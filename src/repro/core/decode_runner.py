"""CachedDecoder — FastCache's statistical block gate applied to
autoregressive LLM decode steps (beyond-paper; DESIGN.md §4/§7).

The iterative axis is the decode step: adjacent tokens' residual-stream
hiddens are highly correlated, so the chi^2 gate (Eq. 7) on the per-layer
block input decides whether to replace the block with its learnable linear
approximation (Eq. 6).  KV-cache consistency: on a skipped block we still
compute and write that position's K/V from the (normalized) block input, so
future tokens attend to an approximated-but-present entry; the mixer-state
desync problem that forbids this for SSM layers (DESIGN.md §4) does not
arise.  Supported: period-1 attention stacks (dense / moe / vlm families).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FastCacheConfig
from repro.core import linear_approx, statcache
from repro.models import common
from repro.models.transformer import TransformerModel

F32 = jnp.float32


class CachedDecoder:
    def __init__(self, model: TransformerModel, fc: FastCacheConfig,
                 fc_params: Optional[Dict] = None):
        assert model.period == 1 and model.kinds == ("attn",), (
            "CachedDecoder supports period-1 attention stacks; "
            f"got {model.kinds}")
        self.model = model
        self.fc = fc
        self.L = model.cfg.num_layers
        d = model.cfg.d_model
        self.fc_params = fc_params or linear_approx.init_linear_params(
            self.L, d)

    def init_state(self, batch: int) -> Dict:
        d = self.model.cfg.d_model
        return {
            "prev_hidden": jnp.zeros((self.L + 1, batch, d),
                                     jnp.dtype(self.model.cfg.dtype)),
            "gate": statcache.init_gate_state(self.L),
            "have_cache": jnp.zeros((), bool),
            "stats": {"blocks_computed": jnp.zeros((), F32),
                      "blocks_skipped": jnp.zeros((), F32),
                      "steps": jnp.zeros((), F32)},
        }

    def _kv_write(self, p_attn, x, cache, decode_pos):
        """Write this position's K/V from block input x (B,1,D) on skip."""
        cfg = self.model.cfg
        h_in = common.rms_norm(x, p_attn["norm"], cfg.norm_eps)
        k = common.feinsum("bsd,dhk->bshk", h_in, p_attn["wk"])
        v = common.feinsum("bsd,dhk->bshk", h_in, p_attn["wv"])
        if cfg.qk_norm:
            k = common.rms_norm(k, p_attn["k_norm"], cfg.norm_eps)
        k = common.rope_dispatch(k, decode_pos[:, None], cfg.rope_kind,
                                 cfg.rope_theta, cfg.mrope_sections)
        w = cache["k"].shape[1]
        slot = decode_pos % w
        bidx = jnp.arange(x.shape[0])
        return {
            "k": cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype)),
            "v": cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype)),
            "pos": cache["pos"].at[bidx, slot].set(decode_pos),
        }

    def decode_step(self, params, tokens: jax.Array, cache, state):
        """tokens (B,). Returns (logits, cache, state)."""
        m = self.model
        cfg = m.cfg
        fc = self.fc
        fcp = self.fc_params
        step = cache["step"]
        x = m.embed(params, {"tokens": tokens[:, None]})    # (B,1,D)
        positions = step[:, None]
        nd = int(x.size)
        threshold = statcache.make_threshold(fc.alpha, nd)
        gate = state["gate"]

        def body(carry, xs):
            x, sig, ini, comp, skip = carry
            bps, blk_cache, w_l, b_l, prev_in, lidx = xs
            diff, prevsq = statcache.delta_stats(x[:, 0], prev_in)
            do_cache = (statcache.gate_decision(diff, prevsq, sig[lidx], nd,
                                                threshold)
                        & ini[lidx] & state["have_cache"]
                        & jnp.asarray(fc.use_sc))

            def skip_fn(op):
                xx, bc = op
                new_cache = self._kv_write(bps["attn"], xx, bc, step)
                return linear_approx.apply_linear(w_l, b_l, xx), new_cache

            def comp_fn(op):
                xx, bc = op
                x_new, c, _ = m.block_apply(0, bps, xx, positions=positions,
                                            cache=bc, decode_pos=step,
                                            decode=True)
                return x_new, c

            x_new, new_cache = jax.lax.cond(do_cache, skip_fn, comp_fn,
                                            (x, blk_cache))
            new_sig, _ = statcache.update_sigma(sig[lidx], ini[lidx], diff,
                                                nd, fc.background_momentum)
            sig = sig.at[lidx].set(jnp.where(do_cache, sig[lidx], new_sig))
            ini = ini.at[lidx].set(True)
            comp = comp + jnp.where(do_cache, 0.0, 1.0)
            skip = skip + jnp.where(do_cache, 1.0, 0.0)
            return (x_new, sig, ini, comp, skip), (new_cache, x[:, 0])

        lidx = jnp.arange(self.L)
        carry0 = (x, gate.sigma2, gate.initialized, jnp.zeros((), F32),
                  jnp.zeros((), F32))
        (x, sig, ini, comp, skip), (new_blocks, inputs) = jax.lax.scan(
            body, carry0,
            (params["blocks"]["pos0"], cache["blocks"]["pos0"],
             fcp["W_l"], fcp["b_l"], state["prev_hidden"][:-1], lidx))
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = m.unembed(params, x[:, 0])

        new_cache = {"blocks": {"pos0": new_blocks}, "step": step + 1}
        st = dict(state)
        st["prev_hidden"] = jnp.concatenate([inputs, x[:, 0][None]], 0)
        st["gate"] = statcache.GateState(sigma2=sig, initialized=ini)
        st["have_cache"] = jnp.ones((), bool)
        stats = dict(st["stats"])
        stats["blocks_computed"] = stats["blocks_computed"] + comp
        stats["blocks_skipped"] = stats["blocks_skipped"] + skip
        stats["steps"] = stats["steps"] + 1.0
        st["stats"] = stats
        return logits, new_cache, st
