"""CachedDecoder — FastCache's statistical block gate applied to
autoregressive LLM decode steps (beyond-paper; DESIGN.md §4/§7).

The iterative axis is the decode step: adjacent tokens' residual-stream
hiddens are highly correlated, so the chi^2 gate (Eq. 7) on the per-layer
block input decides whether to replace the block with its learnable linear
approximation (Eq. 6).  The gate is **per-sample**: each serving slot gets
its own (batch,)-indexed decision, variance tracker and skip counters, so one
fresh or fast-moving request no longer forces its batchmates to recompute —
the prerequisite for continuous batching.  ``reset_slot`` re-arms one slot's
trackers when the serving engine assigns it a new request.

KV-cache consistency: on a skipped block we still compute and write that
position's K/V from the (normalized) block input, so future tokens attend to
an approximated-but-present entry; when any sample in the batch recomputes,
the block itself writes identical K/V for every sample (the block derives
K/V from the same input ``_kv_write`` uses).  The mixer-state desync problem
that forbids this for SSM layers (DESIGN.md §4) does not arise.  Supported:
period-1 attention stacks (dense / moe / vlm families).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FastCacheConfig
from repro.core import linear_approx, statcache
from repro.models import common
from repro.models.transformer import TransformerModel

F32 = jnp.float32


class CachedDecoder:
    def __init__(self, model: TransformerModel, fc: FastCacheConfig,
                 fc_params: Optional[Dict] = None):
        if model.period != 1 or model.kinds != ("attn",):
            raise ValueError(
                "CachedDecoder supports period-1 attention stacks; "
                f"got {model.kinds}")
        self.model = model
        self.fc = fc
        self.gate_mode = fc.gate_mode
        self.L = model.cfg.num_layers
        d = model.cfg.d_model
        self.fc_params = fc_params or linear_approx.init_linear_params(
            self.L, d)

    def init_state(self, batch: int) -> Dict:
        d = self.model.cfg.d_model
        return {
            "prev_hidden": jnp.zeros((self.L + 1, batch, d),
                                     jnp.dtype(self.model.cfg.dtype)),
            "gate": statcache.init_gate_state(self.L, batch),
            "have_cache": jnp.zeros((batch,), bool),
            "stats": {"blocks_computed": jnp.zeros((batch,), F32),
                      "blocks_skipped": jnp.zeros((batch,), F32),
                      "steps": jnp.zeros((), F32)},
        }

    def reset_slot(self, state: Dict, slot: int) -> Dict:
        """Re-arm one slot for a new request: drop its hidden cache and
        variance trackers without disturbing its batchmates.  Stats stay
        cumulative (engine-lifetime counters)."""
        st = dict(state)
        st["have_cache"] = state["have_cache"].at[slot].set(False)
        st["gate"] = statcache.reset_gate_slot(state["gate"], slot)
        st["prev_hidden"] = state["prev_hidden"].at[:, slot].set(0.0)
        return st

    def _kv_write(self, p_attn, x, cache, decode_pos):
        """Write this position's K/V from block input x (B,1,D) on skip."""
        cfg = self.model.cfg
        h_in = common.rms_norm(x, p_attn["norm"], cfg.norm_eps)
        k = common.feinsum("bsd,dhk->bshk", h_in, p_attn["wk"])
        v = common.feinsum("bsd,dhk->bshk", h_in, p_attn["wv"])
        if cfg.qk_norm:
            k = common.rms_norm(k, p_attn["k_norm"], cfg.norm_eps)
        k = common.rope_dispatch(k, decode_pos[:, None], cfg.rope_kind,
                                 cfg.rope_theta, cfg.mrope_sections)
        w = cache["k"].shape[1]
        slot = decode_pos % w
        bidx = jnp.arange(x.shape[0])
        return {
            "k": cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype)),
            "v": cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype)),
            "pos": cache["pos"].at[bidx, slot].set(decode_pos),
        }

    def decode_step(self, params, tokens: jax.Array, cache, state):
        """tokens (B,). Returns (logits, cache, state)."""
        m = self.model
        cfg = m.cfg
        fc = self.fc
        fcp = self.fc_params
        step = cache["step"]
        x = m.embed(params, {"tokens": tokens[:, None]})    # (B,1,D)
        b = x.shape[0]
        positions = step[:, None]
        nd = int(x.shape[-1])                # per-sample elements (one token)
        threshold = statcache.make_threshold(fc.alpha, nd)
        if self.gate_mode == "global":
            threshold_g = statcache.make_threshold(fc.alpha, nd * b)
        gate = state["gate"]
        use_sc = bool(fc.use_sc)

        def body(carry, xs):
            x, sig, ini, comp, skip = carry
            bps, blk_cache, w_l, b_l, prev_in, lidx = xs
            diff, prevsq = statcache.delta_stats_per_sample(x[:, 0], prev_in)
            eligible = ini[lidx] & state["have_cache"] & use_sc      # (B,)
            if self.gate_mode == "global":
                do_cache = jnp.broadcast_to(
                    statcache.gate_decision_global(diff, sig[lidx], nd * b,
                                                   threshold_g)
                    & jnp.all(eligible), (b,))
            else:
                do_cache = statcache.gate_decision(
                    diff, prevsq, sig[lidx], nd, threshold) & eligible
            approx = linear_approx.apply_linear(w_l, b_l, x)

            def all_skip(op):
                xx, bc = op
                return approx, self._kv_write(bps["attn"], xx, bc, step)

            def mixed(op):
                xx, bc = op
                x_new, cnew, _ = m.block_apply(0, bps, xx,
                                               positions=positions,
                                               cache=bc, decode_pos=step,
                                               decode=True)
                return jnp.where(do_cache[:, None, None], approx,
                                 x_new), cnew

            x_new, new_cache = jax.lax.cond(jnp.all(do_cache), all_skip,
                                            mixed, (x, blk_cache))
            # only observe deltas taken against a REAL previous hidden:
            # after a slot reset prev_hidden is zeroed and ||h - 0||^2 would
            # poison the no-change variance into an always-skip gate
            observe = jnp.logical_not(do_cache) & state["have_cache"]
            new_sig, _ = statcache.update_sigma(sig[lidx], ini[lidx], diff,
                                                nd, fc.background_momentum)
            sig = sig.at[lidx].set(jnp.where(observe, new_sig, sig[lidx]))
            ini = ini.at[lidx].set(ini[lidx] | observe)
            dc = do_cache.astype(F32)
            comp = comp + (1.0 - dc)
            skip = skip + dc
            return (x_new, sig, ini, comp, skip), (new_cache, x[:, 0])

        lidx = jnp.arange(self.L)
        carry0 = (x, gate.sigma2, gate.initialized, jnp.zeros((b,), F32),
                  jnp.zeros((b,), F32))
        (x, sig, ini, comp, skip), (new_blocks, inputs) = jax.lax.scan(
            body, carry0,
            (params["blocks"]["pos0"], cache["blocks"]["pos0"],
             fcp["W_l"], fcp["b_l"], state["prev_hidden"][:-1], lidx))
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = m.unembed(params, x[:, 0])

        new_cache = {"blocks": {"pos0": new_blocks}, "step": step + 1}
        st = dict(state)
        st["prev_hidden"] = jnp.concatenate([inputs, x[:, 0][None]], 0)
        st["gate"] = statcache.GateState(sigma2=sig, initialized=ini)
        st["have_cache"] = jnp.ones_like(state["have_cache"])
        stats = dict(st["stats"])
        stats["blocks_computed"] = stats["blocks_computed"] + comp
        stats["blocks_skipped"] = stats["blocks_skipped"] + skip
        stats["steps"] = stats["steps"] + 1.0
        st["stats"] = stats
        return logits, new_cache, st
