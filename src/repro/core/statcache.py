"""Transformer-level statistical cache gate (Eqs. 4-9).

The paper tests (ND) * delta^2 against chi^2_{ND,1-alpha} where
delta = ||H_t - H_{t-1}||_F / ||H_{t-1}||_F.  Read literally, the statistic
assumes each element of (H_t - H_{t-1}) has variance ||H||_F^2 / ND under the
no-change hypothesis; with ND ~ 3e5 the quantile/ND ratio is ~1 + O(1e-2) and
the raw rule degenerates (always-skip).  The paper's §5.2 notes a *sliding
window tracking delta_t* — we implement exactly that normalization: a running
(EMA) estimate sigma2 of the per-element no-change variance turns the
statistic into  ||dH||_F^2 / sigma2  ~  chi^2_ND,  which is alpha-sensitive
and reproduces the paper's Figure-3 monotone cache-ratio curve.  ``mode=
'raw'`` keeps the literal Eq. 7 for ablation.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.chi2 import cache_threshold

F32 = jnp.float32


class GateState(NamedTuple):
    sigma2: jax.Array      # per-layer EMA of no-change per-element variance
    initialized: jax.Array  # per-layer bool


def init_gate_state(num_blocks: int, batch: int = 0) -> GateState:
    """Gate tracker state. ``batch > 0`` gives per-(layer, sample) trackers
    (the per-sample gating path); ``batch == 0`` keeps the legacy per-layer
    scalars."""
    shape = (num_blocks, batch) if batch else (num_blocks,)
    return GateState(sigma2=jnp.ones(shape, F32),
                     initialized=jnp.zeros(shape, bool))


def reset_gate_slot(gate: GateState, slot) -> GateState:
    """Re-arm one sample's trackers (a serving slot was re-assigned)."""
    return GateState(sigma2=gate.sigma2.at[:, slot].set(1.0),
                     initialized=gate.initialized.at[:, slot].set(False))


def delta_stats(h: jax.Array, h_prev: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (||h - h_prev||_F^2, ||h_prev||_F^2) in f32."""
    d = h.astype(F32) - h_prev.astype(F32)
    return jnp.sum(d * d), jnp.sum(jnp.square(h_prev.astype(F32)))


def delta_stats_per_sample(h: jax.Array, h_prev: jax.Array
                           ) -> Tuple[jax.Array, jax.Array]:
    """Per-sample Frobenius stats: sums over every axis but the leading batch
    axis.  h: (B, ...) -> ((B,), (B,)) in f32."""
    axes = tuple(range(1, h.ndim))
    d = h.astype(F32) - h_prev.astype(F32)
    return (jnp.sum(d * d, axis=axes),
            jnp.sum(jnp.square(h_prev.astype(F32)), axis=axes))


def gate_decision(diff_sq: jax.Array, prev_sq: jax.Array, sigma2: jax.Array,
                  n_elements: int, threshold: float, mode: str = "normalized",
                  ) -> jax.Array:
    """True => cache (skip the block).  `threshold` is chi2_{ND,1-a}/ND."""
    if mode == "raw":                      # literal Eq. 7
        delta_sq = diff_sq / jnp.maximum(prev_sq, 1e-12)
        return delta_sq <= threshold
    stat = diff_sq / (jnp.maximum(sigma2, 1e-30) * n_elements)
    return stat <= threshold


def gate_decision_global(diff_sq: jax.Array, sigma2: jax.Array,
                         n_total: int, threshold: float) -> jax.Array:
    """Legacy whole-batch decision from per-sample stats: the (B,) Frobenius
    deltas and trackers are reduced to ONE statistic ~ chi^2_{B*ND}.
    `threshold` is chi2_{B*ND,1-a}/(B*ND).  Returns a scalar bool."""
    stat = jnp.sum(diff_sq) / (jnp.maximum(jnp.mean(sigma2), 1e-30) * n_total)
    return stat <= threshold


def update_sigma(state_sigma2: jax.Array, state_init: jax.Array,
                 diff_sq: jax.Array, n_elements: int,
                 momentum: float = 0.7) -> Tuple[jax.Array, jax.Array]:
    """EMA-update the no-change variance from an observed per-element
    mean-square difference (called on *recompute* steps: the observed delta
    becomes the new noise floor — the paper's sliding-window tracker)."""
    obs = diff_sq / n_elements
    new = jnp.where(state_init, momentum * state_sigma2
                    + (1.0 - momentum) * obs, obs)
    return new, jnp.ones_like(state_init, dtype=bool) | state_init


def make_threshold(alpha: float, n_elements: int) -> float:
    return cache_threshold(alpha, n_elements)
