"""TokenReducer: the serving-path token-compression stage (CTM, Eqs. 10-13).

One reducer sits between ``tokens_in`` and the cache policy inside
``CachedDiT.step``: per sample and per step it scores tokens (kNN density x
temporal motion), merges each fixed window of ``w`` tokens down to a STATIC
M = ceil(r * w) cluster centers (``core/token_merge.py``; the fused Pallas
kernels in ``kernels/token_merge.py`` back the TPU hot path), hands the
policy the reduced (B, M_total, D) grid, and unmerges the final hidden back
to full resolution inside the policy's ``_eps`` — so every registered cache
policy composes with token compression without knowing it exists.

Static-shape contract (the jit/serving requirement): M is computed at
construction time from (window, keep_ratio), so the reduced grid never
changes shape across steps, samples, or admissions — capacity overflow
(a ratio that rounds up to the full window) degrades speed, never shape,
by deactivating the reducer entirely (``active == False`` => the runner
drops it and the step is bitwise-identical to merge-off).

Per-sample state: the previous step's full-resolution tokens (the temporal
term of Eq. 12) ride the policy state pytree under the reserved ``tokred``
key — (B, N, D) + a (B,) warm flag, so the sharding walker places them over
the mesh ``data`` axis and engine admissions reset them per slot like any
cache payload.  A cold row scores against itself (zero motion), keeping
every row's merge decision independent of its batchmates — the engines'
bitwise mid-flight-admission contract.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import token_merge
from repro.models.dit import DiTModel

F32 = jnp.float32

# the reserved key the reducer's rows ride under in the policy state pytree
STATE_KEY = "tokred"


class TokenReducer:
    def __init__(self, model: DiTModel, fc, *, use_fused: bool = False):
        self.window = int(fc.merge_window)
        self.keep_ratio = float(fc.merge_ratio)
        self.k = int(fc.knn_k)
        self.lam = float(fc.merge_lambda)
        self.use_fused = use_fused
        self.n_tokens = model.num_tokens
        self.d_model = model.cfg.d_model
        self.dtype = jnp.dtype(model.cfg.dtype)
        if self.window < 2:
            raise ValueError(f"merge_window must be >= 2, got {self.window}")
        self.m = token_merge.keep_count(self.window, self.keep_ratio)
        # a ratio whose ceil hits the full window keeps every token: the
        # stage is statically inert (the runner drops the reducer, so
        # r=1.0 is bitwise-identical to merge-off, not just allclose)
        self.active = self.m < self.window
        if self.active:
            if self.n_tokens % self.window != 0:
                raise ValueError(
                    f"token count {self.n_tokens} must be divisible by the "
                    f"merge window {self.window}")
            token_merge._check_k(self.k, self.window)
        self.n_windows = self.n_tokens // max(1, self.window)
        self.reduced_tokens = (self.n_windows * self.m if self.active
                               else self.n_tokens)
        self._mm = None                 # per-trace MergeMap stash (see step)

    # -- per-sample state (rides the policy pytree under STATE_KEY) ------

    def init_rows(self, batch: int) -> Dict[str, jax.Array]:
        return {
            "prev_full": jnp.zeros((batch, self.n_tokens, self.d_model),
                                   self.dtype),
            "have_prev": jnp.zeros((batch,), bool),
        }

    def reset_rows(self, tr: Dict, rows) -> Dict[str, jax.Array]:
        return {
            "prev_full": tr["prev_full"].at[rows].set(0.0),
            "have_prev": tr["have_prev"].at[rows].set(False),
        }

    # -- the stage -------------------------------------------------------

    def reduce(self, x_full: jax.Array, tr: Dict
               ) -> Tuple[jax.Array, Dict]:
        """(B, N, D) full-resolution tokens -> (B, M_total, D) merged grid
        + refreshed reducer rows.  The MergeMap is stashed on the reducer
        for THIS trace only — ``unmerge`` (called from the policy's
        ``_eps`` later in the same traced step) consumes it, and the
        runner clears it when the step returns."""
        prev = jnp.where(tr["have_prev"][:, None, None],
                         tr["prev_full"].astype(x_full.dtype), x_full)
        merged, mm = token_merge.merge_tokens(
            x_full, prev, window=self.window, keep_ratio=self.keep_ratio,
            k=self.k, lam=self.lam, use_fused=self.use_fused)
        self._mm = mm
        new_tr = {"prev_full": x_full.astype(self.dtype),
                  "have_prev": jnp.ones_like(tr["have_prev"])}
        return merged, new_tr

    def unmerge(self, hidden: jax.Array) -> jax.Array:
        """(B, M_total, D) reduced hidden -> (B, N, D) via the step's
        stashed assignment (Alg. 2's M mapping)."""
        if self._mm is None:
            raise RuntimeError("TokenReducer.unmerge called outside a "
                               "reduce()d step (no MergeMap stashed)")
        return token_merge.unmerge_tokens(
            hidden, self._mm, window=self.window, n_tokens=self.n_tokens,
            use_fused=self.use_fused)
