"""CachedDiT: a thin shell around the pluggable cache-policy registry.

The execution engines for each cache method live in ``core/policies/``
(one module per policy — the paper's FastCache plus the Table 1/12
baselines; ``core/policies/base.py`` documents the ``CachePolicy``
protocol and the state-pytree contract).  ``CachedDiT`` resolves a policy
by name, embeds the DiT model into it, and forwards:

  init_state(batch)            -> policy.init_state       (minimal,
                                  policy-owned state pytree)
  reset_slot(state, slot)      -> policy.reset_rows       (re-arm serving
                                  slot rows; stats stay cumulative)
  step(params, state, latents, t, labels)
                               -> tokens_in + conditioning, then
                                  policy.step(params, state, x, c)
  stats(state)                 -> policy.stats

Gating is **per-sample** in every shipped policy: data-dependent cache
decisions are (batch,) gates blended with ``jnp.where`` masking, so one
moving sample never invalidates its batchmates' caches — the serving
engines' bitwise mid-flight-admission contract rests on this.
``FastCacheConfig.gate_mode="global"`` restores the whole-batch decision
(the statistic is reduced over the batch) for ablations.

``POLICIES`` is derived from the registry on attribute access (module
``__getattr__``), so the tuple can never drift from what is actually
registered; unknown names raise ``ValueError`` listing the registry.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax

from repro.configs.base import FastCacheConfig
from repro.core import linear_approx
from repro.core import policies as _policies  # registers the built-ins
from repro.core.policies.base import (get_policy_class, registered_policies,
                                      summarize_stats)  # noqa: F401  (re-export)
from repro.core.policies.l2c import l2c_mask_from_deltas  # noqa: F401
from repro.core.token_reduce import STATE_KEY as TOKRED_KEY
from repro.core.token_reduce import TokenReducer
from repro.kernels import ops as kernel_ops
from repro.models.dit import DiTModel

GATE_MODES = ("per_sample", "global")


def __getattr__(name: str):
    if name == "POLICIES":
        return registered_policies()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class CachedDiT:
    """DiT sampling under a named cache policy.

    The constructor keeps the historical per-policy knobs as explicit
    kwargs; together with ``**policy_kwargs`` the full set is handed to
    the resolved policy, which keeps the knobs it knows and ignores the
    rest — so registering a new policy (with its own kwargs) requires no
    edit here."""

    def __init__(self, model: DiTModel, fc: FastCacheConfig,
                 policy: str = "fastcache",
                 fc_params: Optional[Dict] = None,
                 fora_interval: int = 3,
                 tea_threshold: float = 0.15,
                 ada_thresholds: Tuple[float, float] = (0.05, 0.15),
                 fb_rdt: float = 0.08,
                 l2c_mask: Optional[jax.Array] = None,
                 token_reduce: Optional[bool] = None,
                 **policy_kwargs):
        cls = get_policy_class(policy)     # ValueError on unknown names
        if fc.gate_mode not in GATE_MODES:
            raise ValueError(f"unknown gate_mode {fc.gate_mode!r}; "
                             f"expected one of {GATE_MODES}")
        self.model = model
        self.fc = fc
        self.policy = policy
        self.gate_mode = fc.gate_mode
        self.use_fused = (kernel_ops.default_use_fused()
                          if fc.use_fused_gate is None else fc.use_fused_gate)
        self.L = model.cfg.num_layers
        self.fc_params = fc_params or linear_approx.init_linear_params(
            self.L, model.cfg.d_model)
        # token-compression stage (core/token_reduce.py): merge each
        # window of fc.merge_window tokens down to ceil(merge_ratio * w)
        # centers before the policy runs, unmerge inside its _eps.  The
        # ``token_reduce`` kwarg overrides fc.merge_enabled; a ratio whose
        # static M fills the window deactivates the stage entirely, so
        # r=1.0 is bitwise-identical to merge-off (same traced program).
        want_merge = (fc.merge_enabled if token_reduce is None
                      else bool(token_reduce))
        self.reducer: Optional[TokenReducer] = None
        if want_merge:
            red = TokenReducer(model, fc, use_fused=self.use_fused)
            if red.active:
                self.reducer = red
        self.impl = cls(model, fc, self.fc_params,
                        gate_mode=self.gate_mode, use_fused=self.use_fused,
                        token_reducer=self.reducer,
                        fora_interval=fora_interval,
                        tea_threshold=tea_threshold,
                        ada_thresholds=ada_thresholds, fb_rdt=fb_rdt,
                        l2c_mask=l2c_mask, **policy_kwargs)

    # ------------------------------------------------------------------

    def init_state(self, batch: int) -> Dict:
        """The policy's own state pytree for ``batch`` samples — only that
        policy's buffers (plus the standard ``stats`` block).  With token
        compression on, the reducer's per-sample rows ride the same pytree
        under the reserved ``tokred`` key."""
        state = self.impl.init_state(batch)
        if self.reducer is not None:
            state = dict(state)
            state[TOKRED_KEY] = self.reducer.init_rows(batch)
        return state

    def reset_slot(self, state: Dict, slot) -> Dict:
        """Re-arm one sample (or an index array of samples, e.g. a CFG
        cond/uncond pair) for a new request: drop its cache payload and
        policy counters without disturbing its batchmates.  Stats stay
        cumulative (engine-lifetime counters)."""
        state = self.impl.reset_rows(state, slot)
        if self.reducer is not None:
            state = dict(state)
            state[TOKRED_KEY] = self.reducer.reset_rows(
                state[TOKRED_KEY], slot)
        return state

    def snapshot_slot(self, state: Dict, rows) -> Dict:
        """Extract ``rows`` into a same-treedef preemption checkpoint (see
        ``CachePolicy.snapshot_rows``).  The generic row walker covers the
        reducer's ``tokred`` rows too — they are batch-leading like any
        per-slot leaf, so no reducer-specific handling is needed."""
        return self.impl.snapshot_rows(state, rows)

    def restore_slot(self, state: Dict, snap: Dict, rows) -> Dict:
        """Scatter a ``snapshot_slot`` checkpoint back into ``rows`` of a
        live state, bitwise; ``rows`` may differ from the donor slot's."""
        return self.impl.restore_rows(state, snap, rows)

    def step(self, params, state: Dict, latents, t, labels
             ) -> Tuple[jax.Array, Dict]:
        """One denoising-model evaluation under the cache policy.
        ``t`` and ``labels`` are (B,) and may be heterogeneous across the
        batch.  Returns (eps, new_state)."""
        x_in = self.model.tokens_in(params, latents)
        c = self.model.conditioning(params, t, labels)
        if self.reducer is not None:
            x_in, tokred = self.reducer.reduce(x_in, state[TOKRED_KEY])
            state = {**state, TOKRED_KEY: tokred}
        try:
            eps, state = self.impl.step(params, state, x_in, c)
        finally:
            if self.reducer is not None:
                self.reducer._mm = None    # MergeMap is per-trace only
        state = dict(state)
        stats = dict(state["stats"])
        stats["steps"] = stats["steps"] + 1.0
        if self.reducer is not None:
            kept = float(self.reducer.reduced_tokens)
            merged = float(self.model.num_tokens - self.reducer.reduced_tokens)
            stats["tokens_kept"] = stats["tokens_kept"] + kept
            stats["tokens_merged"] = stats["tokens_merged"] + merged
        state["stats"] = stats
        return eps, state

    def stats(self, state: Dict) -> Dict[str, float]:
        """Host-side summary of the cache counters (``summarize_stats``)."""
        return self.impl.stats(state)

    # -- audit plane (obs.audit) ---------------------------------------

    def audit_eval(self, params, latents, t, labels
                   ) -> Tuple[jax.Array, jax.Array]:
        """The shadow-compute twin of ``step``: the same tokens-in /
        conditioning plumbing feeding the policy's uncached full forward.
        Returns ``(eps_true, hidden)`` — hidden is the (L+1, B, N, D) stack
        ``CachePolicy.audit_forward`` documents.  Stateless: never touches
        cache payloads or stats, so auditing cannot perturb the run it
        measures."""
        x_in = self.model.tokens_in(params, latents)
        c = self.model.conditioning(params, t, labels)
        return self.impl.audit_forward(params, x_in, c)

    def audit_hidden(self, state: Dict):
        """The cached path's per-layer hidden stack for this step, or None
        when the policy keeps none (see ``CachePolicy.audit_hidden``).
        With token compression on the cached stack lives on the reduced
        grid and cannot be compared layerwise against the full-resolution
        shadow forward, so the audit plane falls back to end-to-end eps
        error — exactly the merge+cache vs nocache quantity we report."""
        if self.reducer is not None:
            return None
        return self.impl.audit_hidden(state)

    def audit_bound(self) -> Optional[float]:
        """The policy's claimed per-step relative error bound (None = no
        claim; see ``CachePolicy.predicted_error_bound``)."""
        return self.impl.predicted_error_bound()
