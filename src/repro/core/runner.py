"""CachedDiT: the FastCache execution engine around a DiT block stack, plus
the baseline cache policies the paper compares against (Table 1/12).

Policies (all jit-compatible):

  nocache    full compute every step (reference)
  fora       static-interval layer cache: recompute every N-th step, else
             reuse the previous step's model output (FORA, Lindsay-style)
  teacache   accumulated input-change gate: skip whole steps while the
             accumulated relative change stays under a threshold (TeaCache)
  adacache   content-adaptive step-skip schedule from the input distance
             (AdaCache)
  fbcache    first-block gate: run block 0; if its output moved less than
             `rdt`, reuse the previous step's output (FBCache/ParaAttention)
  l2c        learned static layer subset replaced by linear approximations
             (Learning-to-Cache, offline-calibrated mask)
  fastcache  the paper: STR token partition + per-block chi^2 statistical
             gate + learnable linear approximation + motion-aware blending

Gating is **per-sample**: every data-dependent cache decision is a (batch,)
boolean gate, and cached vs freshly computed activations are blended with
``jnp.where`` masking, so one moving sample never invalidates its batchmates'
caches.  The transformer stack itself only runs when at least one sample
recomputes (``lax.cond`` on the all-skip fast path), which preserves the
whole-batch speedup when every sample is static.  Per-sample statistics
(``blocks_skipped``, ``steps_reused``, ...) are kept as (batch,) accumulators.
``FastCacheConfig.gate_mode="global"`` restores the pre-refactor whole-batch
decision (the statistic is reduced over the batch) for ablations.

The FastCache state carries the previous step's per-block input hiddens
(H_{t-1,l-1} in Eq. 4), the previous token embeddings (Eq. 1) and the
previous model output (for step-level baselines and MB blending).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FastCacheConfig
from repro.core import linear_approx, saliency, statcache, token_merge
from repro.distributed.sharding import constrain
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.models.dit import DiTModel

F32 = jnp.float32

POLICIES = ("nocache", "fora", "teacache", "adacache", "fbcache", "l2c",
            "fastcache")
GATE_MODES = ("per_sample", "global")


class CachedDiT:
    def __init__(self, model: DiTModel, fc: FastCacheConfig,
                 policy: str = "fastcache",
                 fc_params: Optional[Dict] = None,
                 fora_interval: int = 3,
                 tea_threshold: float = 0.15,
                 ada_thresholds: Tuple[float, float] = (0.05, 0.15),
                 fb_rdt: float = 0.08,
                 l2c_mask: Optional[jax.Array] = None):
        assert policy in POLICIES, policy
        assert fc.gate_mode in GATE_MODES, fc.gate_mode
        self.model = model
        self.fc = fc
        self.policy = policy
        self.gate_mode = fc.gate_mode
        self.use_fused = (kernel_ops.default_use_fused()
                          if fc.use_fused_gate is None else fc.use_fused_gate)
        self.L = model.cfg.num_layers
        d = model.cfg.d_model
        self.fc_params = fc_params or linear_approx.init_linear_params(
            self.L, d)
        self.fora_interval = fora_interval
        self.tea_threshold = tea_threshold
        self.ada_thresholds = ada_thresholds
        self.fb_rdt = fb_rdt
        self.l2c_mask = (l2c_mask if l2c_mask is not None
                         else jnp.zeros((self.L,), bool))
        n = model.num_tokens
        self.gate_nd = n * d  # ND of Eq. 5 (full token grid, one sample)
        self.threshold = statcache.make_threshold(fc.alpha, self.gate_nd)
        self.capacity = max(1, int(round(fc.motion_capacity * n)))

    # ------------------------------------------------------------------

    def init_state(self, batch: int) -> Dict:
        m = self.model
        cfg = m.cfg
        n, d = m.num_tokens, cfg.d_model
        dt = jnp.dtype(cfg.dtype)
        img = cfg.dit.image_size
        return {
            "prev_tokens_in": jnp.zeros((batch, n, d), dt),
            "prev_hidden": jnp.zeros((self.L + 1, batch, n, d), dt),
            "prev_eps": jnp.zeros((batch, img, img, cfg.dit.in_channels), dt),
            "gate": statcache.init_gate_state(self.L, batch),
            # per-sample step phase: serving slots admitted mid-flight keep
            # their own schedule position (fora's interval counts from 0 for
            # every request, not from the engine's global step)
            "step_count": jnp.zeros((batch,), jnp.int32),
            "have_cache": jnp.zeros((batch,), bool),
            "tea_acc": jnp.zeros((batch,), F32),
            "ada_skip_left": jnp.zeros((batch,), jnp.int32),
            "stats": {
                "blocks_computed": jnp.zeros((batch,), F32),
                "blocks_skipped": jnp.zeros((batch,), F32),
                "steps_reused": jnp.zeros((batch,), F32),
                "motion_frac_sum": jnp.zeros((batch,), F32),
                "steps": jnp.zeros((), F32),
            },
        }

    def reset_slot(self, state: Dict, slot) -> Dict:
        """Re-arm one sample (or an index array of samples, e.g. a CFG
        cond/uncond pair) for a new request: drop its cache payload, variance
        trackers and policy counters without disturbing its batchmates.
        Stats stay cumulative (engine-lifetime counters)."""
        st = dict(state)
        st["have_cache"] = state["have_cache"].at[slot].set(False)
        st["gate"] = statcache.reset_gate_slot(state["gate"], slot)
        st["prev_tokens_in"] = state["prev_tokens_in"].at[slot].set(0.0)
        st["prev_hidden"] = state["prev_hidden"].at[:, slot].set(0.0)
        st["prev_eps"] = state["prev_eps"].at[slot].set(0.0)
        st["step_count"] = state["step_count"].at[slot].set(0)
        st["tea_acc"] = state["tea_acc"].at[slot].set(0.0)
        st["ada_skip_left"] = state["ada_skip_left"].at[slot].set(0)
        return st

    # ------------------------------------------------------------------
    # Full forward that records per-block inputs (the cache payload)
    # ------------------------------------------------------------------

    def _full_forward(self, params, x, c):
        def body(x, bp):
            return self.model.block_apply(bp, x, c), x

        x_out, inputs = jax.lax.scan(body, x, params["blocks"])
        hidden = jnp.concatenate([inputs, x_out[None]], axis=0)  # (L+1,B,N,D)
        return x_out, hidden

    def _eps(self, params, hidden_final, c, latents_shape):
        out = self.model.final_layer(params, hidden_final, c)
        p = self.model.cfg.dit.patch_size
        from repro.models.common import unpatchify
        return unpatchify(out[..., :self.model.patch_dim], p, self.model.grid)

    # ------------------------------------------------------------------
    # Step-level per-sample gate
    # ------------------------------------------------------------------

    def _rel_change(self, x: jax.Array, prev: jax.Array) -> jax.Array:
        """Per-sample relative Frobenius change, (B,).  In global mode the
        statistic is reduced over the batch and broadcast."""
        diff, prevsq = statcache.delta_stats_per_sample(x, prev)
        if self.gate_mode == "global":
            rel = jnp.sqrt(jnp.sum(diff)
                           / jnp.maximum(jnp.sum(prevsq), 1e-12))
            return jnp.broadcast_to(rel, diff.shape)
        return jnp.sqrt(diff / jnp.maximum(prevsq, 1e-12))

    def _masked_step(self, params, state, x_in, c, skip: jax.Array,
                     computed_on_skip: float = 0.0):
        """One step under a per-sample step-level gate.  ``skip`` (B,) bool:
        True reuses that sample's cached eps and leaves its cache payload
        untouched; False recomputes and refreshes it.  The block stack only
        runs when at least one sample recomputes.  ``computed_on_skip``
        counts probe blocks (fbcache's block 0) charged to skipped samples.
        """
        def reuse_all(st):
            return st["prev_eps"].astype(F32).astype(x_in.dtype), dict(st)

        def mixed(st):
            x_out, hidden = self._full_forward(params, x_in, c)
            eps = self._eps(params, x_out, c, None)
            out = dict(st)
            out["prev_tokens_in"] = jnp.where(skip[:, None, None],
                                              st["prev_tokens_in"], x_in)
            out["prev_hidden"] = jnp.where(skip[None, :, None, None],
                                           st["prev_hidden"], hidden)
            eps_sel = jnp.where(skip[:, None, None, None],
                                st["prev_eps"].astype(eps.dtype), eps)
            out["prev_eps"] = eps_sel.astype(st["prev_eps"].dtype)
            return eps_sel, out

        eps, st = jax.lax.cond(jnp.all(skip), reuse_all, mixed, state)
        st["have_cache"] = jnp.ones_like(state["have_cache"])
        skf = skip.astype(F32)
        stats = dict(st["stats"])
        stats["blocks_computed"] = (stats["blocks_computed"]
                                    + (1.0 - skf) * self.L
                                    + skf * computed_on_skip)
        stats["blocks_skipped"] = (stats["blocks_skipped"]
                                   + skf * (self.L - computed_on_skip))
        stats["steps_reused"] = stats["steps_reused"] + skf
        stats["motion_frac_sum"] = stats["motion_frac_sum"] + (1.0 - skf)
        st["stats"] = stats
        return eps, st

    # ------------------------------------------------------------------

    def step(self, params, state, latents, t, labels):
        """One denoising-model evaluation under the cache policy.
        ``t`` and ``labels`` are (B,) and may be heterogeneous across the
        batch.  Returns (eps, new_state)."""
        m = self.model
        x_in = m.tokens_in(params, latents)
        c = m.conditioning(params, t, labels)
        b = x_in.shape[0]
        have = state["have_cache"]

        p = self.policy
        if p == "nocache":
            eps, state = self._masked_step(params, state, x_in, c,
                                           jnp.zeros((b,), bool))
        elif p == "fora":
            recompute = state["step_count"] % self.fora_interval == 0  # (B,)
            skip = ~recompute & have
            eps, state = self._masked_step(params, state, x_in, c, skip)
        elif p == "teacache":
            rel = self._rel_change(x_in, state["prev_tokens_in"])
            acc = state["tea_acc"] + rel
            skip = (acc < self.tea_threshold) & have
            eps, state = self._masked_step(params, state, x_in, c, skip)
            state["tea_acc"] = jnp.where(skip, acc, 0.0)
        elif p == "adacache":
            rel = self._rel_change(x_in, state["prev_tokens_in"])
            lo, hi = self.ada_thresholds
            budget = jnp.where(rel < lo, 3, jnp.where(rel < hi, 1, 0))
            skip = (state["ada_skip_left"] > 0) & have
            eps, state = self._masked_step(params, state, x_in, c, skip)
            state["ada_skip_left"] = jnp.where(
                skip, state["ada_skip_left"] - 1,
                budget).astype(jnp.int32)
        elif p == "fbcache":
            bp0 = jax.tree.map(lambda a: a[0], params["blocks"])
            h1 = m.block_apply(bp0, x_in, c)
            rel = self._rel_change(h1, state["prev_hidden"][1])
            skip = (rel < self.fb_rdt) & have
            eps, state = self._masked_step(params, state, x_in, c, skip,
                                           computed_on_skip=1.0)
        elif p == "l2c":
            eps, state = self._layerwise_step(
                params, state, x_in, c,
                forced_mask=self.l2c_mask, use_gate=False, use_str=False)
        else:  # fastcache
            # Per-block gating needs a sample's cache payload.  All-warm
            # batches take the pure gated path; all-cold batches (the first
            # sampling step) take one full forward.  A MIXED batch — a
            # request admitted into a running serving batch — warms up the
            # cold samples with a full forward while the warm samples keep
            # their per-sample gate decisions, cache payloads and trackers
            # (their outputs and state match an admission-free run exactly).
            eps, state = jax.lax.cond(
                jnp.all(have),
                lambda s: self._fastcache_step(params, s, x_in, c),
                lambda s: jax.lax.cond(
                    jnp.any(have),
                    lambda s2: self._fastcache_mixed_step(params, s2, x_in,
                                                          c, have),
                    lambda s2: self._masked_step(params, s2, x_in, c,
                                                 jnp.zeros((b,), bool)),
                    s),
                state)
        state = dict(state)
        state["step_count"] = state["step_count"] + 1
        stats = dict(state["stats"])
        stats["steps"] = stats["steps"] + 1.0
        state["stats"] = stats
        return eps, state

    # ------------------------------------------------------------------
    # FastCache proper (Alg. 1), per-sample block gates
    # ------------------------------------------------------------------

    def _fastcache_step(self, params, state, x_in, c):
        fc = self.fc
        fcp = self.fc_params
        b, n, d = x_in.shape

        # ---- STR: token partition (Eqs. 1-2), per-sample
        if fc.use_str:
            sal = saliency.token_saliency(x_in, state["prev_tokens_in"])
            part = saliency.partition_tokens(sal, fc.motion_threshold,
                                             self.capacity)
        else:
            sal = jnp.full((b, n), jnp.inf, F32)
            part = saliency.partition_tokens(sal, -1.0, n)
        mfrac = saliency.motion_fraction(part)               # (B,)

        # ---- static bypass (Eq. 3) + MB blend with previous final hidden
        h_static = linear_approx.apply_linear(fcp["W_c"], fcp["b_c"], x_in)
        if fc.use_mb:
            h_static = linear_approx.blend(h_static, state["prev_hidden"][-1],
                                           fc.blend_gamma)

        # ---- motion stream through gated blocks
        xm = saliency.gather_motion(x_in, part)              # (B,C,D)
        gate = state["gate"]
        # df of the chi^2 statistic = observed elements of ONE sample (static
        # at trace time; the paper's ND with the motion capacity applied)
        nd = int(xm.shape[1] * xm.shape[2])
        threshold = statcache.make_threshold(fc.alpha, nd)
        if self.gate_mode == "global":
            threshold_g = statcache.make_threshold(fc.alpha, nd * b)
        use_sc = bool(fc.use_sc)

        def body(carry, xs):
            xm, sig, ini, comp, skip = carry
            bp, w_l, b_l, prev_in, prev_out, lidx = xs
            prev_m = saliency.gather_motion(prev_in, part)
            prev_om = saliency.gather_motion(prev_out, part)
            eligible = ini[lidx] & use_sc                    # (B,)

            if self.gate_mode == "global":
                diff, prevsq = statcache.delta_stats_per_sample(xm, prev_m)
                do_cache = jnp.broadcast_to(
                    statcache.gate_decision_global(diff, sig[lidx], nd * b,
                                                   threshold_g)
                    & jnp.all(eligible), (b,))
                approx = linear_approx.apply_linear(w_l, b_l, xm)
                if fc.use_mb:
                    approx = linear_approx.blend(approx, prev_om,
                                                 fc.blend_gamma)
                out = jnp.where(do_cache[:, None, None], approx, xm)
            elif self.use_fused:
                out, do_cache, diff, prevsq = kernel_ops.fused_gate(
                    xm, prev_m, prev_om, w_l, b_l, sig[lidx], eligible,
                    threshold=threshold, gamma=fc.blend_gamma,
                    use_blend=fc.use_mb)
            else:
                out, do_cache, diff, prevsq = kernel_ref.fused_gate(
                    xm, prev_m, prev_om, w_l, b_l, sig[lidx], eligible,
                    threshold=threshold, gamma=fc.blend_gamma,
                    use_blend=fc.use_mb)

            # skip the MXU block entirely when every sample caches; otherwise
            # compute it once for the batch and keep cached samples' approx
            xm_new = jax.lax.cond(
                jnp.all(do_cache),
                lambda ops_: ops_[0],
                lambda ops_: jnp.where(do_cache[:, None, None], ops_[0],
                                       self.model.block_apply(bp, ops_[1],
                                                              c)),
                (out, xm))
            # keep the motion-stream carry on its slot shards (serving runs
            # this scan under a (data, model) mesh; without the constraint
            # GSPMD is free to gather the carry onto one device per layer)
            xm_new = constrain(xm_new, "act_batch", "act_seq", "act_embed")
            # sliding-window variance tracker updates on recompute, per-sample
            new_sig, _ = statcache.update_sigma(
                sig[lidx], ini[lidx], diff, nd, fc.background_momentum)
            sig = sig.at[lidx].set(jnp.where(do_cache, sig[lidx], new_sig))
            ini = ini.at[lidx].set(jnp.ones_like(ini[lidx]))
            dc = do_cache.astype(F32)
            comp = comp + (1.0 - dc)
            skip = skip + dc
            # cache payload: this block's input scattered over prev full grid
            new_prev_in = saliency.scatter_motion(prev_in, xm, part)
            return (xm_new, sig, ini, comp, skip), new_prev_in

        lidx = jnp.arange(self.L)
        prev_in_stack = state["prev_hidden"][:-1]            # (L,B,N,D)
        prev_out_stack = state["prev_hidden"][1:]            # (L,B,N,D)
        carry0 = (xm, gate.sigma2, gate.initialized,
                  jnp.zeros((b,), F32), jnp.zeros((b,), F32))
        (xm, sig, ini, comp, skip), new_prev_in = jax.lax.scan(
            body, carry0,
            (params["blocks"], fcp["W_l"], fcp["b_l"], prev_in_stack,
             prev_out_stack, lidx))

        # ---- reassemble full grid (concat of Eq. 2 sets)
        h_final = saliency.scatter_motion(h_static, xm, part)
        eps = self._eps(params, h_final, c, None)

        st = dict(state)
        st["prev_tokens_in"] = x_in
        st["prev_hidden"] = jnp.concatenate([new_prev_in, h_final[None]], 0)
        st["prev_eps"] = eps.astype(state["prev_eps"].dtype)
        st["gate"] = statcache.GateState(sigma2=sig, initialized=ini)
        stats = dict(st["stats"])
        stats["blocks_computed"] = stats["blocks_computed"] + comp
        stats["blocks_skipped"] = stats["blocks_skipped"] + skip
        stats["motion_frac_sum"] = stats["motion_frac_sum"] + mfrac
        st["stats"] = stats
        return eps, st

    def _fastcache_mixed_step(self, params, state, x_in, c, have):
        """Mixed warm/cold batch (a request admitted mid-flight): cold
        samples take a full forward (their warm-up step — the STR static
        bypass is only valid with a real cache payload), warm samples take
        the gated fastcache path.  Results and state are selected per-sample,
        so a warm sample's outputs, cache payload, variance trackers and
        stats are bit-identical to a run where the admission never happened,
        and a cold sample's match its own solo warm-up step."""
        warm = have                                          # (B,)
        x_out, hidden = self._full_forward(params, x_in, c)
        eps_full = self._eps(params, x_out, c, None)
        eps_fc, st_fc = self._fastcache_step(params, state, x_in, c)

        w3 = warm[:, None, None]
        w4 = warm[:, None, None, None]
        eps = jnp.where(w4, eps_fc, eps_full.astype(eps_fc.dtype))
        st = dict(st_fc)
        st["prev_tokens_in"] = jnp.where(w3, st_fc["prev_tokens_in"], x_in)
        st["prev_hidden"] = jnp.where(warm[None, :, None, None],
                                      st_fc["prev_hidden"],
                                      hidden.astype(st_fc["prev_hidden"].dtype))
        st["prev_eps"] = jnp.where(w4, st_fc["prev_eps"],
                                   eps_full.astype(st_fc["prev_eps"].dtype))
        # cold samples' warm-up leaves the gate untouched (matching
        # _masked_step): trackers first observe a delta on the NEXT step,
        # against the real payload installed here
        st["gate"] = statcache.GateState(
            sigma2=jnp.where(warm[None, :], st_fc["gate"].sigma2,
                             state["gate"].sigma2),
            initialized=jnp.where(warm[None, :], st_fc["gate"].initialized,
                                  state["gate"].initialized))
        st["have_cache"] = jnp.ones_like(have)
        old = state["stats"]
        stats = dict(st_fc["stats"])
        stats["blocks_computed"] = jnp.where(
            warm, stats["blocks_computed"], old["blocks_computed"] + self.L)
        for k in ("blocks_skipped", "steps_reused"):
            stats[k] = jnp.where(warm, stats[k], old[k])
        stats["motion_frac_sum"] = jnp.where(
            warm, stats["motion_frac_sum"], old["motion_frac_sum"] + 1.0)
        st["stats"] = stats
        return eps, st

    # ------------------------------------------------------------------
    # Layerwise forced-mask path (L2C)
    # ------------------------------------------------------------------

    def _layerwise_step(self, params, state, x_in, c, forced_mask,
                        use_gate: bool, use_str: bool):
        fcp = self.fc_params

        def body(carry, xs):
            x, comp, skip = carry
            bp, w_l, b_l, masked = xs

            x_new = jax.lax.cond(
                masked,
                lambda x: linear_approx.apply_linear(w_l, b_l, x),
                lambda x: self.model.block_apply(bp, x, c), x)
            x_new = constrain(x_new, "act_batch", "act_seq", "act_embed")
            comp = comp + jnp.where(masked, 0.0, 1.0)
            skip = skip + jnp.where(masked, 1.0, 0.0)
            return (x_new, comp, skip), x

        (x_out, comp, skip), inputs = jax.lax.scan(
            body, (x_in, jnp.zeros((), F32), jnp.zeros((), F32)),
            (params["blocks"], fcp["W_l"], fcp["b_l"], forced_mask))
        eps = self._eps(params, x_out, c, None)
        st = dict(state)
        st["prev_tokens_in"] = x_in
        st["prev_hidden"] = jnp.concatenate([inputs, x_out[None]], 0)
        st["prev_eps"] = eps.astype(state["prev_eps"].dtype)
        st["have_cache"] = jnp.ones_like(state["have_cache"])
        stats = dict(st["stats"])
        stats["blocks_computed"] = stats["blocks_computed"] + comp
        stats["blocks_skipped"] = stats["blocks_skipped"] + skip
        stats["motion_frac_sum"] = stats["motion_frac_sum"] + 1.0
        st["stats"] = stats
        return eps, st


def summarize_stats(state) -> Dict[str, float]:
    """Batch-mean view of the (batch,) per-sample accumulators, so the
    reported numbers stay in per-sample units (steps reused per sample,
    blocks skipped per sample, ...) regardless of batch size.  The raw
    per-sample counts are under ``per_sample``."""
    s = state["stats"]

    def mean(a):
        return float(jnp.mean(jnp.asarray(a, F32)))

    steps = float(s["steps"])
    computed = mean(s["blocks_computed"])
    skipped = mean(s["blocks_skipped"])
    reused = mean(s["steps_reused"])
    total = computed + skipped
    out = {
        "steps": steps,
        "steps_reused": reused,
        "blocks_computed": computed,
        "blocks_skipped": skipped,
        "block_cache_ratio": skipped / total if total else 0.0,
        "mean_motion_fraction": (mean(s["motion_frac_sum"])
                                 / max(1.0, steps - reused)),
    }
    if jnp.ndim(s["blocks_skipped"]):
        out["per_sample"] = {
            k: [float(v) for v in jnp.asarray(s[k])]
            for k in ("blocks_computed", "blocks_skipped", "steps_reused",
                      "motion_frac_sum")}
    return out


def l2c_mask_from_deltas(deltas: jax.Array, n_skip: int) -> jax.Array:
    """Learning-to-Cache proxy: skip the n layers whose outputs move the
    residual stream least (offline calibration)."""
    order = jnp.argsort(deltas)
    mask = jnp.zeros(deltas.shape, bool)
    return mask.at[order[:n_skip]].set(True)
