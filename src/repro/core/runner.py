"""CachedDiT: the FastCache execution engine around a DiT block stack, plus
the baseline cache policies the paper compares against (Table 1/12).

Policies (all jit-compatible; data-dependent decisions via lax.cond):

  nocache    full compute every step (reference)
  fora       static-interval layer cache: recompute every N-th step, else
             reuse the previous step's model output (FORA, Lindsay-style)
  teacache   accumulated input-change gate: skip whole steps while the
             accumulated relative change stays under a threshold (TeaCache)
  adacache   content-adaptive step-skip schedule from the input distance
             (AdaCache)
  fbcache    first-block gate: run block 0; if its output moved less than
             `rdt`, reuse the previous step's output (FBCache/ParaAttention)
  l2c        learned static layer subset replaced by linear approximations
             (Learning-to-Cache, offline-calibrated mask)
  fastcache  the paper: STR token partition + per-block chi^2 statistical
             gate + learnable linear approximation + motion-aware blending

The FastCache state carries the previous step's per-block input hiddens
(H_{t-1,l-1} in Eq. 4), the previous token embeddings (Eq. 1) and the
previous model output (for step-level baselines and MB blending).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FastCacheConfig
from repro.core import linear_approx, saliency, statcache, token_merge
from repro.models.dit import DiTModel

F32 = jnp.float32

POLICIES = ("nocache", "fora", "teacache", "adacache", "fbcache", "l2c",
            "fastcache")


class CachedDiT:
    def __init__(self, model: DiTModel, fc: FastCacheConfig,
                 policy: str = "fastcache",
                 fc_params: Optional[Dict] = None,
                 fora_interval: int = 3,
                 tea_threshold: float = 0.15,
                 ada_thresholds: Tuple[float, float] = (0.05, 0.15),
                 fb_rdt: float = 0.08,
                 l2c_mask: Optional[jax.Array] = None):
        assert policy in POLICIES, policy
        self.model = model
        self.fc = fc
        self.policy = policy
        self.L = model.cfg.num_layers
        d = model.cfg.d_model
        self.fc_params = fc_params or linear_approx.init_linear_params(
            self.L, d)
        self.fora_interval = fora_interval
        self.tea_threshold = tea_threshold
        self.ada_thresholds = ada_thresholds
        self.fb_rdt = fb_rdt
        self.l2c_mask = (l2c_mask if l2c_mask is not None
                         else jnp.zeros((self.L,), bool))
        n = model.num_tokens
        self.gate_nd = n * d  # ND of Eq. 5 (full token grid)
        self.threshold = statcache.make_threshold(fc.alpha, self.gate_nd)
        self.capacity = max(1, int(round(fc.motion_capacity * n)))

    # ------------------------------------------------------------------

    def init_state(self, batch: int) -> Dict:
        m = self.model
        cfg = m.cfg
        n, d = m.num_tokens, cfg.d_model
        dt = jnp.dtype(cfg.dtype)
        img = cfg.dit.image_size
        return {
            "prev_tokens_in": jnp.zeros((batch, n, d), dt),
            "prev_hidden": jnp.zeros((self.L + 1, batch, n, d), dt),
            "prev_eps": jnp.zeros((batch, img, img, cfg.dit.in_channels), dt),
            "gate": statcache.init_gate_state(self.L),
            "step_count": jnp.zeros((), jnp.int32),
            "have_cache": jnp.zeros((), bool),
            "tea_acc": jnp.zeros((), F32),
            "ada_skip_left": jnp.zeros((), jnp.int32),
            "stats": {
                "blocks_computed": jnp.zeros((), F32),
                "blocks_skipped": jnp.zeros((), F32),
                "steps_reused": jnp.zeros((), F32),
                "motion_frac_sum": jnp.zeros((), F32),
                "steps": jnp.zeros((), F32),
            },
        }

    # ------------------------------------------------------------------
    # Full forward that records per-block inputs (the cache payload)
    # ------------------------------------------------------------------

    def _full_forward(self, params, x, c):
        def body(x, bp):
            return self.model.block_apply(bp, x, c), x

        x_out, inputs = jax.lax.scan(body, x, params["blocks"])
        hidden = jnp.concatenate([inputs, x_out[None]], axis=0)  # (L+1,B,N,D)
        return x_out, hidden

    def _eps(self, params, hidden_final, c, latents_shape):
        out = self.model.final_layer(params, hidden_final, c)
        p = self.model.cfg.dit.patch_size
        from repro.models.common import unpatchify
        return unpatchify(out[..., :self.model.patch_dim], p, self.model.grid)

    # ------------------------------------------------------------------

    def step(self, params, state, latents, t, labels):
        """One denoising-model evaluation under the cache policy.
        Returns (eps, new_state)."""
        m = self.model
        x_in = m.tokens_in(params, latents)
        c = m.conditioning(params, t, labels)

        def compute_full(state):
            x_out, hidden = self._full_forward(params, x_in, c)
            eps = self._eps(params, x_out, c, latents.shape)
            st = dict(state)
            st["prev_tokens_in"] = x_in
            st["prev_hidden"] = hidden
            st["prev_eps"] = eps.astype(state["prev_eps"].dtype)
            st["have_cache"] = jnp.ones((), bool)
            stats = dict(st["stats"])
            stats["blocks_computed"] = stats["blocks_computed"] + self.L
            stats["motion_frac_sum"] = stats["motion_frac_sum"] + 1.0
            st["stats"] = stats
            return eps, st

        def reuse_step(state):
            st = dict(state)
            stats = dict(st["stats"])
            stats["steps_reused"] = stats["steps_reused"] + 1.0
            stats["blocks_skipped"] = stats["blocks_skipped"] + self.L
            st["stats"] = stats
            return st["prev_eps"].astype(F32).astype(x_in.dtype), st

        p = self.policy
        if p == "nocache":
            eps, state = compute_full(state)
        elif p == "fora":
            compute = (state["step_count"] % self.fora_interval == 0) | (
                ~state["have_cache"])
            eps, state = jax.lax.cond(compute, compute_full, reuse_step, state)
        elif p == "teacache":
            diff, prev = statcache.delta_stats(x_in, state["prev_tokens_in"])
            rel = jnp.sqrt(diff / jnp.maximum(prev, 1e-12))
            acc = state["tea_acc"] + rel
            skip = (acc < self.tea_threshold) & state["have_cache"]

            def sk(s):
                eps, s = reuse_step(s)
                s = dict(s)
                s["tea_acc"] = acc
                return eps, s

            def co(s):
                eps, s = compute_full(s)
                s = dict(s)
                s["tea_acc"] = jnp.zeros((), F32)
                return eps, s

            eps, state = jax.lax.cond(skip, sk, co, state)
        elif p == "adacache":
            diff, prev = statcache.delta_stats(x_in, state["prev_tokens_in"])
            rel = jnp.sqrt(diff / jnp.maximum(prev, 1e-12))
            lo, hi = self.ada_thresholds
            budget = jnp.where(rel < lo, 3, jnp.where(rel < hi, 1, 0))
            skip = (state["ada_skip_left"] > 0) & state["have_cache"]

            def sk(s):
                eps, s = reuse_step(s)
                s = dict(s)
                s["ada_skip_left"] = s["ada_skip_left"] - 1
                return eps, s

            def co(s):
                eps, s = compute_full(s)
                s = dict(s)
                s["ada_skip_left"] = budget.astype(jnp.int32)
                return eps, s

            eps, state = jax.lax.cond(skip, sk, co, state)
        elif p == "fbcache":
            bp0 = jax.tree.map(lambda a: a[0], params["blocks"])
            h1 = m.block_apply(bp0, x_in, c)
            diff, prev = statcache.delta_stats(h1, state["prev_hidden"][1])
            rel = jnp.sqrt(diff / jnp.maximum(prev, 1e-12))
            skip = (rel < self.fb_rdt) & state["have_cache"]

            def sk(s):
                eps, s = reuse_step(s)
                s = dict(s)
                stats = dict(s["stats"])
                stats["blocks_computed"] = stats["blocks_computed"] + 1.0
                stats["blocks_skipped"] = stats["blocks_skipped"] - 1.0
                s["stats"] = stats
                return eps, s

            eps, state = jax.lax.cond(skip, sk,
                                      lambda s: compute_full(s), state)
        elif p == "l2c":
            eps, state = self._layerwise_step(
                params, state, x_in, c,
                forced_mask=self.l2c_mask, use_gate=False, use_str=False)
        else:  # fastcache
            def first(s):
                return compute_full(s)

            def cached(s):
                return self._fastcache_step(params, s, x_in, c)

            eps, state = jax.lax.cond(state["have_cache"], cached, first,
                                      state)
        state = dict(state)
        state["step_count"] = state["step_count"] + 1
        stats = dict(state["stats"])
        stats["steps"] = stats["steps"] + 1.0
        state["stats"] = stats
        return eps, state

    # ------------------------------------------------------------------
    # FastCache proper (Alg. 1)
    # ------------------------------------------------------------------

    def _fastcache_step(self, params, state, x_in, c):
        fc = self.fc
        fcp = self.fc_params
        b, n, d = x_in.shape

        # ---- STR: token partition (Eqs. 1-2)
        if fc.use_str:
            sal = saliency.token_saliency(x_in, state["prev_tokens_in"])
            part = saliency.partition_tokens(sal, fc.motion_threshold,
                                             self.capacity)
        else:
            sal = jnp.full((b, n), jnp.inf, F32)
            part = saliency.partition_tokens(sal, -1.0, n)
        mfrac = saliency.motion_fraction(part)

        # ---- static bypass (Eq. 3) + MB blend with previous final hidden
        h_static = linear_approx.apply_linear(fcp["W_c"], fcp["b_c"], x_in)
        if fc.use_mb:
            h_static = linear_approx.blend(h_static, state["prev_hidden"][-1],
                                           fc.blend_gamma)

        # ---- motion stream through gated blocks
        xm = saliency.gather_motion(x_in, part)              # (B,C,D)
        gate = state["gate"]
        # df of the chi^2 statistic = number of observed elements (static at
        # trace time; the paper's ND with the motion capacity applied)
        nd = int(xm.size)
        threshold = statcache.make_threshold(fc.alpha, nd)

        def body(carry, xs):
            xm, sig, ini, comp, skip = carry
            bp, w_l, b_l, prev_in, prev_out, lidx = xs
            prev_m = saliency.gather_motion(prev_in, part)
            diff, prevsq = statcache.delta_stats(xm, prev_m)
            do_cache = statcache.gate_decision(
                diff, prevsq, sig[lidx], nd, threshold) & ini[lidx]
            do_cache = do_cache & jnp.asarray(fc.use_sc)

            def skip_fn(xm):
                approx = linear_approx.apply_linear(w_l, b_l, xm)
                if fc.use_mb:
                    approx = linear_approx.blend(
                        approx, saliency.gather_motion(prev_out, part),
                        fc.blend_gamma)
                return approx

            def comp_fn(xm):
                return self.model.block_apply(bp, xm, c)

            xm_new = jax.lax.cond(do_cache, skip_fn, comp_fn, xm)
            # sliding-window variance tracker updates on recompute
            new_sig_l, _ = statcache.update_sigma(
                sig[lidx], ini[lidx], diff, nd, fc.background_momentum)
            sig = sig.at[lidx].set(jnp.where(do_cache, sig[lidx], new_sig_l))
            ini = ini.at[lidx].set(True)
            comp = comp + jnp.where(do_cache, 0.0, 1.0)
            skip = skip + jnp.where(do_cache, 1.0, 0.0)
            # cache payload: this block's input scattered over prev full grid
            new_prev_in = saliency.scatter_motion(prev_in, xm, part)
            return (xm_new, sig, ini, comp, skip), new_prev_in

        lidx = jnp.arange(self.L)
        prev_in_stack = state["prev_hidden"][:-1]            # (L,B,N,D)
        prev_out_stack = state["prev_hidden"][1:]            # (L,B,N,D)
        carry0 = (xm, gate.sigma2, gate.initialized,
                  jnp.zeros((), F32), jnp.zeros((), F32))
        (xm, sig, ini, comp, skip), new_prev_in = jax.lax.scan(
            body, carry0,
            (params["blocks"], fcp["W_l"], fcp["b_l"], prev_in_stack,
             prev_out_stack, lidx))

        # ---- reassemble full grid (concat of Eq. 2 sets)
        h_final = saliency.scatter_motion(h_static, xm, part)
        eps = self._eps(params, h_final, c, None)

        st = dict(state)
        st["prev_tokens_in"] = x_in
        st["prev_hidden"] = jnp.concatenate([new_prev_in, h_final[None]], 0)
        st["prev_eps"] = eps.astype(state["prev_eps"].dtype)
        st["gate"] = statcache.GateState(sigma2=sig, initialized=ini)
        stats = dict(st["stats"])
        stats["blocks_computed"] = stats["blocks_computed"] + comp
        stats["blocks_skipped"] = stats["blocks_skipped"] + skip
        stats["motion_frac_sum"] = stats["motion_frac_sum"] + mfrac
        st["stats"] = stats
        return eps, st

    # ------------------------------------------------------------------
    # Layerwise forced-mask path (L2C)
    # ------------------------------------------------------------------

    def _layerwise_step(self, params, state, x_in, c, forced_mask,
                        use_gate: bool, use_str: bool):
        fcp = self.fc_params

        def body(carry, xs):
            x, comp, skip = carry
            bp, w_l, b_l, masked = xs

            x_new = jax.lax.cond(
                masked,
                lambda x: linear_approx.apply_linear(w_l, b_l, x),
                lambda x: self.model.block_apply(bp, x, c), x)
            comp = comp + jnp.where(masked, 0.0, 1.0)
            skip = skip + jnp.where(masked, 1.0, 0.0)
            return (x_new, comp, skip), x

        (x_out, comp, skip), inputs = jax.lax.scan(
            body, (x_in, jnp.zeros((), F32), jnp.zeros((), F32)),
            (params["blocks"], fcp["W_l"], fcp["b_l"], forced_mask))
        eps = self._eps(params, x_out, c, None)
        st = dict(state)
        st["prev_tokens_in"] = x_in
        st["prev_hidden"] = jnp.concatenate([inputs, x_out[None]], 0)
        st["prev_eps"] = eps.astype(state["prev_eps"].dtype)
        st["have_cache"] = jnp.ones((), bool)
        stats = dict(st["stats"])
        stats["blocks_computed"] = stats["blocks_computed"] + comp
        stats["blocks_skipped"] = stats["blocks_skipped"] + skip
        stats["motion_frac_sum"] = stats["motion_frac_sum"] + 1.0
        st["stats"] = stats
        return eps, st


def summarize_stats(state) -> Dict[str, float]:
    s = state["stats"]
    total = float(s["blocks_computed"]) + float(s["blocks_skipped"])
    return {
        "steps": float(s["steps"]),
        "steps_reused": float(s["steps_reused"]),
        "blocks_computed": float(s["blocks_computed"]),
        "blocks_skipped": float(s["blocks_skipped"]),
        "block_cache_ratio": (float(s["blocks_skipped"]) / total
                              if total else 0.0),
        "mean_motion_fraction": (float(s["motion_frac_sum"])
                                 / max(1.0, float(s["steps"])
                                       - float(s["steps_reused"]))),
    }


def l2c_mask_from_deltas(deltas: jax.Array, n_skip: int) -> jax.Array:
    """Learning-to-Cache proxy: skip the n layers whose outputs move the
    residual stream least (offline calibration)."""
    order = jnp.argsort(deltas)
    mask = jnp.zeros(deltas.shape, bool)
    return mask.at[order[:n_skip]].set(True)
