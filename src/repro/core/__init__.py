"""FastCache core — the paper's primary contribution.

saliency.py      spatial-temporal token saliency + static/motion partition
statcache.py     chi^2 statistical cache gate (Eqs. 4-9)
linear_approx.py learnable linear approximators + least-squares calibration
token_merge.py   local-clustering token merge (CTM, Eqs. 10-13 / Alg. 2)
policies/        the CachePolicy plugin registry — one module per cache
                 method (fastcache proper + the Table 1/12 baselines +
                 SmoothCache-style layer schedules); see policies/base.py
runner.py        CachedDiT — thin shell resolving a policy from the registry
decode_runner.py CachedDecoder — the gate applied to AR decode (beyond-paper)
chi2.py          host-side chi-square quantiles

``POLICIES`` is derived from the policy registry on attribute access.
"""
from repro.core.chi2 import cache_threshold, chi2_ppf, error_bound  # noqa
from repro.core.decode_runner import CachedDecoder  # noqa: F401
from repro.core.runner import (CachedDiT,  # noqa: F401
                               l2c_mask_from_deltas, summarize_stats)
from repro.core.policies import (CachePolicy, get_policy_class,  # noqa: F401
                                 register, registered_policies)


def __getattr__(name: str):
    if name == "POLICIES":
        return registered_policies()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
