"""FastCache core — the paper's primary contribution.

saliency.py      spatial-temporal token saliency + static/motion partition
statcache.py     chi^2 statistical cache gate (Eqs. 4-9)
linear_approx.py learnable linear approximators + least-squares calibration
token_merge.py   local-clustering token merge (CTM, Eqs. 10-13 / Alg. 2)
runner.py        CachedDiT — Alg. 1 around a DiT stack + baseline policies
decode_runner.py CachedDecoder — the gate applied to AR decode (beyond-paper)
chi2.py          host-side chi-square quantiles
"""
from repro.core.chi2 import cache_threshold, chi2_ppf, error_bound  # noqa
from repro.core.decode_runner import CachedDecoder  # noqa: F401
from repro.core.runner import (CachedDiT, POLICIES,  # noqa: F401
                               l2c_mask_from_deltas, summarize_stats)
