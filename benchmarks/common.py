"""Shared benchmark utilities: reduced DiT variants (CPU-scale stand-ins for
the paper's DiT-S/B/L/XL), timing, and quality proxies.

Quality metrics: the paper reports FID / t-FID against real data; offline on
CPU we report (a) relative L2 error of generated latents vs the exact
(nocache) sampler — the direct measure of cache-induced deviation — and (b) a
Frechet distance between Gaussian fits of latent feature vectors
("fid_proxy"), directionally comparable to FID deltas between methods.
"""
from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FastCacheConfig, ModelConfig
from repro.configs.dit import _dit
from repro.core import CachedDiT, summarize_stats
from repro.diffusion import sample
from repro.models import build_model

# CPU-scale stand-ins mirroring the paper's depth/width ladder (Table 4)
BENCH_DITS: Dict[str, ModelConfig] = {
    "dit-s2": _dit("bench-dit-s2", 3, 96, 4),
    "dit-b2": _dit("bench-dit-b2", 4, 128, 4),
    "dit-l2": _dit("bench-dit-l2", 6, 160, 4),
    "dit-xl2": _dit("bench-dit-xl2", 7, 192, 4),
}
for k in list(BENCH_DITS):
    import dataclasses
    BENCH_DITS[k] = BENCH_DITS[k].replace(
        dtype="float32",
        dit=dataclasses.replace(BENCH_DITS[k].dit, num_classes=10,
                                image_size=16))


def build_dit(name: str):
    cfg = BENCH_DITS[name]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # adaLN-zero init makes untrained blocks the identity (gates=0), which
    # would make every cache policy trivially exact; un-zero the modulation
    # so blocks transform like a trained model's would
    k = jax.random.PRNGKey(1)
    params["blocks"]["ada_w"] = 0.05 * jax.random.normal(
        k, params["blocks"]["ada_w"].shape)
    params["blocks"]["ada_b"] = 0.2 * jax.random.normal(
        jax.random.fold_in(k, 1), params["blocks"]["ada_b"].shape)
    # ... and the zero-init output head (otherwise eps == 0 identically and
    # every policy is trivially "exact")
    params["final_w"] = (jax.random.normal(jax.random.fold_in(k, 2),
                                           params["final_w"].shape)
                         / cfg.d_model ** 0.5)
    return cfg, model, params


def timed_sample(model, params, fc: FastCacheConfig, policy: str, *,
                 batch: int = 2, steps: int = 12, guidance: float = 4.0,
                 seed: int = 0, repeats: int = 2,
                 **runner_kw) -> Tuple[jax.Array, Dict]:
    runner = CachedDiT(model, fc, policy=policy, **runner_kw)
    key = jax.random.PRNGKey(seed)
    # warmup (compile)
    x, state = sample(runner, params, key, batch=batch, num_steps=steps,
                      guidance_scale=guidance)
    jax.block_until_ready(x)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        x, state = sample(runner, params, key, batch=batch, num_steps=steps,
                          guidance_scale=guidance)
        jax.block_until_ready(x)
        best = min(best, time.perf_counter() - t0)
    stats = summarize_stats(state)
    stats["time_s"] = best
    stats["us_per_step"] = best / steps * 1e6
    return x, stats


def rel_err(x, ref) -> float:
    return float(jnp.linalg.norm(x - ref) / (jnp.linalg.norm(ref) + 1e-9))


def frechet_proxy(x, ref) -> float:
    """Frechet distance between Gaussian fits of latent feature vectors
    (samples = all spatial positions of all images)."""
    def stats(a):
        f = np.asarray(a).reshape(-1, a.shape[-1]).astype(np.float64)
        return f.mean(0), np.cov(f, rowvar=False)

    mu1, c1 = stats(x)
    mu2, c2 = stats(ref)
    diff = float(((mu1 - mu2) ** 2).sum())
    try:
        import scipy.linalg
        covmean = scipy.linalg.sqrtm(c1 @ c2)
        if np.iscomplexobj(covmean):
            covmean = covmean.real
        tr = float(np.trace(c1 + c2 - 2.0 * covmean))
    except Exception:
        tr = float(np.trace(c1 + c2))
    return diff + max(tr, 0.0)
