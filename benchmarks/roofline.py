"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json, emits per (arch x shape x mesh):
  compute_s / memory_s / collective_s (per-chip seconds), dominant term,
  MODEL_FLOPS (6ND / 6N_active·D), useful-FLOP ratio, bytes/chip, and one
  bottleneck note.  Also writes experiments/roofline.md.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List

NOTE = {
    "compute_s": ("compute-bound: cut masked-attention waste (prefix-grouped"
                  " causal / Pallas flash), drop remat recompute, or raise"
                  " arithmetic intensity per chip"),
    "memory_s": ("HBM-bound: fuse elementwise chains, keep activations bf16,"
                 " shrink attention working set (smaller KV chunks),"
                 " or re-shard to cut per-chip bytes"),
    "collective_s": ("ICI-bound: re-shard to remove all-gathers (weight-"
                     "stationary layouts), overlap collectives with compute,"
                     " or swap all-gather+slice for all-to-all (MoE)"),
}


def load(out_dir: str = "experiments/dryrun", tag: str = "") -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if tag and r.get("tag", "") != tag:
            continue
        if not tag and r.get("tag", ""):
            continue
        recs.append(r)
    return recs


def run(out_dir: str = "experiments/dryrun") -> List[dict]:
    rows = []
    recs = load(out_dir)
    md = ["| arch | shape | mesh | compute_s | memory_s | collective_s | "
          "dominant | useful | bytes/chip | note |",
          "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "skip":
            rows.append({"name": f"roofline/{r['arch']}/{r['shape']}"
                                 f"/{r['mesh']}",
                         "us_per_call": 0.0,
                         "derived": f"SKIP: {r['skip_reason']}"})
            md.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - |"
                      f" - | skip | - | - | {r['skip_reason']} |")
            continue
        if r["status"] != "ok":
            rows.append({"name": f"roofline/{r['arch']}/{r['shape']}"
                                 f"/{r['mesh']}",
                         "us_per_call": 0.0,
                         "derived": f"FAIL: {r.get('error')}"})
            continue
        t = r["roofline"]
        dom = t["dominant"]
        mem = r.get("memory_analysis", {})
        temp = mem.get("temp_size_in_bytes", 0)
        if r["mesh"] == "multi" and r.get("cost_measure_s", 1) == 0.0:
            # multi-pod pass is the 512-chip compile proof; its costs are
            # scan-counted (while bodies once) — report memory/fit only
            rows.append({
                "name": f"roofline/{r['arch']}/{r['shape']}/multi",
                "us_per_call": 0.0,
                "derived": (f"compile_proof_512chips temp_gib="
                            f"{temp/2**30:.2f} params={r['params']}"),
            })
            md.append(f"| {r['arch']} | {r['shape']} | multi (512) | - | - |"
                      f" - | compile-proof | - | {temp/2**30:.1f} GiB |"
                      f" 512-chip pod-axis shard proof |")
            continue
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            "us_per_call": t[dom] * 1e6,
            "derived": (f"compute={t['compute_s']:.4e}s"
                        f" memory={t['memory_s']:.4e}s"
                        f" collective={t['collective_s']:.4e}s"
                        f" dominant={dom}"
                        f" useful_ratio={r['useful_flops_ratio']:.3f}"
                        f" temp_gib={temp/2**30:.2f}"),
        })
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {t['compute_s']:.3e} | {t['memory_s']:.3e} |"
            f" {t['collective_s']:.3e} | {dom.replace('_s','')} |"
            f" {r['useful_flops_ratio']:.2f} | {temp/2**30:.1f} GiB |"
            f" {NOTE[dom]} |")
    if recs:
        os.makedirs("experiments", exist_ok=True)
        with open("experiments/roofline.md", "w") as f:
            f.write("\n".join(md) + "\n")
    return rows
