"""Heterogeneous sampling-plan serving benchmark: mixed-step-budget Poisson
traffic (e.g. 20-step and 50-step requests at different guidance scales)
through one continuous-batching engine, FIFO vs shortest-job-first.

Every request carries its own ``SamplingPlan`` (DDIM step budget + guidance
scale drawn from the mix), and one engine batch serves them side by side —
the per-slot plan tables make a 20-step job next to a 50-step job exact,
so the scheduler policy is the only variable.  SJF should cut the short
jobs' queueing latency (they stop waiting behind long residents' slots)
at the cost of long-job tail latency; this benchmark measures exactly that
trade plus the cache behavior per step budget (cache schedules are a
function of the request's budget — SmoothCache / Learning-to-Cache — so
the per-budget ratio is the serving-relevant number, not the pooled one).

    PYTHONPATH=src python -m benchmarks.serving_hetero [--json out.json]
    PYTHONPATH=src python -m benchmarks.serving_hetero --steps-mix 20,50

Emits a JSON report (stdout or --json path): one row per scheduling
policy with overall p50/p95 latency plus, per step budget in the mix,
request count, p50/p95 latency and the cache ratio harvested from the
requests' own request-scoped counters (``req.cache``).  Also runnable
through benchmarks/run.py (suite name ``serving_hetero``) as compact CSV
rows.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Sequence

from benchmarks.common import build_dit
from benchmarks.serving_diffusion import serve_once
from repro.serving import poisson_trace, summarize_by_steps


def benchmark(*, dit: str = "dit-b2", policy: str = "fastcache",
              requests: int = 12, slots: int = 2,
              steps_mix: Sequence[int] = (4, 8),
              guidance_mix: Sequence[float] = (1.0, 4.0),
              rate: float = 0.25, seed: int = 0) -> Dict:
    cfg, model, params = build_dit(dit)
    trace = poisson_trace(requests, rate, seed=seed,
                          num_classes=cfg.dit.num_classes,
                          steps_mix=steps_mix, guidance_mix=guidance_mix)
    max_steps = max(steps_mix)
    report: Dict = {
        "config": {"dit": dit, "policy": policy, "requests": requests,
                   "slots": slots, "steps_mix": list(steps_mix),
                   "guidance_mix": list(guidance_mix),
                   "poisson_rate": rate, "seed": seed},
        "runs": [],
    }
    for sched in ("fifo", "sjf"):
        res, done = serve_once(model, params, trace, policy=policy,
                               slots=slots, steps=min(steps_mix),
                               guidance=guidance_mix[0], lockstep=False,
                               max_steps=max_steps, sched_policy=sched)
        res["by_steps"] = summarize_by_steps(done)
        report["runs"].append(res)
    # headline: SJF must not lose on the short jobs' p95 (that's its
    # point).  A small/unlucky trace may never draw the short budget, so
    # the headline is None rather than a KeyError in that case.
    short = str(min(steps_mix))
    runs = {r["sched_policy"]: r for r in report["runs"]}
    for sched in ("fifo", "sjf"):
        grp = runs[sched]["by_steps"].get(short)
        report[f"short_job_p95_{sched}"] = (
            grp["latency_steps_p95"] if grp else None)
    return report


def run() -> List[dict]:
    """benchmarks/run.py driver entry: compact CSV rows."""
    report = benchmark()
    rows = []
    for r in report["runs"]:
        budgets = " ".join(
            f"steps{n}:p95={v['latency_steps_p95']:.0f}"
            f",cache={v['cache_ratio']:.3f}"
            for n, v in r["by_steps"].items())
        rows.append({
            "name": (f"serving_hetero/{report['config']['dit']}"
                     f"/{r['policy']}/{r['sched_policy']}"),
            "us_per_call": r["model_step_ms"] * 1e3,
            "derived": (f"p95_latency_steps={r['latency_steps_p95']:.0f}"
                        f" p50={r['latency_steps_p50']:.0f} {budgets}"),
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dit", default="dit-b2")
    ap.add_argument("--policy", default="fastcache")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--steps-mix", default="4,8",
                    help="comma list of per-request DDIM step budgets "
                         "(paper-scale: 20,50)")
    ap.add_argument("--guidance-mix", default="1.0,4.0")
    ap.add_argument("--rate", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="",
                    help="write the JSON report here (default: stdout)")
    args = ap.parse_args()
    report = benchmark(
        dit=args.dit, policy=args.policy, requests=args.requests,
        slots=args.slots,
        steps_mix=[int(v) for v in args.steps_mix.split(",") if v],
        guidance_mix=[float(v) for v in args.guidance_mix.split(",") if v],
        rate=args.rate, seed=args.seed)
    text = json.dumps(report, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        print(f"[serving_hetero] report written to {args.json}")
    else:
        print(text)


if __name__ == "__main__":
    main()
