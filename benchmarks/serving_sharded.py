"""Sharded-serving benchmark driver row: run the topology sweep of
``benchmarks/serving_diffusion.py --mesh`` on an 8-virtual-device CPU mesh.

The parent benchmark process has already initialized jax on a single CPU
device, and XLA only honors ``--xla_force_host_platform_device_count`` at
first init — so the sweep runs in a subprocess with the flag set (the same
pattern as the production-mesh dry-run), then its JSON report is folded
into compact CSV rows: one row per (data, model) topology with p50/p95
latency, steps/sec and parity against the single-device engine.

    PYTHONPATH=src python -m benchmarks.run --only serving_sharded
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
from typing import List

TOPOLOGIES = "1x1,4x1,8x1,4x2"
DEVICES = 8


def run(*, topologies: str = TOPOLOGIES, requests: int = 8, slots: int = 4,
        steps: int = 6, policy: str = "fastcache", rate: float = 0.25,
        seed: int = 0) -> List[dict]:
    env = dict(os.environ)
    kept = [t for t in env.get("XLA_FLAGS", "").split()
            if not t.startswith("--xla_force_host_platform_device_count")]
    env["XLA_FLAGS"] = " ".join(
        kept + [f"--xla_force_host_platform_device_count={DEVICES}"])
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        out_path = f.name
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.serving_diffusion",
             "--mesh", topologies, "--policies", policy,
             "--requests", str(requests), "--slots", str(slots),
             "--steps", str(steps), "--rate", str(rate),
             "--seed", str(seed), "--json", out_path],
            env=env, capture_output=True, text=True)
        if proc.returncode != 0:
            # surface the child's traceback — a bare CalledProcessError
            # makes CI failures undebuggable
            sys.stderr.write(proc.stderr)
            raise RuntimeError(
                f"serving_diffusion sweep subprocess failed "
                f"(exit {proc.returncode}); stderr above")
        with open(out_path) as f:
            report = json.load(f)
    finally:
        os.unlink(out_path)

    rows = []
    for r in report["topologies"]:
        topo = r["topology"]
        name = (f"serving_sharded/{report['config']['dit']}"
                f"/{r.get('policy', policy)}"
                f"/data{topo['data']}xmodel{topo['model']}")
        if r.get("skipped"):
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": f"SKIPPED: {r['skipped']}"})
            continue
        # parity fields exist only when the (1,1) baseline ran in the sweep
        parity = ""
        if "max_abs_diff_vs_single" in r:
            parity = (f" sched_parity="
                      f"{r['schedule_identical_vs_single']}"
                      f" max_abs_diff_vs_single="
                      f"{r['max_abs_diff_vs_single']:.1e}")
        rows.append({
            "name": name,
            "us_per_call": r["model_step_ms"] * 1e3,
            "derived": (f"steps_per_s={r['steps_per_s']:.2f}"
                        f" p95_latency_steps={r['latency_steps_p95']:.0f}"
                        f" p50={r['latency_steps_p50']:.0f}" + parity +
                        f" cache_ratio="
                        f"{r['cache']['block_cache_ratio']:.3f}"),
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
