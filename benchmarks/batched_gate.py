"""Per-sample vs global cache gating under a heterogeneous batch.

The serving-relevant regime: half the batch is static (identical latents
every step — fully cacheable), half keeps moving (amplitude doubling each
step — never cacheable).  The global gate ANDs the batch together, so one
moving sample forces full compute for everyone; the per-sample gate keeps
the static half on the linear-approximation path.  Reported per mode:
per-sample skip rates and wall-clock, plus the fused Pallas gate kernel
(interpret on CPU) as a third row.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import build_dit
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT, summarize_stats


def _drive(runner, params, cfg, *, batch: int, steps: int):
    img, ch = cfg.dit.image_size, cfg.dit.in_channels
    x0 = jax.random.normal(jax.random.PRNGKey(2), (batch, img, img, ch))
    moving = (jnp.arange(batch) >= batch // 2).astype(jnp.float32)
    state = runner.init_state(batch)
    step = jax.jit(runner.step)
    labels = jnp.arange(batch) % cfg.dit.num_classes
    t0 = None
    for t in range(steps):
        scale = 1.0 + moving * (2.0 ** t - 1.0)
        x = x0 * scale[:, None, None, None]
        eps, state = step(params, state, x, jnp.full((batch,), 25), labels)
        jax.block_until_ready(eps)
        if t == 0:                 # exclude compile from the timed region
            t0 = time.perf_counter()
    dt = (time.perf_counter() - t0) / max(1, steps - 1)
    return dt, summarize_stats(state)


def run(arch: str = "dit-b2", batch: int = 4, steps: int = 10) -> List[dict]:
    cfg, model, params = build_dit(arch)
    rows = []
    modes = [("global", FastCacheConfig(gate_mode="global")),
             ("per_sample", FastCacheConfig()),
             ("per_sample_fused", FastCacheConfig(use_fused_gate=True))]
    for name, fc in modes:
        runner = CachedDiT(model, fc, policy="fastcache")
        dt, s = _drive(runner, params, cfg, batch=batch, steps=steps)
        per = s["per_sample"]["blocks_skipped"]
        # step 0 is the cold full compute and step 1 initializes the sigma
        # trackers (gates ineligible), so (steps-2)*L decisions are skippable
        decisions = (steps - 2) * model.cfg.num_layers
        static_rate = sum(per[:batch // 2]) / (batch // 2) / decisions
        moving_rate = sum(per[batch // 2:]) / (batch - batch // 2) / decisions
        rows.append({
            "name": f"batched_gate/{arch}/b{batch}/{name}",
            "us_per_call": dt * 1e6,
            "derived": (f"skip_rate_static={static_rate:.3f}"
                        f" skip_rate_moving={moving_rate:.3f}"
                        f" cache_ratio={s['block_cache_ratio']:.3f}"),
        })
    return rows
