"""Kernel micro-benchmarks.

On CPU the Pallas kernels run in interpret mode (not representative of TPU),
so the timed numbers here are for the XLA reference implementations — the
derived column carries the kernel's roofline-relevant counters (bytes moved,
FLOPs, arithmetic intensity) that transfer to TPU.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(fn, *args, repeats=5):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> List[dict]:
    key = jax.random.PRNGKey(0)
    rows = []

    n, d = 4096, 1024
    x = jax.random.normal(key, (n, d), jnp.bfloat16)
    xp = jax.random.normal(jax.random.fold_in(key, 1), (n, d), jnp.bfloat16)
    f = jax.jit(ref.saliency_delta)
    dt = _time(f, x, xp)
    bytes_moved = 2 * n * d * 2
    rows.append({"name": "kernel/saliency_delta(4096x1024)",
                 "us_per_call": dt * 1e6,
                 "derived": f"bytes={bytes_moved} fused_passes=1_of_3"})

    m, dd, ff = 2048, 1024, 1024
    xx = jax.random.normal(key, (m, dd), jnp.bfloat16)
    w = jax.random.normal(key, (dd, ff), jnp.bfloat16) * 0.02
    b = jnp.zeros((ff,), jnp.bfloat16)
    prev = jax.random.normal(key, (m, ff), jnp.bfloat16)
    f = jax.jit(lambda *a: ref.linear_blend(*a, 0.5))
    dt = _time(f, xx, w, b, prev)
    flops = 2 * m * dd * ff
    rows.append({"name": "kernel/linear_blend(2048x1024x1024)",
                 "us_per_call": dt * 1e6,
                 "derived": f"flops={flops} intensity="
                            f"{flops/(2*(m*dd+dd*ff+2*m*ff)):.1f}"})

    bb, h, kvh, s, dh = 1, 8, 2, 2048, 64
    q = jax.random.normal(key, (bb, h, s, dh), jnp.bfloat16)
    k = jax.random.normal(key, (bb, kvh, s, dh), jnp.bfloat16)
    v = jax.random.normal(key, (bb, kvh, s, dh), jnp.bfloat16)
    f = jax.jit(lambda *a: ref.flash_attention(*a, causal=True))
    dt = _time(f, q, k, v)
    flops = 4 * bb * h * s * s * dh // 2
    rows.append({"name": "kernel/flash_attention(8hx2048x64,causal)",
                 "us_per_call": dt * 1e6,
                 "derived": f"useful_flops={flops}"})

    hwin = jax.random.normal(key, (64, 16, 256), jnp.bfloat16)
    f = jax.jit(lambda a: ref.knn_density(a, 5))
    dt = _time(f, hwin)
    rows.append({"name": "kernel/knn_density(64x16x256,K=5)",
                 "us_per_call": dt * 1e6,
                 "derived": "window=16 local_ctm_stage=1"})
    return rows
