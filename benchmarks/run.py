"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1,roofline]
    PYTHONPATH=src python -m benchmarks.run --suite serving \
        --bench-out BENCH_serving.json

Prints ``name,us_per_call,derived`` CSV rows (stdout) — reduced-scale CPU
measurements for the paper's tables plus the roofline report derived from the
production-mesh dry-run artifacts (experiments/dryrun/).  With
``--bench-out``, suites that expose a ``write_trajectory`` hook (currently
``serving``) instead append one perf-trajectory entry — per-policy p50/p95
latency, steps/sec, cache ratio, and the metrics-plane overhead — to the
committed BENCH_*.json so speedups are machine-read across PRs.
"""
from __future__ import annotations

import argparse
import sys
import traceback

SUITES = {
    "table1": ("benchmarks.table1_policies", "Table 1/12: policy comparison"),
    "table2": ("benchmarks.table2_ablation", "Table 2/9: STR/SC/MB ablation"),
    "table5": ("benchmarks.table5_static_ratio",
               "Table 5/Fig 1: static-ratio under motion"),
    "table6": ("benchmarks.table6_thresholds",
               "Table 6/Fig 3: threshold robustness"),
    "tokens": ("benchmarks.table_tokens",
               "Token compression on the serving path: keep-ratio + Table "
               "15 kNN-K sweep (latency, audit error, latent FID-proxy)"),
    "decode_gate": ("benchmarks.decode_gate",
                    "Beyond-paper: AR-decode statistical gate"),
    "batched_gate": ("benchmarks.batched_gate",
                     "Per-sample vs global gating on heterogeneous batches"),
    "serving": ("benchmarks.serving_diffusion",
                "Continuous vs lockstep diffusion serving under Poisson "
                "arrivals"),
    "serving_sharded": ("benchmarks.serving_sharded",
                        "Sharded vs single-device diffusion serving across "
                        "(data, model) mesh topologies (8-virtual-device "
                        "CPU subprocess)"),
    "serving_overload": ("benchmarks.serving_overload",
                         "SLO control plane under a bursty overload trace: "
                         "goodput vs p99 latency per cache-ratio shedding "
                         "level, audit-measured quality cost"),
    "serving_hetero": ("benchmarks.serving_hetero",
                       "Heterogeneous sampling plans (mixed step budgets/"
                       "guidance) under Poisson arrivals: FIFO vs SJF, "
                       "cache ratio by step budget"),
    "kernels": ("benchmarks.kernels_bench", "Kernel microbenchmarks"),
    "roofline": ("benchmarks.roofline", "Roofline from dry-run artifacts"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", "--suite", dest="only", default="",
                    help="comma-separated suite names (default: all)")
    ap.add_argument("--bench-out", default="",
                    help="append a perf-trajectory entry (suites exposing "
                         "write_trajectory, e.g. serving -> "
                         "BENCH_serving.json) instead of timing CSV rows")
    args = ap.parse_args()
    picked = [s.strip() for s in args.only.split(",") if s.strip()] \
        or list(SUITES)

    failures = 0
    if args.bench_out:
        # trajectory mode: the picked suites write/append the committed
        # BENCH_*.json point instead of printing CSV timing rows
        for name in picked:
            mod_name, desc = SUITES[name]
            print(f"# {name}: {desc}", file=sys.stderr, flush=True)
            try:
                mod = __import__(mod_name, fromlist=["write_trajectory"])
                if not hasattr(mod, "write_trajectory"):
                    raise AttributeError(
                        f"suite {name!r} has no trajectory writer")
                doc = mod.write_trajectory(args.bench_out)
                entry = doc["entries"][-1]
                extra = ""
                if "metrics_overhead_pct" in entry:
                    extra += (f", metrics overhead "
                              f"{entry['metrics_overhead_pct']:+.2f}%")
                if "audit_overhead_pct" in entry:
                    extra += (f", audit overhead "
                              f"{entry['audit_overhead_pct']:+.2f}%")
                if "goodput_monotone" in entry:
                    extra += (f", goodput monotone="
                              f"{entry['goodput_monotone']}, quality "
                              f"cost monotone="
                              f"{entry['quality_cost_monotone']}")
                print(f"{name}: wrote trajectory entry "
                      f"({len(entry['points'])} points{extra}) "
                      f"-> {args.bench_out}", flush=True)
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"{name}: ERROR: {type(e).__name__}: {e}",
                      flush=True)
                traceback.print_exc(file=sys.stderr)
        if failures:
            raise SystemExit(1)
        return

    print("name,us_per_call,derived")
    for name in picked:
        mod_name, desc = SUITES[name]
        print(f"# {name}: {desc}", file=sys.stderr, flush=True)
        try:
            mod = __import__(mod_name, fromlist=["run"])
            for row in mod.run():
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"\"{row['derived']}\"", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,\"ERROR: {type(e).__name__}: {e}\"", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
