"""Beyond-paper: FastCache's statistical gate on autoregressive LLM decode
(CachedDecoder) — cache ratio and logit deviation vs exact decode."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDecoder
from repro.models import build_model


def run(arch: str = "qwen3-0.6b", new_tokens: int = 24) -> List[dict]:
    cfg = get_reduced(arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    rows = []
    for alpha in (0.05, 0.2):
        fc = FastCacheConfig(alpha=alpha)
        dec = CachedDecoder(model, fc)
        logits_e, cache_e = model.prefill(params, {"tokens": toks},
                                          window=64)
        logits_f, cache_f = model.prefill(params, {"tokens": toks},
                                          window=64)
        st = dec.init_state(4)
        dstep = jax.jit(dec.decode_step)
        estep = jax.jit(model.decode_step)
        dev = 0.0
        t0 = time.perf_counter()
        for _ in range(new_tokens):
            nxt = jnp.argmax(logits_e, -1).astype(jnp.int32)
            logits_e, cache_e = estep(params, nxt, cache_e)
            logits_f, cache_f, st = dstep(params, nxt, cache_f, st)
            dev = max(dev, float(jnp.linalg.norm(logits_f - logits_e)
                                 / (jnp.linalg.norm(logits_e) + 1e-9)))
        dt = (time.perf_counter() - t0) / new_tokens
        skipped = float(jnp.sum(st["stats"]["blocks_skipped"]))
        tot = float(jnp.sum(st["stats"]["blocks_computed"])) + skipped
        rows.append({
            "name": f"decode_gate/{arch}/alpha={alpha}",
            "us_per_call": dt * 1e6,
            "derived": (f"cache_ratio={skipped / tot:.3f}"
                        f" max_logit_rel_dev={dev:.4f}"),
        })
    return rows
