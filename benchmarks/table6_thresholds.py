"""Paper Table 6 + Figure 3: threshold robustness — the alpha significance
level (statistical gate) and tau_s (motion threshold) sweeps.  The paper's
claim: cache ratio grows as alpha shrinks, FID degrades gracefully over
alpha in [0.01, 0.1]."""
from __future__ import annotations

from typing import List

from repro.configs.base import FastCacheConfig

from benchmarks.common import build_dit, frechet_proxy, rel_err, timed_sample


def run(model_name: str = "dit-b2", steps: int = 12) -> List[dict]:
    cfg, model, params = build_dit(model_name)
    ref, _ = timed_sample(model, params, FastCacheConfig(), "nocache",
                          steps=steps, repeats=1)
    rows = []
    for alpha in (0.01, 0.05, 0.1, 0.3):
        fc = FastCacheConfig(alpha=alpha)
        x, st = timed_sample(model, params, fc, "fastcache", steps=steps)
        rows.append({
            "name": f"fig3/{model_name}/alpha={alpha}",
            "us_per_call": st["us_per_step"],
            "derived": (f"cache_ratio={st['block_cache_ratio']:.3f}"
                        f" rel_err={rel_err(x, ref):.4f}"),
        })
    for tau in (0.02, 0.05, 0.1, 0.5):
        fc = FastCacheConfig(motion_threshold=tau)
        x, st = timed_sample(model, params, fc, "fastcache", steps=steps)
        rows.append({
            "name": f"table6/{model_name}/tau_s={tau}",
            "us_per_call": st["us_per_step"],
            "derived": (f"motion_frac={st['mean_motion_fraction']:.3f}"
                        f" cache_ratio={st['block_cache_ratio']:.3f}"
                        f" rel_err={rel_err(x, ref):.4f}"),
        })
    return rows
