"""Paper Table 15: kNN parameter K for the token-merging module — token
reduction vs reconstruction quality."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import token_merge

from benchmarks.common import build_dit


def run(model_name: str = "dit-b2") -> List[dict]:
    cfg, model, params = build_dit(model_name)
    key = jax.random.PRNGKey(0)
    b, n, d = 2, 64, cfg.d_model
    h = jax.random.normal(key, (b, n, d))
    h_prev = h + 0.05 * jax.random.normal(jax.random.fold_in(key, 1),
                                          (b, n, d))
    rows = []
    for k in (3, 5, 7, 10):
        fn = jax.jit(lambda a, b_: token_merge.merge_tokens(
            a, b_, window=16, keep_ratio=0.5, k=k, lam=1.0))
        merged, mm = fn(h, h_prev)
        jax.block_until_ready(merged)
        t0 = time.perf_counter()
        for _ in range(10):
            merged, mm = fn(h, h_prev)
        jax.block_until_ready(merged)
        dt = (time.perf_counter() - t0) / 10
        restored = token_merge.unmerge_tokens(merged, mm, window=16,
                                              n_tokens=n)
        err = float(jnp.linalg.norm(restored - h) / jnp.linalg.norm(h))
        rows.append({
            "name": f"table15/K={k}",
            "us_per_call": dt * 1e6,
            "derived": (f"token_reduction={1 - merged.shape[1]/n:.3f}"
                        f" recon_rel_err={err:.4f}"),
        })
    return rows
