"""CI perf-regression gate against the committed serving trajectory.

    PYTHONPATH=src python -m benchmarks.bench_check [--bench BENCH_serving.json]

The committed BENCH file holds trajectory entries from one or more suites
(``serving`` — per-policy continuous-serving points; ``serving_overload``
— per-shedding-level SLO control-plane points; entries written before
suites shared the file carry no tag and count as ``serving``).  For each
suite present, this gate measures a FRESH trajectory point (same
benchmark config as that suite's latest committed entry, same
policies/levels) and fails — exit 1 with a per-point table — if any
point's ``model_step_ms`` regressed more than ``--max-regress-pct``
(default 25%) against the committed number.  Only slowdowns gate;
speedups and new points pass.

The 25% default is deliberately loose: these are short reduced-scale CPU
runs on shared CI machines, so the gate is meant to catch "the serve step
got 2x slower" structural regressions, not 5% noise.  A legitimate
slowdown (e.g. a PR that knowingly trades step time for quality) is
ridden past the gate by setting ``BENCH_CHECK_OVERRIDE=<reason>`` in the
environment — CI wires that to a ``perf-regression-ok`` PR label — which
downgrades failures to warnings.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

OVERRIDE_ENV = "BENCH_CHECK_OVERRIDE"

# suite tag -> module exposing fresh_for_check(baseline_entry) -> entry
SUITE_MODULES = {
    "serving": "benchmarks.serving_diffusion",
    "serving_overload": "benchmarks.serving_overload",
}


def check_regression(baseline_entry: Dict, fresh_entry: Dict,
                     max_regress_pct: float = 25.0) -> List[Dict]:
    """Compare two trajectory entries point-by-point (keyed on
    ``policy`` — for the overload suite that is ``<policy>@<level>``);
    return one record per point whose fresh ``model_step_ms`` exceeds
    the baseline's by more than ``max_regress_pct`` percent.  Points
    present only on one side are skipped (renames/additions must not
    gate), as are baseline points with non-positive step time
    (corrupt/placeholder data)."""
    base = {p["policy"]: p for p in baseline_entry.get("points", [])}
    fresh = {p["policy"]: p for p in fresh_entry.get("points", [])}
    failures = []
    for policy in base:
        if policy not in fresh:
            continue
        b = float(base[policy].get("model_step_ms", 0.0))
        f = float(fresh[policy].get("model_step_ms", 0.0))
        if b <= 0.0:
            continue
        pct = (f - b) / b * 100.0
        if pct > max_regress_pct:
            failures.append({"policy": policy, "baseline_ms": b,
                             "fresh_ms": f, "regress_pct": pct})
    return failures


def _check_suite(suite: str, baseline: Dict,
                 max_regress_pct: float) -> List[Dict]:
    """Measure a fresh point for one suite and report its table; returns
    the regression records (empty = pass)."""
    mod_name = SUITE_MODULES.get(suite)
    if mod_name is None:
        print(f"[bench-check] {suite}: unknown suite tag; skipping "
              "(no gate)")
        return []
    points = baseline.get("points", [])
    if not points:
        print(f"[bench-check] {suite}: baseline entry has no points "
              "(pass)")
        return []
    print(f"[bench-check] {suite}: baseline {baseline.get('date', '?')} "
          f"({len(points)} points); measuring fresh point ...",
          flush=True)
    mod = __import__(mod_name, fromlist=["fresh_for_check"])
    fresh = mod.fresh_for_check(baseline)
    failures = check_regression(baseline, fresh, max_regress_pct)
    for p in fresh["points"]:
        base = next((b for b in points if b["policy"] == p["policy"]),
                    None)
        tag = ""
        if base and float(base.get("model_step_ms", 0.0)) > 0.0:
            pct = ((p["model_step_ms"] - base["model_step_ms"])
                   / base["model_step_ms"] * 100.0)
            tag = f" ({pct:+.1f}% vs baseline)"
        print(f"[bench-check]   {p['policy']}: "
              f"{p['model_step_ms']:.3f} ms/step{tag}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="BENCH_serving.json",
                    help="committed trajectory file to gate against")
    ap.add_argument("--max-regress-pct", type=float, default=25.0)
    ap.add_argument("--suite", default="",
                    help="comma list of suite tags to gate (default: "
                         "every suite present in the BENCH file)")
    args = ap.parse_args()
    try:
        with open(args.bench) as f:
            doc = json.load(f)
        entries = doc["entries"]
        if not entries:
            raise KeyError("entries")
    except (OSError, ValueError, KeyError):
        print(f"[bench-check] no usable baseline in {args.bench}; "
              "nothing to gate against (pass)")
        return
    # latest entry per suite is that suite's baseline (entries are
    # appended in date order; untagged legacy entries are 'serving')
    by_suite: Dict[str, Dict] = {}
    for e in entries:
        by_suite[e.get("suite", "serving")] = e
    picked = [s.strip() for s in args.suite.split(",") if s.strip()] \
        or sorted(by_suite)
    failures: List[Dict] = []
    for suite in picked:
        if suite not in by_suite:
            print(f"[bench-check] {suite}: no committed entry in "
                  f"{args.bench} (pass)")
            continue
        failures.extend(_check_suite(suite, by_suite[suite],
                                     args.max_regress_pct))
    if not failures:
        print(f"[bench-check] OK: no point regressed more than "
              f"{args.max_regress_pct:.0f}%")
        return
    override = os.environ.get(OVERRIDE_ENV, "")
    for f_ in failures:
        print(f"[bench-check] REGRESSION {f_['policy']}: "
              f"{f_['baseline_ms']:.3f} -> {f_['fresh_ms']:.3f} ms/step "
              f"({f_['regress_pct']:+.1f}% > "
              f"{args.max_regress_pct:.0f}%)", file=sys.stderr)
    if override:
        print(f"[bench-check] overridden ({OVERRIDE_ENV}={override!r}); "
              "treating regressions as warnings")
        return
    print(f"[bench-check] FAIL: set {OVERRIDE_ENV} (CI: the "
          "perf-regression-ok label) to override a known slowdown",
          file=sys.stderr)
    raise SystemExit(1)


if __name__ == "__main__":
    main()
