"""CI perf-regression gate against the committed serving trajectory.

    PYTHONPATH=src python -m benchmarks.bench_check [--bench BENCH_serving.json]

Measures a FRESH trajectory point (same benchmark config as the committed
baseline's latest entry, same policies) and fails — exit 1 with a
per-policy table — if any policy's ``model_step_ms`` regressed more than
``--max-regress-pct`` (default 25%) against the committed number.  Only
slowdowns gate; speedups and new policies pass.

The 25% default is deliberately loose: these are short reduced-scale CPU
runs on shared CI machines, so the gate is meant to catch "the serve step
got 2x slower" structural regressions, not 5% noise.  A legitimate
slowdown (e.g. a PR that knowingly trades step time for quality) is
ridden past the gate by setting ``BENCH_CHECK_OVERRIDE=<reason>`` in the
environment — CI wires that to a ``perf-regression-ok`` PR label — which
downgrades failures to warnings.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

from benchmarks.serving_diffusion import trajectory

OVERRIDE_ENV = "BENCH_CHECK_OVERRIDE"


def check_regression(baseline_entry: Dict, fresh_entry: Dict,
                     max_regress_pct: float = 25.0) -> List[Dict]:
    """Compare two trajectory entries policy-by-policy; return one record
    per policy whose fresh ``model_step_ms`` exceeds the baseline's by
    more than ``max_regress_pct`` percent.  Policies present only on one
    side are skipped (renames/additions must not gate), as are baseline
    points with non-positive step time (corrupt/placeholder data)."""
    base = {p["policy"]: p for p in baseline_entry.get("points", [])}
    fresh = {p["policy"]: p for p in fresh_entry.get("points", [])}
    failures = []
    for policy in base:
        if policy not in fresh:
            continue
        b = float(base[policy].get("model_step_ms", 0.0))
        f = float(fresh[policy].get("model_step_ms", 0.0))
        if b <= 0.0:
            continue
        pct = (f - b) / b * 100.0
        if pct > max_regress_pct:
            failures.append({"policy": policy, "baseline_ms": b,
                             "fresh_ms": f, "regress_pct": pct})
    return failures


def _config_kwargs(config: Dict) -> Dict:
    """Map a committed entry's config record back to ``trajectory()``
    keyword arguments (``poisson_rate`` -> ``rate``; ``mode`` is implied)."""
    kw = {k: config[k] for k in ("dit", "requests", "slots", "steps",
                                 "guidance", "seed", "repeats",
                                 "merge_ratio", "merge_window")
          if k in config}
    if "poisson_rate" in config:
        kw["rate"] = config["poisson_rate"]
    return kw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="BENCH_serving.json",
                    help="committed trajectory file to gate against")
    ap.add_argument("--max-regress-pct", type=float, default=25.0)
    args = ap.parse_args()
    try:
        with open(args.bench) as f:
            doc = json.load(f)
        baseline = doc["entries"][-1]
    except (OSError, ValueError, KeyError, IndexError):
        print(f"[bench-check] no usable baseline in {args.bench}; "
              "nothing to gate against (pass)")
        return
    policies = tuple(p["policy"] for p in baseline.get("points", []))
    if not policies:
        print("[bench-check] baseline entry has no points (pass)")
        return
    print(f"[bench-check] baseline {baseline['date']} "
          f"({len(policies)} policies); measuring fresh point ...",
          flush=True)
    fresh = trajectory(policies=policies,
                       **_config_kwargs(baseline.get("config", {})))
    failures = check_regression(baseline, fresh, args.max_regress_pct)
    for p in fresh["points"]:
        base = next((b for b in baseline["points"]
                     if b["policy"] == p["policy"]), None)
        tag = ""
        if base and float(base.get("model_step_ms", 0.0)) > 0.0:
            pct = ((p["model_step_ms"] - base["model_step_ms"])
                   / base["model_step_ms"] * 100.0)
            tag = f" ({pct:+.1f}% vs baseline)"
        print(f"[bench-check]   {p['policy']}: "
              f"{p['model_step_ms']:.3f} ms/step{tag}")
    if not failures:
        print(f"[bench-check] OK: no policy regressed more than "
              f"{args.max_regress_pct:.0f}%")
        return
    override = os.environ.get(OVERRIDE_ENV, "")
    for f_ in failures:
        print(f"[bench-check] REGRESSION {f_['policy']}: "
              f"{f_['baseline_ms']:.3f} -> {f_['fresh_ms']:.3f} ms/step "
              f"({f_['regress_pct']:+.1f}% > "
              f"{args.max_regress_pct:.0f}%)", file=sys.stderr)
    if override:
        print(f"[bench-check] overridden ({OVERRIDE_ENV}={override!r}); "
              "treating regressions as warnings")
        return
    print(f"[bench-check] FAIL: set {OVERRIDE_ENV} (CI: the "
          "perf-regression-ok label) to override a known slowdown",
          file=sys.stderr)
    raise SystemExit(1)


if __name__ == "__main__":
    main()
