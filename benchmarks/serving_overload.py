"""Overload benchmark: goodput vs p99 latency per cache-ratio shedding level.

    PYTHONPATH=src python -m benchmarks.serving_overload [--json out.json]

A bursty arrival trace (rate-modulated Poisson: calm -> burst -> calm, via
``piecewise_rate``) with mixed priority classes and per-request deadlines is
served through the SLO control plane (``SLOScheduler``: EDF admission,
deadline-aware rejection, priority preemption) once per **shedding level**.
Each level of the ladder is a ``ShedLevel`` pinned for the whole run
(single-level ``DegradationController``), combining the two degradation
knobs:

- ``steps_scale`` — shrink the DDIM step budget of shed-eligible classes
  (``min_priority`` and above) at admission.  Zero-recompile: the plan
  tables already support heterogeneous budgets.
- ``alpha`` — the chi^2 significance of the cache gate, applied at ENGINE
  CONSTRUCTION (thresholds are trace-time constants; see
  ``slo/controller.py``).  Smaller alpha -> higher skip threshold -> more
  cache reuse -> faster steps but larger approximation error.

Per level the benchmark reports **goodput** (fraction of offered requests
finishing within their deadline — deadlines live on the engine-step clock,
so this is deterministic and wall-noise-free), step-clock latency
p50/p99, queue wait, rejections/preemptions, and the **audit-measured
quality cost**: a second run with ``audit_fraction=1.0`` shadow-computes
the uncached forward on every step; the headline ``quality_cost`` is the
mean cached-vs-true eps error per gated audited slot-step from the exact
per-request error budgets (the PR 8 audit plane pricing each shedding
level in quality), with the histogram quantiles alongside.  The
acceptance story is the committed
ladder showing monotonically increasing goodput AND audit error across
levels — shedding buys deadline hits with quality, and the audit plane
shows exactly how much.

Also runnable through benchmarks/run.py (suite ``serving_overload``);
``--bench-out BENCH_serving.json`` appends one trajectory entry (suite
tag ``serving_overload``) next to the ``serving`` entries, gated by
``benchmarks/bench_check.py``.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from benchmarks.common import build_dit
from benchmarks.serving_diffusion import _fresh_trace, append_entry
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT
from repro.obs import MetricsCollector
from repro.serving import (DegradationController, DiffusionRequest,
                           DiffusionServingEngine, ShedLevel, SLOScheduler,
                           piecewise_rate, poisson_trace,
                           summarize_by_class)

# The committed ladder: each level sheds harder on every axis, so goodput
# and quality cost move together monotonically.  ``steps_scale`` drives
# the step-clock goodput; ``capacity_scale`` + ``alpha`` drive the
# quality cost (``capacity_scale`` is the axis that bites at reduced CPU
# scale — it routes more tokens through the STR static bypass every
# step, while the chi^2 stat sits far above any alpha-reachable
# threshold on a randomly-initialized reduced model; alpha still drops
# per rung so the ladder is production-shaped).  alpha=None on the
# nominal level means "the FastCacheConfig default" (0.05).  The scales
# balance two opposing error effects — smaller capacity raises the
# per-step approximation error, while a shorter budget samples fewer
# high-error late steps — so each rung's measured quality cost stays
# strictly above the previous one's (tuned on the default trace; see the
# sweep rationale in the PR adding this file).
DEFAULT_LADDER: Tuple[ShedLevel, ...] = (
    ShedLevel("nominal", steps_scale=1.0, alpha=None, capacity_scale=1.0),
    ShedLevel("shed-1", steps_scale=0.875, alpha=1e-3,
              capacity_scale=0.375),
    ShedLevel("shed-2", steps_scale=0.75, alpha=1e-8,
              capacity_scale=0.0625),
)


def overload_trace(*, requests: int, num_classes: int, seed: int,
                   base_rate: float, burst_rate: float, burst_start: int,
                   burst_len: int, priority_mix: Sequence[int],
                   deadline_slack: Sequence[int]) -> List[DiffusionRequest]:
    """Calm -> burst -> calm arrivals with priority classes and
    deadlines.  The burst is what builds the queue the control plane
    sheds against; the calm tail lets every admitted request drain so
    goodput compares complete runs."""
    rate_fn = piecewise_rate([(burst_start, base_rate),
                              (burst_start + burst_len, burst_rate),
                              (10 ** 9, base_rate)])
    return poisson_trace(requests, base_rate, seed=seed,
                         num_classes=num_classes, rate_fn=rate_fn,
                         priority_mix=tuple(priority_mix),
                         deadline_slack_mix=tuple(deadline_slack))


def serve_level(model, params, trace: List[DiffusionRequest],
                level: ShedLevel, *, policy: str = "fastcache",
                slots: int, steps: int, guidance: float,
                audit_fraction: float = 0.0,
                collector: Optional[MetricsCollector] = None,
                repeats: int = 1
                ) -> Tuple[Dict, List[DiffusionRequest], SLOScheduler]:
    """One SLO-controlled run of ``trace`` pinned at ``level``.  Returns
    (result row, finished requests, scheduler) — the scheduler exposes
    ``.rejected`` for the admission-loss accounting.

    Every scheduling outcome (goodput, rejections, preemptions,
    latencies) lives on the deterministic engine-step clock, so repeats
    reproduce it bitwise; only the wall clock varies.  ``repeats`` runs
    the trace that many times on the warm engine and keeps the best-wall
    run for the ``model_step_ms`` measurement, the same noise-floor
    idiom as the serving trajectory's best-of-N."""
    base = FastCacheConfig()
    fc = FastCacheConfig(
        alpha=level.alpha if level.alpha is not None else base.alpha,
        motion_capacity=base.motion_capacity * level.capacity_scale)
    runner = CachedDiT(model, fc, policy=policy)
    engine = DiffusionServingEngine(runner, params, max_slots=slots,
                                    num_steps=steps,
                                    guidance_scale=guidance,
                                    collector=collector,
                                    audit_fraction=audit_fraction)
    # warm the jitted step so wall time excludes compilation, then rewind
    # the clock so the trace's absolute arrival steps (and deadlines,
    # which live on the same clock) line up
    warm = _fresh_trace(trace[:1])
    warm[0].arrival_step = 0
    warm[0].deadline_step = None
    warm[0].priority = 0
    engine.run(warm)
    best = None
    for _ in range(max(1, repeats)):
        engine.reset_clock()
        controller = DegradationController(levels=(level,),
                                           collector=collector)
        sched = SLOScheduler(engine, sched_policy="edf",
                             controller=controller, collector=collector)
        reqs = _fresh_trace(trace)
        t0 = time.perf_counter()
        done = sched.run(reqs)
        wall = time.perf_counter() - t0
        if best is None or wall < best[0]:
            best = (wall, done, sched)
    wall, done, sched = best
    offered = len(trace)
    met = sum(1 for r in done
              if r.deadline_step is None or r.finish_step <= r.deadline_step)
    lats = np.array([r.latency_steps for r in done] or [-1.0], np.float64)
    waits = np.array([r.queue_wait_steps for r in done] or [-1.0],
                     np.float64)
    row = {
        "level": level.name,
        "policy": f"{policy}@{level.name}",
        "steps_scale": level.steps_scale,
        "alpha": fc.alpha,
        "capacity_scale": level.capacity_scale,
        "min_priority": level.min_priority,
        "offered": offered,
        "finished": len(done),
        "rejected": len(sched.rejected),
        "deadline_met": met,
        "goodput": met / offered if offered else 0.0,
        "preemptions": sum(r.preemptions for r in done),
        "latency_steps_p50": float(np.percentile(lats, 50)),
        "latency_steps_p99": float(np.percentile(lats, 99)),
        "queue_wait_p50": float(np.percentile(waits, 50)),
        "queue_wait_p95": float(np.percentile(waits, 95)),
        "engine_steps": engine.clock,
        "model_steps": engine.model_steps,
        "wall_s": wall,
        "model_step_ms": wall / max(1, engine.model_steps) * 1e3,
        "steps_per_s": engine.model_steps / wall if wall else 0.0,
        "cache_ratio": engine.cache_stats()["block_cache_ratio"],
    }
    return row, done, sched


def _monotone(xs: Sequence[float], *, strict: bool = False) -> bool:
    eps = 1e-12
    return all(b > a if strict else b >= a - eps
               for a, b in zip(xs, xs[1:]))


def _levels_config(levels: Sequence[ShedLevel]) -> List[Dict]:
    return [{"name": lv.name, "steps_scale": lv.steps_scale,
             "alpha": lv.alpha, "capacity_scale": lv.capacity_scale,
             "min_priority": lv.min_priority}
            for lv in levels]


def _levels_from_config(spec: Sequence[Dict]) -> Tuple[ShedLevel, ...]:
    return tuple(ShedLevel(d["name"], steps_scale=d["steps_scale"],
                           alpha=d.get("alpha"),
                           capacity_scale=d.get("capacity_scale", 1.0),
                           min_priority=d.get("min_priority", 1))
                 for d in spec)


def benchmark(*, dit: str = "dit-b2", policy: str = "fastcache",
              requests: int = 24, slots: int = 2, steps: int = 8,
              guidance: float = 4.0, seed: int = 0,
              base_rate: float = 0.1, burst_rate: float = 1.5,
              burst_start: int = 2, burst_len: int = 12,
              priority_mix: Sequence[int] = (0, 1, 1, 2),
              deadline_slack: Sequence[int] = (12, 20, 32),
              levels: Sequence[ShedLevel] = DEFAULT_LADDER,
              repeats: int = 2) -> Dict:
    """Serve the same bursty trace once per shedding level: a perf run
    (metrics on, audit off — goodput / latency / step time, best wall of
    ``repeats``) plus a fully-audited quality run (``audit_fraction=1.0``
    — the realized cached-vs-true error this level pays).  Goodput and
    latency live on the deterministic engine-step clock, so the
    level-to-level curves are reproducible; only ``model_step_ms`` is
    wall-derived."""
    cfg, model, params = build_dit(dit)
    trace = overload_trace(requests=requests,
                           num_classes=cfg.dit.num_classes, seed=seed,
                           base_rate=base_rate, burst_rate=burst_rate,
                           burst_start=burst_start, burst_len=burst_len,
                           priority_mix=priority_mix,
                           deadline_slack=deadline_slack)
    report: Dict = {
        "config": {"dit": dit, "policy": policy, "requests": requests,
                   "slots": slots, "steps": steps, "guidance": guidance,
                   "seed": seed, "base_rate": base_rate,
                   "burst_rate": burst_rate, "burst_start": burst_start,
                   "burst_len": burst_len,
                   "priority_mix": list(priority_mix),
                   "deadline_slack": list(deadline_slack),
                   "levels": _levels_config(levels)},
        "levels": [],
    }
    for level in levels:
        coll = MetricsCollector(labels={"level": level.name,
                                        "policy": policy})
        row, done, sched = serve_level(model, params, trace, level,
                                       policy=policy, slots=slots,
                                       steps=steps, guidance=guidance,
                                       collector=coll, repeats=repeats)
        row["by_class"] = summarize_by_class(done + sched.rejected)
        # quality run: shadow-audit EVERY step (wall time unused — this
        # run pays the full uncached forward, it is not a perf
        # measurement); the audited error is what this shedding level
        # costs in output quality
        coll_q = MetricsCollector(labels={"level": level.name,
                                          "policy": policy})
        _, done_q, _ = serve_level(model, params, trace, level,
                                   policy=policy, slots=slots,
                                   steps=steps, guidance=guidance,
                                   audit_fraction=1.0, collector=coll_q)
        # headline quality cost: mean end-to-end (eps-space) audit error
        # per GATED audited slot-step, from the exact per-request error
        # budgets (obs/audit.py AUDIT_ACC_KEYS) rather than the bucketed
        # histogram.  Each request's first step is a warm-up full
        # forward — exact by construction — so counting it would dilute
        # shorter (shed) budgets' measured cost, masking the
        # approximation the level actually buys its speed with.
        err_sum = sum(float((r.cache or {}).get("audit_err_sum", 0.0))
                      for r in done_q)
        asteps = sum(float((r.cache or {}).get("audit_steps", 0.0))
                     for r in done_q)
        gated = asteps - len(done_q)
        row["audited_slot_steps"] = asteps
        row["audit_err_mean"] = err_sum / asteps if asteps else 0.0
        row["quality_cost"] = err_sum / gated if gated > 0 else 0.0
        row["audit_err_p50"] = coll_q.quantile("audit_rel_err", 0.50)
        row["audit_err_p95"] = coll_q.quantile("audit_rel_err", 0.95)
        row["bound_violations"] = coll_q.totals().get(
            "bound_violations_total", 0.0)
        report["levels"].append(row)
    goodputs = [r["goodput"] for r in report["levels"]]
    costs = [r["quality_cost"] for r in report["levels"]]
    report["goodput_monotone"] = _monotone(goodputs)
    report["quality_cost_monotone"] = _monotone(costs)
    return report


def trajectory(*, dit: str = "dit-b2", policy: str = "fastcache",
               requests: int = 24, slots: int = 2, steps: int = 8,
               guidance: float = 4.0, seed: int = 0,
               base_rate: float = 0.1, burst_rate: float = 1.5,
               burst_start: int = 2, burst_len: int = 12,
               priority_mix: Sequence[int] = (0, 1, 1, 2),
               deadline_slack: Sequence[int] = (12, 20, 32),
               levels: Sequence[ShedLevel] = DEFAULT_LADDER) -> Dict:
    """One BENCH_serving.json entry for the overload suite: one point
    per shedding level (policy key ``<policy>@<level>``, so
    ``bench_check`` gates each level's ``model_step_ms`` independently)
    plus the monotonicity headlines."""
    report = benchmark(dit=dit, policy=policy, requests=requests,
                       slots=slots, steps=steps, guidance=guidance,
                       seed=seed, base_rate=base_rate,
                       burst_rate=burst_rate, burst_start=burst_start,
                       burst_len=burst_len, priority_mix=priority_mix,
                       deadline_slack=deadline_slack, levels=levels)
    points = []
    for r in report["levels"]:
        points.append({k: r[k] for k in
                       ("policy", "level", "steps_scale", "alpha",
                        "capacity_scale",
                        "offered", "finished", "rejected", "deadline_met",
                        "goodput", "preemptions", "latency_steps_p50",
                        "latency_steps_p99", "queue_wait_p50",
                        "queue_wait_p95", "model_step_ms", "steps_per_s",
                        "cache_ratio", "audited_slot_steps",
                        "audit_err_mean", "quality_cost", "audit_err_p50",
                        "audit_err_p95", "bound_violations")})
    return {
        "date": time.strftime("%Y-%m-%d"),
        "suite": "serving_overload",
        "config": report["config"],
        "points": points,
        "goodput_monotone": report["goodput_monotone"],
        "quality_cost_monotone": report["quality_cost_monotone"],
    }


def config_kwargs(config: Dict) -> Dict:
    """Map a committed entry's config record back to ``trajectory()``
    keyword arguments (the shed ladder round-trips through its JSON
    form)."""
    kw = {k: config[k] for k in ("dit", "policy", "requests", "slots",
                                 "steps", "guidance", "seed", "base_rate",
                                 "burst_rate", "burst_start", "burst_len",
                                 "priority_mix", "deadline_slack")
          if k in config}
    if "levels" in config:
        kw["levels"] = _levels_from_config(config["levels"])
    return kw


def fresh_for_check(baseline: Dict) -> Dict:
    """bench_check hook: measure a fresh overload point with the
    committed baseline entry's config (including its shed ladder)."""
    return trajectory(**config_kwargs(baseline.get("config", {})))


def write_trajectory(path: str, **kw) -> Dict:
    """Append one overload trajectory entry to the shared BENCH file."""
    return append_entry(path, trajectory(**kw))


def run() -> List[dict]:
    """benchmarks/run.py driver entry: compact CSV rows."""
    report = benchmark()
    rows = []
    for r in report["levels"]:
        rows.append({
            "name": (f"serving_overload/{report['config']['dit']}"
                     f"/{r['policy']}"),
            "us_per_call": r["model_step_ms"] * 1e3,
            "derived": (f"goodput={r['goodput']:.2f}"
                        f" deadline_met={r['deadline_met']}/{r['offered']}"
                        f" rejected={r['rejected']}"
                        f" p99_latency_steps={r['latency_steps_p99']:.0f}"
                        f" quality_cost={r['quality_cost']:.4f}"
                        f" cache_ratio={r['cache_ratio']:.3f}"),
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dit", default="dit-b2")
    ap.add_argument("--policy", default="fastcache")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--guidance", type=float, default=4.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--base-rate", type=float, default=0.1)
    ap.add_argument("--burst-rate", type=float, default=1.5)
    ap.add_argument("--burst-start", type=int, default=2)
    ap.add_argument("--burst-len", type=int, default=12)
    ap.add_argument("--priority-mix", default="0,1,1,2",
                    help="comma list of priority classes requests draw "
                         "from uniformly (0 = most critical)")
    ap.add_argument("--deadline-slack", default="12,20,32",
                    help="comma list of deadline slacks (engine steps "
                         "past arrival) requests draw from uniformly")
    ap.add_argument("--json", default="",
                    help="write the JSON report here (default: stdout)")
    args = ap.parse_args()
    report = benchmark(
        dit=args.dit, policy=args.policy, requests=args.requests,
        slots=args.slots, steps=args.steps, guidance=args.guidance,
        seed=args.seed, base_rate=args.base_rate,
        burst_rate=args.burst_rate, burst_start=args.burst_start,
        burst_len=args.burst_len,
        priority_mix=[int(v) for v in args.priority_mix.split(",") if v],
        deadline_slack=[int(v) for v in args.deadline_slack.split(",")
                        if v])
    text = json.dumps(report, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        print(f"[serving_overload] report written to {args.json}")
    else:
        print(text)


if __name__ == "__main__":
    main()
