"""Serving benchmark: lockstep (fixed-wave) vs continuous-admission batching
for DiT sampling under a Poisson arrival trace.

Both modes serve the SAME request trace through the same engine; the only
difference is the admission policy — lockstep admits a new wave only when
every slot is free (a batched ``sample()`` loop), continuous admits into any
free slot mid-flight, which the per-slot FastCache state makes safe.  Late
arrivals therefore stop paying for their whole wave's completion, which is
the p95-latency win this benchmark measures.

    PYTHONPATH=src python -m benchmarks.serving_diffusion [--json out.json]

Emits a JSON report (stdout or --json path) with per-mode throughput,
p50/p95 request latency (engine-step clock + measured wall time per step)
and engine-level cache-ratio stats; also runnable through benchmarks/run.py
(suite name ``serving``) as compact CSV rows.

``--mesh 1x1,4x1,4x2`` adds a topology sweep: the SAME trace is served
through the single-device engine and through ``ShardedDiffusionEngine`` on
each listed ``(data, model)`` mesh (async host admission), reporting one
JSON row per topology — p50/p95 latency, steps/sec, cache ratio, and
max-abs-diff of every request's latents against the single-device run
(bitwise parity => 0.0).  Multi-device topologies on CPU need
``XLA_FLAGS=--xla_force_host_platform_device_count=N``; the ``bench-serve``
driver row (suite name ``serving_sharded``) sets that in a subprocess.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import build_dit
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT, registered_policies
from repro.obs import DEFAULT_AUDIT_FRACTION, MetricsCollector
from repro.serving import (DiffusionRequest, DiffusionServingEngine,
                           ShardedDiffusionEngine, make_serving_mesh,
                           poisson_trace)


def _fresh_trace(trace: List[DiffusionRequest]) -> List[DiffusionRequest]:
    """Engines mutate requests in place; each mode gets its own copies."""
    return [dataclasses.replace(r, latents=None, cache=None, admit_step=-1,
                                finish_step=-1, done=False,
                                queue_wait_steps=-1, reject_reason=None,
                                preemptions=0, steps_done=0, snapshot=None)
            for r in trace]


def serve_once(model, params, trace, *, policy: str, slots: int, steps: int,
               guidance: float, lockstep: bool, topology=None,
               async_admission: bool = True, max_steps=None,
               sched_policy: str = "fifo", collector=None,
               enable_metrics: bool = True, audit_fraction: float = 0.0,
               audit_seed: int = 0, fc: FastCacheConfig = None
               ) -> Tuple[Dict, List[DiffusionRequest]]:
    """One engine run over a fresh copy of ``trace``; returns (result row,
    finished requests).  ``topology`` (data, model) != (1, 1) serves
    through the sharded engine on that mesh.  ``max_steps`` sizes the plan
    tables for heterogeneous traces (defaults to ``steps``);
    ``sched_policy`` picks the admission order (fifo / sjf);
    ``collector``/``enable_metrics`` thread the obs plane through the
    engine (``enable_metrics=False`` traces a metrics-free step, the
    A/B baseline for the telemetry-overhead row in the trajectory);
    ``audit_fraction > 0`` arms the shadow-compute audit plane on that
    fraction of serve steps (requires metrics); ``fc`` overrides the
    runner's FastCacheConfig (e.g. to switch the token-merge stage on)."""
    runner = CachedDiT(model, fc or FastCacheConfig(), policy=policy)
    if topology and tuple(topology) != (1, 1):
        data, tp = topology
        engine = ShardedDiffusionEngine(
            runner, params, max_slots=slots, num_steps=steps,
            guidance_scale=guidance, max_steps=max_steps,
            mesh=make_serving_mesh(data, tp),
            async_admission=async_admission, collector=collector,
            enable_metrics=enable_metrics, audit_fraction=audit_fraction,
            audit_seed=audit_seed)
    else:
        engine = DiffusionServingEngine(runner, params, max_slots=slots,
                                        num_steps=steps,
                                        guidance_scale=guidance,
                                        max_steps=max_steps,
                                        collector=collector,
                                        enable_metrics=enable_metrics,
                                        audit_fraction=audit_fraction,
                                        audit_seed=audit_seed)
    reqs = _fresh_trace(trace)
    # warm the jitted serve_step so wall-time excludes compilation, then
    # rewind the clock so the trace's absolute arrival steps line up
    warm = _fresh_trace(trace[:1])
    for r in warm:
        r.arrival_step = 0
    engine.run(warm)
    engine.reset_clock()
    t0 = time.perf_counter()
    done = engine.run(reqs, lockstep=lockstep, sched_policy=sched_policy)
    wall = time.perf_counter() - t0
    assert len(done) == len(trace), (len(done), len(trace))
    lats = np.array([r.latency_steps for r in done], np.float64)
    # per-MODEL-step time: idle clock ticks cost no wall time, so dividing
    # by engine.clock would flatter whichever mode idles more
    model_step_ms = wall / max(1, engine.model_steps) * 1e3
    res = {
        "mode": "lockstep" if lockstep else "continuous",
        "sched_policy": sched_policy,
        "policy": policy,
        "topology": {"data": 1, "model": 1, "devices": 1},
        "requests": len(done),
        "engine_steps": engine.clock,
        "model_steps": engine.model_steps,
        "wall_s": wall,
        "requests_per_s": len(done) / wall if wall else 0.0,
        "steps_per_s": engine.model_steps / wall if wall else 0.0,
        "model_step_ms": model_step_ms,
        "latency_steps_p50": float(np.percentile(lats, 50)),
        "latency_steps_p95": float(np.percentile(lats, 95)),
        "cache": engine.cache_stats(),
    }
    if isinstance(engine, ShardedDiffusionEngine):
        res["topology"] = engine.topology()
        res["async_admission"] = engine.async_admission
    return res, done


def benchmark(*, dit: str = "dit-b2", policies=("nocache", "fastcache"),
              requests: int = 10, slots: int = 2, steps: int = 8,
              guidance: float = 4.0, rate: float = 0.25,
              seed: int = 0) -> Dict:
    cfg, model, params = build_dit(dit)
    trace = poisson_trace(requests, rate, seed=seed,
                          num_classes=cfg.dit.num_classes)
    report: Dict = {
        "config": {"dit": dit, "requests": requests, "slots": slots,
                   "steps": steps, "guidance": guidance,
                   "poisson_rate": rate, "seed": seed},
        "runs": [],
    }
    for policy in policies:
        for lockstep in (True, False):
            res, _ = serve_once(model, params, trace, policy=policy,
                                slots=slots, steps=steps, guidance=guidance,
                                lockstep=lockstep)
            report["runs"].append(res)
    # headline: continuous must beat lockstep on p95 under queueing pressure
    for policy in policies:
        runs = {r["mode"]: r for r in report["runs"]
                if r["policy"] == policy}
        report[f"p95_speedup_steps_{policy}"] = (
            runs["lockstep"]["latency_steps_p95"]
            / max(runs["continuous"]["latency_steps_p95"], 1e-9))
    return report


def trajectory(*, dit: str = "dit-b2", policies=None, requests: int = 6,
               slots: int = 2, steps: int = 8, guidance: float = 4.0,
               rate: float = 0.25, seed: int = 0, repeats: int = 3,
               merge_ratio: float = 0.5, merge_window: int = 16) -> Dict:
    """One perf-trajectory entry: every registered cache policy served
    through the continuous engine with the metrics plane ON (a live
    ``MetricsCollector``, harvested at run end) and OFF (the A/B
    baseline) — so the committed ``BENCH_serving.json`` carries both the
    per-policy serving numbers and the telemetry-overhead headline.

    A single short CPU run is wall-clock noisy, so each (policy, mode)
    pair is served ``repeats`` times interleaved (off/on/audit ... to
    cancel clock drift) and scored by its best wall time; the headline
    ``metrics_overhead_pct`` further aggregates best-run model-step wall
    across ALL policies, which is what the < 5% acceptance bar is
    checked against.

    Quality columns (the audit plane, PR 8): every policy is additionally
    served once with ``audit_fraction=1.0`` — every step shadow-audited —
    and the per-policy ``audit_err_p50/p95`` quantiles of the measured
    cached-vs-true relative error land next to its perf numbers, plus
    ``bound_violations`` against the policy's chi^2-predicted bound.  The
    cost of auditing at the production ``DEFAULT_AUDIT_FRACTION`` is
    measured separately (``model_step_ms_audit``) and aggregated into the
    ``audit_overhead_pct`` headline (vs the metrics-on baseline — the <5%
    acceptance bar).

    Token-compression columns: every policy is additionally served with
    the serving-path merge stage ON (``merge_ratio`` centers kept per
    ``merge_window`` tokens, the same repeats/best-wall protocol) —
    ``model_step_ms_merge`` next to the merge-off ``model_step_ms``
    quantifies the reduced-grid speedup, and a fully-audited merge run
    reports ``merge_audit_err_p50/p95``, the realized end-to-end error of
    merge+cache vs the uncached full-resolution forward."""
    policies = tuple(policies) if policies else registered_policies()
    cfg, model, params = build_dit(dit)
    trace = poisson_trace(requests, rate, seed=seed,
                          num_classes=cfg.dit.num_classes)
    entry: Dict = {
        "date": time.strftime("%Y-%m-%d"),
        "suite": "serving",
        "config": {"dit": dit, "requests": requests, "slots": slots,
                   "steps": steps, "guidance": guidance,
                   "poisson_rate": rate, "seed": seed, "repeats": repeats,
                   "merge_ratio": merge_ratio,
                   "merge_window": merge_window, "mode": "continuous"},
        "points": [],
    }
    fc_merge = FastCacheConfig(merge_enabled=True, merge_ratio=merge_ratio,
                               merge_window=merge_window)
    wall_on = wall_off = wall_audit = 0.0
    steps_on = steps_off = steps_audit = 0
    for policy in policies:
        res_off = res_on = res_audit = res_merge = collector = None
        for _ in range(max(1, repeats)):
            off, _ = serve_once(model, params, trace, policy=policy,
                                slots=slots, steps=steps,
                                guidance=guidance, lockstep=False,
                                enable_metrics=False)
            coll = MetricsCollector(labels={"policy": policy, "dit": dit})
            on, _ = serve_once(model, params, trace, policy=policy,
                               slots=slots, steps=steps,
                               guidance=guidance, lockstep=False,
                               collector=coll)
            aud, _ = serve_once(model, params, trace, policy=policy,
                                slots=slots, steps=steps,
                                guidance=guidance, lockstep=False,
                                collector=MetricsCollector(),
                                audit_fraction=DEFAULT_AUDIT_FRACTION)
            mrg, _ = serve_once(model, params, trace, policy=policy,
                                slots=slots, steps=steps,
                                guidance=guidance, lockstep=False,
                                collector=MetricsCollector(),
                                fc=fc_merge)
            if res_off is None or off["wall_s"] < res_off["wall_s"]:
                res_off = off
            if res_on is None or on["wall_s"] < res_on["wall_s"]:
                res_on, collector = on, coll
            if res_audit is None or aud["wall_s"] < res_audit["wall_s"]:
                res_audit = aud
            if res_merge is None or mrg["wall_s"] < res_merge["wall_s"]:
                res_merge = mrg
        totals = collector.totals()
        # quality row: audit EVERY step once (wall time unused — this run
        # pays the full shadow forward, it is not a perf measurement)
        coll_q = MetricsCollector(labels={"policy": policy, "dit": dit})
        _, _ = serve_once(model, params, trace, policy=policy, slots=slots,
                          steps=steps, guidance=guidance, lockstep=False,
                          collector=coll_q, audit_fraction=1.0)
        q_totals = coll_q.totals()
        # merge quality row: the audit plane's shadow forward stays at
        # full resolution, so the audited error IS merge+cache vs nocache
        coll_m = MetricsCollector(labels={"policy": policy, "dit": dit})
        _, _ = serve_once(model, params, trace, policy=policy, slots=slots,
                          steps=steps, guidance=guidance, lockstep=False,
                          collector=coll_m, audit_fraction=1.0, fc=fc_merge)
        m_totals = coll_m.totals()
        wall_on += res_on["wall_s"]
        wall_off += res_off["wall_s"]
        wall_audit += res_audit["wall_s"]
        steps_on += res_on["model_steps"]
        steps_off += res_off["model_steps"]
        steps_audit += res_audit["model_steps"]
        entry["points"].append({
            "policy": policy,
            "requests": res_on["requests"],
            "latency_steps_p50": res_on["latency_steps_p50"],
            "latency_steps_p95": res_on["latency_steps_p95"],
            "steps_per_s": res_on["steps_per_s"],
            "model_step_ms": res_on["model_step_ms"],
            "model_step_ms_metrics_off": res_off["model_step_ms"],
            "model_step_ms_audit": res_audit["model_step_ms"],
            "cache_ratio": res_on["cache"]["block_cache_ratio"],
            "serve_steps_total": totals.get("serve_steps_total", 0.0),
            "cache_step_reuses_total": totals.get(
                "cache_step_reuses_total", 0.0),
            "audit_err_p50": coll_q.quantile("audit_rel_err", 0.50),
            "audit_err_p95": coll_q.quantile("audit_rel_err", 0.95),
            "bound_violations": q_totals.get("bound_violations_total",
                                             0.0),
            "model_step_ms_merge": res_merge["model_step_ms"],
            "merge_speedup": (res_on["model_step_ms"]
                              / max(res_merge["model_step_ms"], 1e-9)),
            "tokens_kept_total": m_totals.get("tokens_kept_total", 0.0),
            "tokens_merged_total": m_totals.get("tokens_merged_total",
                                                0.0),
            "merge_audit_err_p50": coll_m.quantile("audit_rel_err", 0.50),
            "merge_audit_err_p95": coll_m.quantile("audit_rel_err", 0.95),
        })
    ms_on = wall_on / max(1, steps_on) * 1e3
    ms_off = wall_off / max(1, steps_off) * 1e3
    ms_audit = wall_audit / max(1, steps_audit) * 1e3
    entry["model_step_ms_on"] = ms_on
    entry["model_step_ms_off"] = ms_off
    entry["metrics_overhead_pct"] = (ms_on - ms_off) / ms_off * 100.0 \
        if ms_off else 0.0
    # audit overhead is measured against the metrics-on baseline (the
    # audit plane requires the metrics plane) at the production fraction
    entry["audit_fraction"] = DEFAULT_AUDIT_FRACTION
    entry["model_step_ms_audit"] = ms_audit
    entry["audit_overhead_pct"] = (ms_audit - ms_on) / ms_on * 100.0 \
        if ms_on else 0.0
    return entry


def _entry_key(entry: Dict) -> Tuple[str, str, str]:
    """Dedupe identity for a trajectory entry: same suite + same day +
    same benchmark config (canonical JSON) means a re-run, not a new
    point.  Entries written before suites shared the BENCH file carry no
    ``suite`` field and default to ``serving``."""
    return (entry.get("suite", "serving"), entry.get("date", ""),
            json.dumps(entry.get("config", {}), sort_keys=True))


def append_entry(path: str, entry: Dict) -> Dict:
    """Append one trajectory entry to the BENCH file at ``path`` (created
    if absent), preserving prior entries so the file accumulates one
    point per PR.  Re-running on the same day with the same (suite,
    config) REPLACES that entry in place instead of appending a duplicate
    — the trajectory stays one point per (suite, date, config), so
    iterating on a PR does not pad the committed history.  Shared by
    every suite that writes into the serving BENCH file (``serving``
    here, ``serving_overload`` in benchmarks/serving_overload.py)."""
    doc = {"schema": 1, "suite": "serving", "entries": []}
    try:
        with open(path) as f:
            prev = json.load(f)
        if prev.get("schema") == 1 and isinstance(prev.get("entries"),
                                                  list):
            doc = prev
    except (OSError, ValueError):
        pass
    key = _entry_key(entry)
    # drop any same-key predecessors, then append: the fresh entry is
    # always entries[-1] among its suite and entries stay date-ordered
    # (the key includes today's date, so only today's re-runs are
    # replaced)
    doc["entries"] = [e for e in doc["entries"] if _entry_key(e) != key]
    doc["entries"].append(entry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return doc


def config_kwargs(config: Dict) -> Dict:
    """Map a committed entry's config record back to ``trajectory()``
    keyword arguments (``poisson_rate`` -> ``rate``; ``mode`` is
    implied)."""
    kw = {k: config[k] for k in ("dit", "requests", "slots", "steps",
                                 "guidance", "seed", "repeats",
                                 "merge_ratio", "merge_window")
          if k in config}
    if "poisson_rate" in config:
        kw["rate"] = config["poisson_rate"]
    return kw


def fresh_for_check(baseline: Dict) -> Dict:
    """bench_check hook: measure a fresh trajectory point with the
    committed baseline entry's config and policy set."""
    policies = tuple(p["policy"] for p in baseline.get("points", []))
    return trajectory(policies=policies or None,
                      **config_kwargs(baseline.get("config", {})))


def write_trajectory(path: str, **kw) -> Dict:
    """Append one ``trajectory()`` entry to the BENCH file at ``path``."""
    return append_entry(path, trajectory(**kw))


def parse_topologies(spec: str) -> List[tuple]:
    """'1x1,4x1,4x2' -> [(1, 1), (4, 1), (4, 2)] (data x model)."""
    out = []
    for part in spec.split(","):
        if not part.strip():
            continue
        d, m = part.lower().split("x")
        out.append((int(d), int(m)))
    return out


def benchmark_topologies(*, topologies, dit: str = "dit-b2",
                         policies=("fastcache",), requests: int = 8,
                         slots: int = 4, steps: int = 8,
                         guidance: float = 4.0, rate: float = 0.25,
                         seed: int = 0) -> Dict:
    """Serve the SAME Poisson trace through every listed (data, model)
    topology — (1, 1) is the single-device ``DiffusionServingEngine``,
    everything else ``ShardedDiffusionEngine`` with async admission — for
    every listed policy, reporting one row per (policy, topology).
    Parity fields (``max_abs_diff_vs_single``,
    ``schedule_identical_vs_single``) are emitted only when that policy's
    (1, 1) run is in the sweep to compare against.  Topologies that need
    more devices than available, or that the engine's numerics self-check
    refuses, are reported as skipped rather than failing the sweep."""
    import jax
    cfg, model, params = build_dit(dit)
    trace = poisson_trace(requests, rate, seed=seed,
                          num_classes=cfg.dit.num_classes)
    report: Dict = {
        "config": {"dit": dit, "policies": list(policies),
                   "requests": requests, "slots": slots, "steps": steps,
                   "guidance": guidance, "poisson_rate": rate,
                   "seed": seed, "device_count": jax.device_count()},
        "topologies": [],
    }
    for policy in policies:
        # parity baseline: strictly the single-device (1, 1) run
        baseline: Dict[str, Dict] = {}
        for topo in topologies:
            need = topo[0] * topo[1]
            topo_info = {"data": topo[0], "model": topo[1],
                         "devices": need}
            if need > jax.device_count():
                report["topologies"].append(
                    {"policy": policy, "topology": topo_info,
                     "skipped": f"needs {need} devices, have "
                                f"{jax.device_count()}"})
                continue
            try:
                res, done = serve_once(model, params, trace, policy=policy,
                                       slots=slots, steps=steps,
                                       guidance=guidance, lockstep=False,
                                       topology=topo)
            except RuntimeError as e:
                # e.g. the engine's startup numerics self-check refusing a
                # mesh the backend's partitioner miscompiles
                report["topologies"].append(
                    {"policy": policy, "topology": topo_info,
                     "skipped": str(e)})
                continue
            sched = {r.rid: (r.admit_step, r.finish_step) for r in done}
            if tuple(topo) == (1, 1):
                baseline = {"latents": {r.rid: r.latents for r in done},
                            "sched": sched}
                res["max_abs_diff_vs_single"] = 0.0
                res["schedule_identical_vs_single"] = True
            elif baseline:
                # scheduling parity is exact (host bookkeeping is
                # topology-independent); latents are compared by
                # max-abs-diff because XLA:CPU gemms are batch-shape
                # sensitive — a 2-row and an 8-row matmul differ in the
                # last bits, which the recursive DDIM update then
                # amplifies (bitwise-parity regime: see
                # tests/test_sharded_serving.py)
                res["max_abs_diff_vs_single"] = max(
                    float(np.max(np.abs(np.asarray(r.latents)
                                        - baseline["latents"][r.rid])))
                    for r in done)
                res["schedule_identical_vs_single"] = (
                    sched == baseline["sched"])
            report["topologies"].append(res)
    return report


def run() -> List[dict]:
    """benchmarks/run.py driver entry: compact CSV rows."""
    report = benchmark()
    rows = []
    for r in report["runs"]:
        rows.append({
            "name": (f"serving/{report['config']['dit']}/{r['policy']}"
                     f"/{r['mode']}"),
            "us_per_call": r["model_step_ms"] * 1e3,
            "derived": (f"p95_latency_steps={r['latency_steps_p95']:.0f}"
                        f" p50={r['latency_steps_p50']:.0f}"
                        f" req_per_s={r['requests_per_s']:.2f}"
                        f" cache_ratio="
                        f"{r['cache']['block_cache_ratio']:.3f}"),
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dit", default="dit-b2")
    ap.add_argument("--policies", default="nocache,fastcache")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--guidance", type=float, default=4.0)
    ap.add_argument("--rate", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="",
                    help="topology sweep instead of the mode comparison: "
                         "comma list of DATAxMODEL meshes, e.g. 1x1,4x1,4x2")
    ap.add_argument("--json", default="",
                    help="write the JSON report here (default: stdout)")
    args = ap.parse_args()
    if args.mesh:
        report = benchmark_topologies(
            topologies=parse_topologies(args.mesh), dit=args.dit,
            policies=tuple(p for p in args.policies.split(",") if p),
            requests=args.requests, slots=args.slots, steps=args.steps,
            guidance=args.guidance, rate=args.rate, seed=args.seed)
    else:
        report = benchmark(dit=args.dit,
                           policies=tuple(p for p in
                                          args.policies.split(",") if p),
                           requests=args.requests, slots=args.slots,
                           steps=args.steps, guidance=args.guidance,
                           rate=args.rate, seed=args.seed)
    text = json.dumps(report, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        print(f"[serving_diffusion] report written to {args.json}")
    else:
        print(text)


if __name__ == "__main__":
    main()
