"""Serving benchmark: lockstep (fixed-wave) vs continuous-admission batching
for DiT sampling under a Poisson arrival trace.

Both modes serve the SAME request trace through the same engine; the only
difference is the admission policy — lockstep admits a new wave only when
every slot is free (a batched ``sample()`` loop), continuous admits into any
free slot mid-flight, which the per-slot FastCache state makes safe.  Late
arrivals therefore stop paying for their whole wave's completion, which is
the p95-latency win this benchmark measures.

    PYTHONPATH=src python -m benchmarks.serving_diffusion [--json out.json]

Emits a JSON report (stdout or --json path) with per-mode throughput,
p50/p95 request latency (engine-step clock + measured wall time per step)
and engine-level cache-ratio stats; also runnable through benchmarks/run.py
(suite name ``serving``) as compact CSV rows.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import build_dit
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT
from repro.serving import (DiffusionRequest, DiffusionServingEngine,
                           poisson_trace)


def _fresh_trace(trace: List[DiffusionRequest]) -> List[DiffusionRequest]:
    """Engines mutate requests in place; each mode gets its own copies."""
    return [dataclasses.replace(r, latents=None, admit_step=-1,
                                finish_step=-1, done=False) for r in trace]


def serve_once(model, params, trace, *, policy: str, slots: int, steps: int,
               guidance: float, lockstep: bool) -> Dict:
    runner = CachedDiT(model, FastCacheConfig(), policy=policy)
    engine = DiffusionServingEngine(runner, params, max_slots=slots,
                                    num_steps=steps,
                                    guidance_scale=guidance)
    reqs = _fresh_trace(trace)
    # warm the jitted serve_step so wall-time excludes compilation, then
    # rewind the clock so the trace's absolute arrival steps line up
    warm = _fresh_trace(trace[:1])
    for r in warm:
        r.arrival_step = 0
    engine.run(warm)
    engine.reset_clock()
    t0 = time.perf_counter()
    done = engine.run(reqs, lockstep=lockstep)
    wall = time.perf_counter() - t0
    assert len(done) == len(trace), (len(done), len(trace))
    lats = np.array([r.latency_steps for r in done], np.float64)
    # per-MODEL-step time: idle clock ticks cost no wall time, so dividing
    # by engine.clock would flatter whichever mode idles more
    model_step_ms = wall / max(1, engine.model_steps) * 1e3
    return {
        "mode": "lockstep" if lockstep else "continuous",
        "policy": policy,
        "requests": len(done),
        "engine_steps": engine.clock,
        "model_steps": engine.model_steps,
        "wall_s": wall,
        "requests_per_s": len(done) / wall if wall else 0.0,
        "model_step_ms": model_step_ms,
        "latency_steps_p50": float(np.percentile(lats, 50)),
        "latency_steps_p95": float(np.percentile(lats, 95)),
        "cache": engine.cache_stats(),
    }


def benchmark(*, dit: str = "dit-b2", policies=("nocache", "fastcache"),
              requests: int = 10, slots: int = 2, steps: int = 8,
              guidance: float = 4.0, rate: float = 0.25,
              seed: int = 0) -> Dict:
    cfg, model, params = build_dit(dit)
    trace = poisson_trace(requests, rate, seed=seed,
                          num_classes=cfg.dit.num_classes)
    report: Dict = {
        "config": {"dit": dit, "requests": requests, "slots": slots,
                   "steps": steps, "guidance": guidance,
                   "poisson_rate": rate, "seed": seed},
        "runs": [],
    }
    for policy in policies:
        for lockstep in (True, False):
            res = serve_once(model, params, trace, policy=policy,
                             slots=slots, steps=steps, guidance=guidance,
                             lockstep=lockstep)
            report["runs"].append(res)
    # headline: continuous must beat lockstep on p95 under queueing pressure
    for policy in policies:
        runs = {r["mode"]: r for r in report["runs"]
                if r["policy"] == policy}
        report[f"p95_speedup_steps_{policy}"] = (
            runs["lockstep"]["latency_steps_p95"]
            / max(runs["continuous"]["latency_steps_p95"], 1e-9))
    return report


def run() -> List[dict]:
    """benchmarks/run.py driver entry: compact CSV rows."""
    report = benchmark()
    rows = []
    for r in report["runs"]:
        rows.append({
            "name": (f"serving/{report['config']['dit']}/{r['policy']}"
                     f"/{r['mode']}"),
            "us_per_call": r["model_step_ms"] * 1e3,
            "derived": (f"p95_latency_steps={r['latency_steps_p95']:.0f}"
                        f" p50={r['latency_steps_p50']:.0f}"
                        f" req_per_s={r['requests_per_s']:.2f}"
                        f" cache_ratio="
                        f"{r['cache']['block_cache_ratio']:.3f}"),
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dit", default="dit-b2")
    ap.add_argument("--policies", default="nocache,fastcache")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--guidance", type=float, default=4.0)
    ap.add_argument("--rate", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="",
                    help="write the JSON report here (default: stdout)")
    args = ap.parse_args()
    report = benchmark(dit=args.dit,
                       policies=tuple(p for p in args.policies.split(",")
                                      if p),
                       requests=args.requests, slots=args.slots,
                       steps=args.steps, guidance=args.guidance,
                       rate=args.rate, seed=args.seed)
    text = json.dumps(report, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        print(f"[serving_diffusion] report written to {args.json}")
    else:
        print(text)


if __name__ == "__main__":
    main()
