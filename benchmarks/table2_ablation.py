"""Paper Table 2 / Table 9: module ablation — STR (spatial token reduction),
SC (statistical caching), MB (motion-aware blending)."""
from __future__ import annotations

from typing import List

from repro.configs.base import FastCacheConfig

from benchmarks.common import build_dit, frechet_proxy, rel_err, timed_sample

COMBOS = [  # (STR, SC, MB) — same rows as the paper's Table 2
    (False, False, False),
    (True, False, True),
    (False, True, True),
    (True, True, False),
    (True, True, True),
]


def run(model_name: str = "dit-l2", steps: int = 12) -> List[dict]:
    cfg, model, params = build_dit(model_name)
    ref, _ = timed_sample(model, params, FastCacheConfig(), "nocache",
                          steps=steps, repeats=1)
    rows = []
    for use_str, use_sc, use_mb in COMBOS:
        fc = FastCacheConfig(use_str=use_str, use_sc=use_sc, use_mb=use_mb)
        policy = "fastcache" if (use_str or use_sc or use_mb) else "nocache"
        x, st = timed_sample(model, params, fc, policy, steps=steps)
        tag = "".join("SX"[not b] for b in (use_str, use_sc, use_mb))
        rows.append({
            "name": f"table2/{model_name}/STR={int(use_str)}"
                    f"_SC={int(use_sc)}_MB={int(use_mb)}",
            "us_per_call": st["us_per_step"],
            "derived": (f"cache_ratio={st['block_cache_ratio']:.3f}"
                        f" motion_frac={st['mean_motion_fraction']:.3f}"
                        f" rel_err={rel_err(x, ref):.4f}"),
        })
    return rows
