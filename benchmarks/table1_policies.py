"""Paper Table 1 / Table 12: cache-policy comparison across DiT variants.

Per (DiT variant x policy): sampling wall-time, per-step latency, block cache
ratio, steps reused, and quality proxies vs the exact sampler.

The policy column is driven by the plugin registry (``repro.core.POLICIES``)
minus l2c (whose default mask skips nothing — it needs offline calibration
to say anything), so a newly registered policy lands a Table 1 row with no
edit here: the SmoothCache-style layer-schedule policy arrived exactly that
way.
"""
from __future__ import annotations

from typing import List

from repro.configs.base import FastCacheConfig
from repro.core import POLICIES as REGISTERED

from benchmarks.common import (build_dit, frechet_proxy, rel_err,
                               timed_sample)

POLICIES = tuple(p for p in REGISTERED if p != "l2c")


def run(models=("dit-b2", "dit-xl2"), steps: int = 12) -> List[dict]:
    rows = []
    fc = FastCacheConfig()
    for name in models:
        cfg, model, params = build_dit(name)
        ref, _ = timed_sample(model, params, fc, "nocache", steps=steps,
                              repeats=1)
        for policy in POLICIES:
            x, st = timed_sample(model, params, fc, policy, steps=steps)
            rows.append({
                "name": f"table1/{name}/{policy}",
                "us_per_call": st["us_per_step"],
                "derived": (f"cache_ratio={st['block_cache_ratio']:.3f}"
                            f" steps_reused={st['steps_reused']:.0f}"
                            f" rel_err={rel_err(x, ref):.4f}"
                            f" fid_proxy={frechet_proxy(x, ref):.4f}"),
            })
    return rows
