"""Paper Table 5 + Figure 1: static/dynamic token-ratio behaviour under
motion — FastCache's saliency split vs FBCache's all-or-nothing gate, driven
by the synthetic video workload (static background + moving foreground).

The paper's claims checked here: (a) FastCache's static ratio exceeds
FBCache's at matched settings, (b) static ratio falls as motion amplitude
rises (Fig. 1 interpretation), with an average >~50% static hidden states on
low-motion content (Appendix E.10)."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT, summarize_stats
from repro.data import video_latents

from benchmarks.common import build_dit


def _drive_video(model, params, policy, fc, frames, **kw):
    runner = CachedDiT(model, fc, policy=policy, **kw)
    b = frames.shape[0]
    state = runner.init_state(b)
    step = jax.jit(runner.step)
    labels = jnp.zeros((b,), jnp.int32)
    for t in range(frames.shape[1]):
        # treat each video frame as the next iterate (per-frame denoise eval)
        eps, state = step(params, state, frames[:, t],
                          jnp.full((b,), 25), labels)
    return summarize_stats(state)


def run(model_name: str = "dit-b2", frames: int = 10) -> List[dict]:
    cfg, model, params = build_dit(model_name)
    img = cfg.dit.image_size
    rows = []
    for label, amp in (("static", 0.0), ("low_motion", 0.5),
                       ("high_motion", 2.0)):
        vid = video_latents(2, frames, img, cfg.dit.in_channels,
                            motion_amplitude=amp, seed=1)
        st_fc = _drive_video(model, params, "fastcache",
                             FastCacheConfig(), vid)
        st_fb = _drive_video(model, params, "fbcache", FastCacheConfig(),
                             vid)
        static_fc = 1.0 - st_fc["mean_motion_fraction"]
        rows.append({
            "name": f"table5/{model_name}/{label}/fastcache",
            "us_per_call": 0.0,
            "derived": (f"static_ratio={static_fc:.3f}"
                        f" block_cache_ratio={st_fc['block_cache_ratio']:.3f}"),
        })
        rows.append({
            "name": f"table5/{model_name}/{label}/fbcache",
            "us_per_call": 0.0,
            "derived": (f"steps_reused={st_fb['steps_reused']:.0f}"
                        f" block_cache_ratio={st_fb['block_cache_ratio']:.3f}"),
        })
    return rows
