"""Token-compression sweep: the serving-path merge stage measured end to
end (replaces the old ``table15_knn`` microbenchmark, whose K sweep is
folded in below).

Per registered policy of interest the SAME Poisson trace is served through
the continuous engine with the merge stage off (the r=1.0 baseline) and at
each keep ratio r, measuring what the stage actually buys and costs on the
serving path rather than on a synthetic tensor:

- ``model_step_ms`` — per-model-step wall time on the reduced grid;
- ``audit_err_p50`` — the shadow-audit plane's end-to-end relative eps
  error (merge+cache vs the uncached full-resolution forward, every step
  audited);
- ``latent_rel_err`` — an FID-proxy: per-request relative error of the
  finished latents against the merge-off run of the same request.

The paper's Table 15 K sweep rides the same harness: fastcache at r=0.5
across kNN K values, reporting the same three columns.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import build_dit
from benchmarks.serving_diffusion import serve_once
from repro.configs.base import FastCacheConfig
from repro.obs import MetricsCollector
from repro.serving import poisson_trace

RATIOS = (0.75, 0.5, 0.25)
KNN_KS = (3, 5, 7, 10)
POLICIES = ("nocache", "fastcache")


def _latent_rel_err(done, baseline: Dict[int, np.ndarray]) -> float:
    errs = []
    for r in done:
        ref = baseline[r.rid]
        x = np.asarray(r.latents, np.float64)
        errs.append(float(np.linalg.norm(x - ref)
                          / max(np.linalg.norm(ref), 1e-12)))
    return float(np.mean(errs))


def _serve(model, params, trace, policy, fc, **kw):
    coll = MetricsCollector()
    res, done = serve_once(model, params, trace, policy=policy, slots=2,
                           steps=6, guidance=4.0, lockstep=False,
                           collector=coll, audit_fraction=1.0, fc=fc, **kw)
    return res, done, coll


def run(model_name: str = "dit-b2") -> List[dict]:
    cfg, model, params = build_dit(model_name)
    trace = poisson_trace(4, 0.25, seed=0, num_classes=cfg.dit.num_classes)
    window = 16
    rows: List[dict] = []
    for policy in POLICIES:
        res0, done0, _ = _serve(model, params, trace, policy, None)
        baseline = {r.rid: np.asarray(r.latents, np.float64) for r in done0}
        rows.append({
            "name": f"tokens/{policy}/r=1.00",
            "us_per_call": res0["model_step_ms"] * 1e3,
            "derived": "tokens_kept=1.000 audit_err_p50=0"
                       " latent_rel_err=0",
        })
        for ratio in RATIOS:
            fc = FastCacheConfig(merge_enabled=True, merge_ratio=ratio,
                                 merge_window=window)
            res, done, coll = _serve(model, params, trace, policy, fc)
            t = coll.totals()
            kept = t.get("tokens_kept_total", 0.0)
            frac = kept / max(kept + t.get("tokens_merged_total", 0.0), 1.0)
            rows.append({
                "name": f"tokens/{policy}/r={ratio:.2f}",
                "us_per_call": res["model_step_ms"] * 1e3,
                "derived": (f"tokens_kept={frac:.3f}"
                            f" audit_err_p50="
                            f"{coll.quantile('audit_rel_err', 0.5):.4f}"
                            f" latent_rel_err="
                            f"{_latent_rel_err(done, baseline):.4f}"),
            })
    # Table 15's K sweep on the serving path: fastcache, r=0.5
    _, done0, _ = _serve(model, params, trace, "fastcache", None)
    baseline = {r.rid: np.asarray(r.latents, np.float64) for r in done0}
    for k in KNN_KS:
        fc = FastCacheConfig(merge_enabled=True, merge_ratio=0.5,
                             merge_window=window, knn_k=k)
        res, done, coll = _serve(model, params, trace, "fastcache", fc)
        rows.append({
            "name": f"tokens/knn_k/K={k}",
            "us_per_call": res["model_step_ms"] * 1e3,
            "derived": (f"audit_err_p50="
                        f"{coll.quantile('audit_rel_err', 0.5):.4f}"
                        f" latent_rel_err="
                        f"{_latent_rel_err(done, baseline):.4f}"),
        })
    return rows
