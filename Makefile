# One-command verify/bench entry points (the tier-1 command of ROADMAP.md).
.PHONY: test test-fast test-serving test-sharded test-policies test-obs \
	test-slo lint bench-smoke bench-serve bench bench-trajectory \
	bench-check metrics-doc

test:
	PYTHONPATH=src python -m pytest -x -q

# repo-specific static analysis (six AST checks over src/) plus runtime
# validation of every registered cache policy's state-pytree contract;
# exits non-zero with file:line diagnostics on any finding
lint:
	PYTHONPATH=src python -m tools.reprolint src

# skip the slow dry-run subprocess compiles (~4 min) and the serving +
# per-policy + observability suites (each has its own target/CI job)
test-fast:
	PYTHONPATH=src python -m pytest -x -q \
		-m "not slow and not serving and not policies and not obs and not slo"

# the continuous-batching engine suites (AR decode + diffusion)
test-serving:
	PYTHONPATH=src python -m pytest -x -q -m serving

# the cache-policy plugin suite across the registry: per-policy state
# minimality + bitwise parity against the pre-refactor golden run
test-policies:
	PYTHONPATH=src python -m pytest -x -q -m policies

# sharded-vs-single-device bitwise parity on an 8-virtual-device CPU mesh
# (XLA only honors the flag at first jax init, so it must be in the env
# before pytest starts — do not fold this into the main suite)
test-sharded:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" PYTHONPATH=src \
		python -m pytest -x -q -m distributed

# the observability suite: metrics plane, trace export, calibration
test-obs:
	PYTHONPATH=src python -m pytest -x -q -m obs

# the SLO control plane: priority/EDF scheduling, admission, preempt/resume
# bitwise parity, degradation ladder, multi-replica routing
test-slo:
	PYTHONPATH=src python -m pytest -x -q -m slo

bench-smoke:
	PYTHONPATH=src python -m benchmarks.run --only batched_gate,decode_gate

# append one per-policy perf-trajectory entry to the committed BENCH file
# (re-runs on the same day with the same config replace, not duplicate)
bench-trajectory:
	PYTHONPATH=src python -m benchmarks.run \
		--suite serving,serving_overload --bench-out BENCH_serving.json

# CI perf-regression gate: fresh trajectory point vs the committed BENCH
# baseline; fails on >25% model_step_ms regression for any policy
# (override with BENCH_CHECK_OVERRIDE=<reason>)
bench-check:
	PYTHONPATH=src python -m benchmarks.bench_check

# regenerate METRICS.md (reference table of every registered metric)
# from the obs registry; commit the result
metrics-doc:
	PYTHONPATH=src python -m repro.obs.metrics_doc METRICS.md

# smoke both serving engines for a few steps on reduced configs
bench-serve:
	PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
		--requests 4 --new-tokens 8 --max-batch 2 --fastcache
	PYTHONPATH=src python -m repro.launch.serve_diffusion --arch dit-b2 \
		--reduced --requests 4 --slots 2 --steps 6 --rate 0.5 --json
	PYTHONPATH=src python -m repro.launch.serve_diffusion --arch dit-b2 \
		--reduced --requests 4 --slots 2 --steps 6 --rate 0.5 --json \
		--token-merge-ratio 0.5 --token-merge-window 8
	PYTHONPATH=src python -m benchmarks.run --only serving,serving_sharded

bench:
	PYTHONPATH=src python -m benchmarks.run
