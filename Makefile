# One-command verify/bench entry points (the tier-1 command of ROADMAP.md).
.PHONY: test test-fast bench-smoke bench

test:
	PYTHONPATH=src python -m pytest -x -q

# skip the slow dry-run subprocess compiles (~4 min)
test-fast:
	PYTHONPATH=src python -m pytest -x -q -m "not slow"

bench-smoke:
	PYTHONPATH=src python -m benchmarks.run --only batched_gate,decode_gate

bench:
	PYTHONPATH=src python -m benchmarks.run
