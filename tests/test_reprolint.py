"""reprolint self-tests.

Every check gets a fixture tree under ``tests/data/reprolint/<case>/src``
carrying a known violation on a line marked ``# LINT: <check>``; the check
must fire exactly at the markers and nowhere else.  The runtime half of
policy-contract is exercised both ways: clean on the real registry, and
catching a deliberately mis-shaped policy registered on the fly.  Finally,
reprolint must be silent on the repository's own src/ tree.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.reprolint import run_checks  # noqa: E402
from tools.reprolint.checks import CHECKS, load_all  # noqa: E402

DATA = REPO / "tests" / "data" / "reprolint"

EXPECTED_CHECKS = {"no-bare-assert", "host-sync-in-jit",
                   "tracer-control-flow", "policy-contract",
                   "donation-discipline", "kernel-parity",
                   "obs-discipline"}


def _marked(case):
    """{(abs path, line, check)} from ``# LINT: <check>`` markers."""
    out = set()
    for p in sorted((DATA / case / "src").rglob("*.py")):
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if "# LINT:" in line:
                out.add((str(p), i, line.split("# LINT:")[1].split()[0]))
    return out


def test_all_builtin_checks_registered():
    load_all()
    assert set(CHECKS) == EXPECTED_CHECKS


@pytest.mark.parametrize("case,check", [
    ("bare_assert", "no-bare-assert"),
    ("host_sync", "host-sync-in-jit"),
    ("tracer_flow", "tracer-control-flow"),
    ("donation", "donation-discipline"),
    ("obs_discipline", "obs-discipline"),
])
def test_check_fires_exactly_at_markers(case, check):
    diags = run_checks(DATA / case / "src", checks=[check],
                       static_only=True)
    got = {(d.file, d.line, d.check) for d in diags}
    assert got == _marked(case), \
        f"diagnostics {got} != markers for {case}"


def test_escape_hatch_suppresses():
    assert run_checks(DATA / "suppressed" / "src", static_only=True) == []


def test_policy_contract_static():
    diags = run_checks(DATA / "policy_contract" / "src",
                       checks=["policy-contract"], static_only=True)
    by_file = {Path(d.file).name: d for d in diags}
    assert set(by_file) == {"twice.py", "orphan.py"}, diags
    twice = by_file["twice.py"]
    assert "exactly one" in twice.message and "found 2" in twice.message
    assert {(Path(f).name, l) for f, l, _ in
            _marked("policy_contract")} == {("twice.py", twice.line)}
    assert "not imported" in by_file["orphan.py"].message


def test_kernel_parity_fixture():
    diags = run_checks(DATA / "kernel_parity" / "src",
                       checks=["kernel-parity"], static_only=True)
    by_file = {}
    for d in diags:
        by_file.setdefault(Path(d.file).name, []).append(d)
    assert set(by_file) == {"myk.py", "other.py", "tmerge.py"}, diags
    assert "no pure-jnp counterpart" in by_file["myk.py"][0].message
    assert "parity" in by_file["other.py"][0].message
    # multi-entry kernel modules (the token-merge shape): each public
    # entry is checked on its own
    tmerge = {d.line: d.message for d in by_file["tmerge.py"]}
    assert {(d.file, d.line, "kernel-parity")
            for d in diags} == _marked("kernel_parity") | {
                (by_file["myk.py"][0].file, by_file["myk.py"][0].line,
                 "kernel-parity"),
                (by_file["other.py"][0].file, by_file["other.py"][0].line,
                 "kernel-parity")}
    assert any("unverified" in m for m in tmerge.values())
    assert any("no pure-jnp counterpart" in m for m in tmerge.values())


def test_kernel_parity_silent_on_real_kernels():
    diags = run_checks(REPO / "src", checks=["kernel-parity"],
                       static_only=True, tests_dir=REPO / "tests")
    assert diags == []


def test_static_checks_silent_on_current_tree():
    assert run_checks(REPO / "src", static_only=True,
                      tests_dir=REPO / "tests") == []


def test_runtime_policy_validation_clean_on_registry():
    from tools.reprolint.checks.policy_contract import validate_registry
    assert validate_registry(str(REPO / "src")) == []


def test_runtime_policy_validation_catches_bad_policy():
    import jax.numpy as jnp
    from repro.core.policies import base as policies_base
    from tools.reprolint.checks.policy_contract import validate_registry

    @policies_base.register("_lintprobe")
    class _Probe(policies_base.CachePolicy):
        def init_state(self, batch):
            return {
                # leading axis 9999 is neither the batch nor an L/L+1
                # layer axis -> the sharding walker cannot place the rows
                "weird": jnp.zeros((9999, batch), jnp.float32),
                "stats": {
                    # (B, 2) is not a per-sample (B,) counter
                    "blocks_computed": jnp.zeros((batch, 2), jnp.float32),
                    "steps": jnp.zeros((), jnp.float32),
                },
            }

        def step(self, params, state, x_in, c):
            return x_in, state

    try:
        diags = [d for d in validate_registry(str(REPO / "src"))
                 if "_lintprobe" in d.message]
        msgs = " | ".join(d.message for d in diags)
        assert any("weird" in d.message and "rank rules" in d.message
                   for d in diags), msgs
        assert any("blocks_computed" in d.message
                   and "(B,)" in d.message for d in diags), msgs
        # the probe's own source location is attributed
        assert all(d.file.endswith("test_reprolint.py") for d in diags)
    finally:
        del policies_base._REGISTRY["_lintprobe"]


def test_runtime_policy_validation_catches_bad_preemption_contract():
    """The snapshot/restore half of the runtime contract: a policy whose
    snapshot drops a key (treedef change) or whose restore silently
    perturbs a row leaf (round trip not the bitwise identity) must be
    flagged — either failure corrupts preempted requests on resume."""
    import jax.numpy as jnp
    from repro.core.policies import base as policies_base
    from tools.reprolint.checks.policy_contract import validate_registry

    @policies_base.register("_lintprobe_snapdrop")
    class _Drop(policies_base.CachePolicy):
        def init_state(self, batch):
            return {"payload": jnp.zeros((batch, 4), jnp.float32),
                    "stats": self.init_stats(batch)}

        def step(self, params, state, x_in, c):
            return x_in, state

        def snapshot_rows(self, state, rows):
            snap = dict(super().snapshot_rows(state, rows))
            del snap["payload"]          # treedef no longer matches
            return snap

    @policies_base.register("_lintprobe_corrupt")
    class _Corrupt(policies_base.CachePolicy):
        def init_state(self, batch):
            return {"payload": jnp.zeros((batch, 4), jnp.float32),
                    "stats": self.init_stats(batch)}

        def step(self, params, state, x_in, c):
            return x_in, state

        def restore_rows(self, state, snap, rows):
            out = dict(super().restore_rows(state, snap, rows))
            out["payload"] = out["payload"] + 1.0    # silent corruption
            return out

    try:
        diags = validate_registry(str(REPO / "src"))
        drop = [d for d in diags if "_lintprobe_snapdrop" in d.message]
        corrupt = [d for d in diags if "_lintprobe_corrupt" in d.message]
        assert any("snapshot_rows changed the state treedef" in d.message
                   for d in drop), " | ".join(d.message for d in drop)
        assert any("bitwise identity" in d.message
                   and "payload" in d.message for d in corrupt), \
            " | ".join(d.message for d in corrupt)
    finally:
        del policies_base._REGISTRY["_lintprobe_snapdrop"]
        del policies_base._REGISTRY["_lintprobe_corrupt"]


def test_cli_exit_codes():
    env = dict(os.environ, PYTHONPATH="src")
    bad = subprocess.run(
        [sys.executable, "-m", "tools.reprolint",
         str(DATA / "bare_assert" / "src"), "--static-only"],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert bad.returncode == 1, bad.stderr
    assert "[no-bare-assert]" in bad.stdout
    clean = subprocess.run(
        [sys.executable, "-m", "tools.reprolint",
         str(DATA / "suppressed" / "src"), "--static-only"],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert clean.returncode == 0, clean.stdout + clean.stderr
