"""The CachePolicy plugin API: registry-derived POLICIES, per-policy state
minimality, bitwise parity against the pre-refactor golden run (the
monolithic CachedDiT captured in tests/golden/policies.npz), tolerant
stats summaries, the SmoothCache-style layer-schedule policy, and the
front-door contract (a policy registered at runtime serves through both
engines with zero engine/sharding edits).

Run via ``make test-policies`` (CI job of the same name)."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core
from benchmarks.common import build_dit
from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import (CachedDiT, POLICIES, get_policy_class,
                        register, registered_policies, summarize_stats)
from repro.core.policies import base as policies_base
from repro.core.policies.fora import FORA
from repro.core.policies.smoothcache import (default_smooth_schedule,
                                             smooth_schedule_from_errors)
from repro.diffusion import sample
from repro.models import build_model
from repro.serving import DiffusionRequest, DiffusionServingEngine
from tests.conftest import assert_solo_replay_parity, f32_cfg
from tests.golden.generate import (SAMPLE_STEPS, SERVE_STEPS, STAT_KEYS,
                                   serving_trace)

pytestmark = pytest.mark.policies

GOLDEN = np.load(pathlib.Path(__file__).parent / "golden" / "policies.npz")


@pytest.fixture(scope="module")
def bench_dit():
    return build_dit("dit-b2")     # un-zeroed weights: policies diverge


@pytest.fixture(scope="module")
def reduced_dit():
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Registry / POLICIES
# ---------------------------------------------------------------------------

def test_policies_tuple_is_derived_from_registry():
    assert POLICIES == registered_policies()
    assert set(POLICIES) >= {"nocache", "fora", "teacache", "adacache",
                             "fbcache", "l2c", "fastcache", "smoothcache"}
    # module __getattr__: repro.core.POLICIES re-derives on access, so a
    # runtime registration shows up without editing any tuple
    @register("_probe")
    class Probe(FORA):
        pass
    try:
        assert "_probe" in repro.core.POLICIES
        assert get_policy_class("_probe") is Probe
    finally:
        del policies_base._REGISTRY["_probe"]
    assert "_probe" not in repro.core.POLICIES


def test_unknown_policy_raises_value_error(reduced_dit):
    """ValueError (not AssertionError — asserts vanish under python -O)
    listing the registered names."""
    cfg, model, params = reduced_dit
    with pytest.raises(ValueError, match="fastcache"):
        CachedDiT(model, FastCacheConfig(), policy="bogus")
    with pytest.raises(ValueError, match="gate_mode"):
        CachedDiT(model, FastCacheConfig(gate_mode="weird"))


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register("fora")(type("Clash", (FORA,), {}))


# ---------------------------------------------------------------------------
# Satellite: per-policy state minimality
# ---------------------------------------------------------------------------

# exactly the buffers each policy owns (plus the standard stats block);
# the monolith allocated the UNION of these for every policy
EXPECTED_STATE = {
    "nocache": set(),
    "fora": {"prev_eps", "step_count", "have_cache"},
    "teacache": {"prev_tokens_in", "prev_eps", "tea_acc", "have_cache"},
    "adacache": {"prev_tokens_in", "prev_eps", "ada_skip_left",
                 "have_cache"},
    "fbcache": {"prev_h1", "prev_eps", "have_cache"},
    "l2c": set(),
    "fastcache": {"prev_tokens_in", "prev_hidden", "gate", "have_cache"},
    "smoothcache": {"prev_delta", "step_count", "have_cache"},
}

STD_STATS = {"blocks_computed", "blocks_skipped", "steps_reused",
             "motion_frac_sum", "steps"}


@pytest.mark.parametrize("policy", POLICIES)
def test_init_state_is_minimal(reduced_dit, policy):
    cfg, model, params = reduced_dit
    runner = CachedDiT(model, FastCacheConfig(), policy=policy)
    state = runner.init_state(3)
    assert "stats" in state
    assert set(state["stats"]) == STD_STATS
    if policy in EXPECTED_STATE:
        assert set(state) - {"stats"} == EXPECTED_STATE[policy], policy
    # per-sample counters are (B,); reset_rows leaves batchmates alone
    assert all(state["stats"][k].shape == (3,)
               for k in STD_STATS - {"steps"})
    runner.reset_slot(state, 1)


def test_no_policy_carries_another_policies_buffers(reduced_dit):
    """The monolith's union allocation is gone: e.g. fora carries no chi^2
    trackers and no hidden stacks, nocache carries nothing at all."""
    cfg, model, params = reduced_dit
    fora = CachedDiT(model, FastCacheConfig(), policy="fora").init_state(2)
    assert "gate" not in fora and "prev_hidden" not in fora
    nc = CachedDiT(model, FastCacheConfig(), policy="nocache").init_state(2)
    assert set(nc) == {"stats"}
    # the big (L+1, B, N, D) payload stack exists ONLY where it is read
    for p in POLICIES:
        st = CachedDiT(model, FastCacheConfig(), policy=p).init_state(2)
        if p not in ("fastcache",):
            assert "prev_hidden" not in st, p


# ---------------------------------------------------------------------------
# Satellite: bitwise parity with the pre-refactor golden run
# ---------------------------------------------------------------------------

GOLDEN_POLICIES = tuple(str(p) for p in GOLDEN["policies"])


@pytest.mark.parametrize("policy", GOLDEN_POLICIES)
def test_golden_sample_parity(bench_dit, policy):
    """Every pre-existing policy reproduces the monolith's sample() run
    bitwise — latents AND per-sample stat counters."""
    cfg, model, params = bench_dit
    img, ch = cfg.dit.image_size, cfg.dit.in_channels
    noise = jax.random.normal(jax.random.PRNGKey(123), (2, img, img, ch),
                              jnp.float32)
    runner = CachedDiT(model, FastCacheConfig(), policy=policy)
    x, state = sample(runner, params, jax.random.PRNGKey(0), batch=2,
                      labels=jnp.array([1, 2]), num_steps=SAMPLE_STEPS,
                      guidance_scale=4.0, x_init=noise)
    np.testing.assert_array_equal(np.asarray(x),
                                  GOLDEN[f"{policy}/sample/latents"])
    for k in STAT_KEYS:
        np.testing.assert_array_equal(np.asarray(state["stats"][k]),
                                      GOLDEN[f"{policy}/sample/{k}"],
                                      err_msg=f"{policy}/{k}")


@pytest.mark.parametrize("policy", GOLDEN_POLICIES)
def test_golden_serving_parity(bench_dit, policy):
    """The serving engine reproduces the monolith's mixed-plan staggered
    trace bitwise through the plugin path — per-request latents and the
    headline cache counters."""
    cfg, model, params = bench_dit
    runner = CachedDiT(model, FastCacheConfig(), policy=policy)
    eng = DiffusionServingEngine(runner, params, max_slots=2,
                                 num_steps=SERVE_STEPS, max_steps=7)
    done = eng.run(serving_trace())
    assert len(done) == 3
    for r in done:
        np.testing.assert_array_equal(
            np.asarray(r.latents), GOLDEN[f"{policy}/serve/latents_rid{r.rid}"],
            err_msg=f"{policy} rid={r.rid}")
    cs = eng.cache_stats()
    np.testing.assert_array_equal(
        np.array([cs["blocks_skipped"], cs["blocks_computed"],
                  cs["steps_reused"]], np.float64),
        GOLDEN[f"{policy}/serve/headline"], err_msg=policy)


# ---------------------------------------------------------------------------
# Satellite: summarize_stats tolerates any policy's state pytree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_summarize_stats_over_every_registered_policy(reduced_dit, policy):
    cfg, model, params = reduced_dit
    runner = CachedDiT(model, FastCacheConfig(), policy=policy)
    s = summarize_stats(runner.init_state(2))
    assert s["steps"] == 0.0 and s["block_cache_ratio"] == 0.0
    assert runner.stats(runner.init_state(2)) == s


def test_summarize_stats_missing_keys_return_zero():
    """A future policy that tracks only SOME counters (or none) must not
    KeyError the summary."""
    s = summarize_stats({"stats": {}})
    assert s["blocks_computed"] == 0.0 and s["block_cache_ratio"] == 0.0
    assert "per_sample" not in s
    s = summarize_stats({"stats": {
        "blocks_skipped": jnp.array([3.0, 1.0]),
        "steps": jnp.asarray(2.0)}})
    assert s["blocks_skipped"] == 2.0          # batch mean
    assert s["blocks_computed"] == 0.0         # absent -> 0.0, no KeyError
    assert s["block_cache_ratio"] == 1.0
    assert s["per_sample"] == {"blocks_skipped": [3.0, 1.0]}
    assert summarize_stats({})["steps"] == 0.0  # no stats block at all


# ---------------------------------------------------------------------------
# smoothcache: the SmoothCache-style layer-schedule policy
# ---------------------------------------------------------------------------

def test_smoothcache_schedule_helpers():
    sched = default_smooth_schedule(3, interval=2, table_steps=8)
    assert sched.shape == (3, 8)
    assert not sched[:, 0].any() and sched[:, 1].all()
    err = jnp.array([[0.0, 0.01, 0.5], [0.0, 0.2, 0.01]])
    cal = smooth_schedule_from_errors(err, threshold=0.05)
    assert not cal[:, 0].any()                 # step 0 always computes
    assert bool(cal[0, 1]) and not bool(cal[1, 1])


def test_smoothcache_follows_its_schedule(reduced_dit):
    """With the default every-other-step schedule, half the steps after
    warm-up reuse every layer's cached residual."""
    cfg, model, params = reduced_dit
    runner = CachedDiT(model, FastCacheConfig(), policy="smoothcache")
    img, ch = cfg.dit.image_size, cfg.dit.in_channels
    x = jax.random.normal(jax.random.PRNGKey(1), (2, img, img, ch))
    state = runner.init_state(2)
    step = jax.jit(runner.step)
    for t in range(6):
        eps, state = step(params, state, x, jnp.full((2,), 25),
                          jnp.array([1, 2]))
    s = summarize_stats(state)
    # steps 1,3,5 reuse (schedule), 0,2,4 compute: ratio == 0.5
    assert s["block_cache_ratio"] == 0.5, s
    with pytest.raises(ValueError, match="layer rows"):
        CachedDiT(model, FastCacheConfig(), policy="smoothcache",
                  smooth_schedule=jnp.zeros((7, 4), bool))


def test_smoothcache_custom_schedule_via_front_door(reduced_dit):
    """The schedule kwarg reaches the policy through CachedDiT's generic
    **policy_kwargs passthrough — no shell edit was needed for it."""
    cfg, model, params = reduced_dit
    sched = default_smooth_schedule(cfg.num_layers, interval=3)
    runner = CachedDiT(model, FastCacheConfig(), policy="smoothcache",
                       smooth_schedule=sched)
    img, ch = cfg.dit.image_size, cfg.dit.in_channels
    x = jax.random.normal(jax.random.PRNGKey(1), (1, img, img, ch))
    state = runner.init_state(1)
    step = jax.jit(runner.step)
    for t in range(6):
        eps, state = step(params, state, x, jnp.full((1,), 25),
                          jnp.array([1]))
    # interval 3: steps 1,2,4,5 reuse; 0,3 compute
    assert summarize_stats(state)["block_cache_ratio"] == pytest.approx(4 / 6)


# ---------------------------------------------------------------------------
# Front door: a policy registered at runtime serves with zero engine edits
# ---------------------------------------------------------------------------

def test_runtime_registered_policy_serves_front_door(reduced_dit):
    """Acceptance: adding a cache method is ONE registration — the shell,
    the serving engine, slot reset, per-request counters and the solo
    bitwise-replay contract all pick it up with no serving/ or
    distributed/ edits (the sharded engine shares this path via the opaque
    state walker, exercised per-policy in test_sharded_serving.py)."""
    cfg, model, params = reduced_dit

    @register("_everyother")
    class EveryOther(FORA):
        """FORA at interval 2, under a fresh name and registered live."""
        def __init__(self, model, fc, fc_params, **kw):
            kw.pop("fora_interval", None)
            super().__init__(model, fc, fc_params, fora_interval=2, **kw)

    try:
        runner = CachedDiT(model, FastCacheConfig(), policy="_everyother")
        eng = DiffusionServingEngine(runner, params, max_slots=2,
                                     num_steps=3)
        trace = [DiffusionRequest(rid=0, label=1, seed=1, arrival_step=0),
                 DiffusionRequest(rid=1, label=2, seed=2, arrival_step=1)]
        done = eng.run(trace)
        assert len(done) == 2
        assert_solo_replay_parity(eng, model, params, "_everyother", done)
        # interval 2 over 3 steps reuses step 1, on both CFG rows
        assert all(r.cache["steps_reused"] == 2.0 for r in done)
    finally:
        del policies_base._REGISTRY["_everyother"]
