"""Token-compression stage invariants: merge/unmerge round trips, static
capacity semantics (overflow degrades speed, never shape), and the
composability contract — every registered cache policy runs unchanged on
the reduced grid with full-resolution outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT, POLICIES
from repro.core import token_merge
from repro.core.token_reduce import TokenReducer
from repro.diffusion import sample
from repro.models import build_model
from tests.conftest import f32_cfg


@pytest.fixture(scope="module")
def dit():
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # un-zero the adaLN-zero modulation and output head (as a trained
    # model's would be) so eps depends on the hidden states the merge
    # stage transforms — otherwise eps == 0 and every check is vacuous
    k = jax.random.PRNGKey(7)
    params["blocks"]["ada_w"] = 0.05 * jax.random.normal(
        k, params["blocks"]["ada_w"].shape)
    params["blocks"]["ada_b"] = 0.2 * jax.random.normal(
        jax.random.fold_in(k, 1), params["blocks"]["ada_b"].shape)
    params["final_w"] = (jax.random.normal(jax.random.fold_in(k, 2),
                                           params["final_w"].shape)
                         / cfg.d_model ** 0.5)
    return cfg, model, params


def _fc(ratio, window=8, **kw):
    return FastCacheConfig(merge_enabled=True, merge_ratio=ratio,
                           merge_window=window, **kw)


# ---------------------------------------------------------------------------
# merge/unmerge round-trip invariants (core/token_merge.py)
# ---------------------------------------------------------------------------

def test_ratio_one_merge_is_bitwise_identity(key):
    """keep_ratio=1.0 short-circuits: the 'merged' tensor IS the input
    (bitwise, not allclose) and unmerge restores it bitwise."""
    h = jax.random.normal(key, (2, 32, 16))
    merged, mm = token_merge.merge_tokens(h, h, window=8, keep_ratio=1.0,
                                          k=3, lam=1.0)
    np.testing.assert_array_equal(np.asarray(merged), np.asarray(h))
    out = token_merge.unmerge_tokens(merged, mm, window=8, n_tokens=32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(h))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("ratio", [0.25, 0.5, 0.75])
def test_unmerge_restores_shape_and_dtype(dtype, ratio, key):
    b, n, d, w = 2, 32, 16, 8
    h = jax.random.normal(key, (b, n, d)).astype(dtype)
    hp = jax.random.normal(jax.random.fold_in(key, 1), (b, n, d)
                           ).astype(dtype)
    merged, mm = token_merge.merge_tokens(h, hp, window=w, keep_ratio=ratio,
                                          k=3, lam=1.0)
    m = token_merge.keep_count(w, ratio)
    assert merged.shape == (b, n // w * m, d) and merged.dtype == h.dtype
    out = token_merge.unmerge_tokens(merged, mm, window=w, n_tokens=n)
    assert out.shape == h.shape and out.dtype == h.dtype
    # every restored token is one of its window's cluster centers
    mg = np.asarray(merged, np.float32).reshape(b, n // w, m, d)
    got = np.asarray(out, np.float32).reshape(b, n // w, w, d)
    for bi in range(b):
        for wi in range(n // w):
            for ti in range(w):
                assert any(np.array_equal(got[bi, wi, ti], mg[bi, wi, ci])
                           for ci in range(m))


def test_merge_rejects_indivisible_window(key):
    h = jax.random.normal(key, (1, 30, 8))
    with pytest.raises(ValueError, match="divisible"):
        token_merge.merge_tokens(h, h, window=8, keep_ratio=0.5, k=3,
                                 lam=1.0)


# ---------------------------------------------------------------------------
# TokenReducer statics (core/token_reduce.py)
# ---------------------------------------------------------------------------

def test_capacity_overflow_deactivates_never_reshapes(dit):
    """A ratio whose ceil(r*w) fills the window cannot shrink the grid:
    the reducer goes statically inert (runner drops it) instead of
    emitting a different shape — overflow degrades speed, never shape."""
    cfg, model, params = dit
    red = TokenReducer(model, _fc(0.99, window=8))
    assert not red.active
    assert red.reduced_tokens == model.num_tokens
    runner = CachedDiT(model, _fc(0.99, window=8))
    assert runner.reducer is None
    assert runner.impl.n_tokens == model.num_tokens


def test_reducer_statics_and_state_rows(dit):
    cfg, model, params = dit
    red = TokenReducer(model, _fc(0.5, window=8))
    assert red.active and red.m == 4
    assert red.reduced_tokens == model.num_tokens // 2
    rows = red.init_rows(3)
    assert rows["prev_full"].shape == (3, model.num_tokens, cfg.d_model)
    assert not bool(rows["have_prev"].any())
    _, warm = red.reduce(jnp.ones((3, model.num_tokens, cfg.d_model)), rows)
    assert bool(warm["have_prev"].all())
    cold = red.reset_rows(warm, jnp.array([1]))
    assert [bool(v) for v in cold["have_prev"]] == [True, False, True]


def test_reducer_rejects_bad_window_and_k(dit):
    cfg, model, params = dit
    with pytest.raises(ValueError, match="divisible"):
        TokenReducer(model, _fc(0.5, window=5))
    with pytest.raises(ValueError, match="out of range"):
        TokenReducer(model, _fc(0.5, window=8, knn_k=8))


# ---------------------------------------------------------------------------
# CachedDiT composition: every policy, reduced grid, full-res outputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_every_policy_composes_with_merge(dit, policy):
    cfg, model, params = dit
    runner = CachedDiT(model, _fc(0.5, window=8), policy=policy)
    assert runner.reducer is not None
    assert runner.impl.n_tokens == model.num_tokens // 2
    x, state = sample(runner, params, jax.random.PRNGKey(1), batch=2,
                      num_steps=3, jit_step=False)
    assert x.shape == (2, cfg.dit.image_size, cfg.dit.image_size,
                      cfg.dit.in_channels)
    stats = state["stats"]
    steps = 3 * 2 * 2          # 3 steps x (cond+uncond rows) accumulated
    assert float(jnp.sum(stats["tokens_kept"])) == \
        runner.reducer.reduced_tokens * steps
    assert float(jnp.sum(stats["tokens_merged"])) == \
        (model.num_tokens - runner.reducer.reduced_tokens) * steps
    # the per-trace MergeMap stash never leaks across steps
    assert runner.reducer._mm is None


def test_ratio_one_runner_is_bitwise_merge_off(dit):
    cfg, model, params = dit
    on = CachedDiT(model, _fc(1.0), policy="fastcache")
    off = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    assert on.reducer is None
    x1, _ = sample(on, params, jax.random.PRNGKey(2), batch=2, num_steps=3)
    x0, _ = sample(off, params, jax.random.PRNGKey(2), batch=2, num_steps=3)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x0))


def test_merge_actually_changes_output(dit):
    """r < 1 must change the sampled latents (the stage is live, not
    silently bypassed) on a model whose eps depends on the hiddens."""
    cfg, model, params = dit
    on = CachedDiT(model, _fc(0.5, window=8), policy="nocache")
    off = CachedDiT(model, FastCacheConfig(), policy="nocache")
    x1, _ = sample(on, params, jax.random.PRNGKey(2), batch=2, num_steps=3)
    x0, _ = sample(off, params, jax.random.PRNGKey(2), batch=2, num_steps=3)
    assert float(jnp.max(jnp.abs(x1 - x0))) > 0.0


def test_audit_hidden_none_with_merge_on(dit):
    """With merge on the cached stack lives on the reduced grid — the
    audit plane must fall back to end-to-end eps error (audit_hidden is
    None) instead of comparing mismatched-resolution stacks."""
    cfg, model, params = dit
    runner = CachedDiT(model, _fc(0.5, window=8), policy="fastcache")
    state = runner.init_state(2)
    assert runner.audit_hidden(state) is None
    off = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    assert off.audit_hidden(off.init_state(2)) is not None
