"""The observability subsystem: metric registry discipline, pure-jnp
device-plane updates, MetricsCollector harvest/export round-trips
(Prometheus text + JSONL windows), Chrome/Perfetto trace recording, the
calibration recorder's .npz contract, and the end-to-end engine wiring.

Run via ``make test-obs`` (CI job of the same name)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT
from repro.core.policies.smoothcache import smooth_schedule_from_errors
from repro.models import build_model
from repro.obs import (METRICS, MetricsCollector, TraceRecorder, counter,
                       histogram, init_device_metrics, load_calibration,
                       parse_prometheus, record_calibration,
                       save_calibration, validate_trace)
from repro.obs import metrics as obs_metrics
from repro.serving import DiffusionRequest, DiffusionServingEngine
from tests.conftest import f32_cfg

pytestmark = pytest.mark.obs


@pytest.fixture(scope="module")
def dit():
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_duplicate_registration_with_different_spec_raises():
    name = counter("_obs_test_probe_total", "probe")
    try:
        # identical re-registration is idempotent (module reloads)
        assert counter("_obs_test_probe_total", "probe") == name
        with pytest.raises(ValueError, match="already registered"):
            counter("_obs_test_probe_total", "different help")
        with pytest.raises(ValueError, match="already registered"):
            histogram("_obs_test_probe_total", "now a histogram")
    finally:
        del METRICS[name]


def test_invalid_metric_names_and_buckets_raise():
    with pytest.raises(ValueError, match="not a valid"):
        counter("bad-name")
    with pytest.raises(ValueError, match="ascending"):
        histogram("_obs_test_bad_buckets", buckets=(2, 1))
    with pytest.raises(ValueError, match="ascending"):
        histogram("_obs_test_dup_buckets", buckets=(1, 1, 2))
    assert "_obs_test_bad_buckets" not in METRICS


def test_serving_metric_set_is_registered():
    for n in (obs_metrics.DEVICE_COUNTERS + obs_metrics.DEVICE_HISTOGRAMS
              + obs_metrics.DEVICE_PER_SLOT):
        assert n in METRICS


# ---------------------------------------------------------------------------
# Device plane
# ---------------------------------------------------------------------------

def test_device_updates_are_pure_and_jit_consistent():
    m = init_device_metrics(4)
    m2 = obs_metrics.inc(m, obs_metrics.SERVE_STEPS, 2.0)
    assert float(m["counters"][obs_metrics.SERVE_STEPS]) == 0.0
    assert float(m2["counters"][obs_metrics.SERVE_STEPS]) == 2.0

    def update(mm):
        mm = obs_metrics.inc(mm, obs_metrics.SERVE_STEPS, 1.0)
        mm = obs_metrics.observe(mm, obs_metrics.ACTIVE_SLOTS, 3.0)
        return obs_metrics.slot_add(mm, obs_metrics.SLOT_ACTIVE_STEPS,
                                    jnp.ones((4,), jnp.float32))

    eager, jitted = update(m), jax.jit(update)(m)
    for a, b in zip(jax.tree.leaves(eager), jax.tree.leaves(jitted)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    h = eager["hist"][obs_metrics.ACTIVE_SLOTS]
    # active_slots buckets (0, 1, 2, 4, ...): 3.0 lands in the le=4 bin
    assert float(h["bucket"][3]) == 1.0 and float(h["count"]) == 1.0
    assert float(h["sum"]) == 3.0


def test_histogram_overflow_bin():
    m = init_device_metrics(1)
    m = obs_metrics.observe(m, obs_metrics.ACTIVE_SLOTS, 1e9)
    h = m["hist"][obs_metrics.ACTIVE_SLOTS]
    assert float(h["bucket"][-1]) == 1.0  # +Inf overflow bin


# ---------------------------------------------------------------------------
# Host plane: collector, harvest, exports
# ---------------------------------------------------------------------------

def test_collector_kind_mismatch_and_window_validation():
    c = MetricsCollector()
    with pytest.raises(ValueError, match="not a counter"):
        c.inc(obs_metrics.REQUEST_LATENCY)
    with pytest.raises(ValueError, match="not a histogram"):
        c.observe(obs_metrics.ADMISSIONS, 1.0)
    with pytest.raises(ValueError, match="unknown metric"):
        c.inc("never_registered_total")
    with pytest.raises(ValueError, match="window_steps"):
        MetricsCollector(window_steps=0)


def test_harvest_merges_host_and_device_planes():
    c = MetricsCollector(labels={"policy": "fastcache"})
    c.inc(obs_metrics.ADMISSIONS, 3)
    c.observe(obs_metrics.REQUEST_LATENCY, 10.0)
    m = init_device_metrics(2)
    m = obs_metrics.inc(m, obs_metrics.SERVE_STEPS, 5.0)
    w = c.harvest(m, at_step=7)
    assert w["at_step"] == 7 and w["labels"] == {"policy": "fastcache"}
    totals = c.totals()
    assert totals[obs_metrics.ADMISSIONS] == 3.0
    assert totals[obs_metrics.SERVE_STEPS] == 5.0
    # harvest is cumulative, not a delta: a second harvest of the same
    # device tree reports the same totals
    c.harvest(m, at_step=8)
    assert c.totals()[obs_metrics.SERVE_STEPS] == 5.0
    assert len(c.windows) == 2


def test_prometheus_round_trip():
    c = MetricsCollector(labels={"policy": "fora", "dit": "dit-b2"})
    c.inc(obs_metrics.ADMISSIONS, 2)
    for v in (3.0, 9.0, 1000.0):
        c.observe(obs_metrics.REQUEST_LATENCY, v)
    c.set_gauge("run_wall_seconds", 1.25)
    text = c.to_prometheus()
    parsed = parse_prometheus(text)
    adm = parsed["repro_" + obs_metrics.ADMISSIONS]
    assert adm["type"] == "counter"
    assert adm["samples"][0] == ({"dit": "dit-b2", "policy": "fora"}, 2.0)
    lat = parsed["repro_" + obs_metrics.REQUEST_LATENCY]
    assert lat["type"] == "histogram"
    by_le = {s[0]["le"]: s[1] for s in lat["samples"] if "le" in s[0]}
    # cumulative le-buckets must be monotone and end at count == 3
    cum = [by_le[k] for k in sorted(by_le, key=float)]
    assert cum == sorted(cum) and by_le["+Inf"] == 3.0
    assert parsed["repro_run_wall_seconds"]["type"] == "gauge"


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError, match="malformed"):
        parse_prometheus("this is { not exposition\n")


def test_prometheus_round_trip_escaped_label_values():
    """Label values carrying the three characters the text format escapes
    (backslash, double quote, newline) must survive export -> parse."""
    nasty = 'a\\b"c\nd'
    c = MetricsCollector(labels={"policy": nasty, "plain": "ok"})
    c.inc(obs_metrics.ADMISSIONS, 1)
    text = c.to_prometheus()
    # the raw exposition must stay line-oriented: no literal newline may
    # leak out of the quoted label value
    sample_lines = [ln for ln in text.splitlines()
                    if ln and not ln.startswith("#")]
    assert all("admissions" in ln or "policy" not in ln
               for ln in sample_lines)
    parsed = parse_prometheus(text)
    labels, value = parsed["repro_" + obs_metrics.ADMISSIONS]["samples"][0]
    assert labels == {"policy": nasty, "plain": "ok"}
    assert value == 1.0
    # a quote inside a label value must not terminate label scanning early
    assert parse_prometheus(
        'm{a="x\\"y",b="z"} 2\n')["m"]["samples"][0] \
        == ({"a": 'x"y', "b": "z"}, 2.0)
    with pytest.raises(ValueError, match="unterminated|malformed"):
        parse_prometheus('m{a="never closed\n')


def test_prometheus_round_trip_inf_buckets():
    """The implicit +Inf overflow bucket and observations beyond the last
    finite bound round-trip as +Inf, not a float-repr like 'inf'."""
    c = MetricsCollector()
    c.observe(obs_metrics.REQUEST_LATENCY, 1e12)  # overflow bin
    text = c.to_prometheus()
    assert 'le="+Inf"' in text
    parsed = parse_prometheus(text)
    lat = parsed["repro_" + obs_metrics.REQUEST_LATENCY]
    by_le = {s[0]["le"]: s[1] for s in lat["samples"] if "le" in s[0]}
    assert by_le["+Inf"] == 1.0
    assert all(v == 0.0 for le, v in by_le.items() if le != "+Inf")
    # explicit ±Inf sample VALUES parse too (gauges may legitimately hit)
    parsed = parse_prometheus("g 1\nh +Inf\ni -Inf\n")
    assert parsed["h"]["samples"][0][1] == float("inf")
    assert parsed["i"]["samples"][0][1] == float("-inf")


def test_prometheus_round_trip_nan_gauge():
    """A NaN gauge (e.g. a 0/0 ratio window) must export as the canonical
    'NaN' token and parse back to a float NaN rather than erroring."""
    c = MetricsCollector()
    c.set_gauge("empty_window_ratio", float("nan"))
    text = c.to_prometheus()
    assert "NaN" in text
    parsed = parse_prometheus(text)
    val = parsed["repro_empty_window_ratio"]["samples"][0][1]
    assert val != val  # NaN is the only float unequal to itself
    # arbitrary-case NaN tokens are rejected — only canonical spellings
    with pytest.raises(ValueError, match="malformed"):
        parse_prometheus("g not_a_number\n")


def test_jsonl_windows():
    c = MetricsCollector()
    c.inc(obs_metrics.ADMISSIONS)
    c.harvest(at_step=4)
    c.inc(obs_metrics.ADMISSIONS)
    c.harvest(at_step=8)
    lines = c.to_jsonl().strip().splitlines()
    assert len(lines) == 2
    w0, w1 = (json.loads(ln) for ln in lines)
    assert w0["at_step"] == 4 and w1["at_step"] == 8
    assert w1["counters"][obs_metrics.ADMISSIONS] == 2.0


# ---------------------------------------------------------------------------
# Trace recorder
# ---------------------------------------------------------------------------

def test_trace_recorder_round_trip(tmp_path):
    rec = TraceRecorder()
    rec.admit(0, 0, label=3, num_steps=4, engine_step=0)
    acc0 = {"steps_reused": jnp.zeros((2,), jnp.float32)}
    acc1 = {"steps_reused": jnp.array([1.0, 0.0], jnp.float32)}
    active = np.array([True, False])
    with rec.step_begin(1, active=1):
        pass
    rec.snapshot_slots(1, active, acc0)
    with rec.step_begin(2, active=1):
        pass
    rec.snapshot_slots(2, active, acc1)
    rec.finish(0, engine_step=2, stats={"steps_reused": 1.0})
    doc = rec.to_json()
    validate_trace(doc)
    names = [e["name"] for e in doc["traceEvents"]]
    assert "admit" in names and "finish" in names
    assert "request rid=0" in names and "serve_step" in names
    # slot 0's accumulator moved between snapshots -> a cache-reuse slice
    assert "denoise (cache reuse)" in names
    assert doc["displayTimeUnit"] == "ms"
    p = tmp_path / "trace.json"
    rec.write(str(p))
    validate_trace(json.loads(p.read_text()))


def test_validate_trace_rejects_bad_docs():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_trace({"events": []})
    with pytest.raises(ValueError, match="missing ts/dur"):
        validate_trace({"traceEvents": [
            {"name": "x", "ph": "X", "pid": 0}]})
    with pytest.raises(ValueError, match="unknown phase"):
        validate_trace({"traceEvents": [
            {"name": "x", "ph": "Z", "pid": 0}]})
    with pytest.raises(ValueError, match="missing ts"):
        validate_trace({"traceEvents": [
            {"name": "x", "ph": "C", "pid": 0, "args": {"v": 1.0}}]})
    with pytest.raises(ValueError, match="no series args"):
        validate_trace({"traceEvents": [
            {"name": "x", "ph": "C", "pid": 0, "ts": 1.0}]})


def test_trace_counter_tracks():
    """Perfetto counter tracks (ph="C") from the cumulative snapshots:
    the running cache ratio always, the running mean audit error when the
    audit plane's accumulators ride the slot stats."""
    rec = TraceRecorder()
    active = np.array([True, True])
    snaps = [
        {"blocks_computed": jnp.array([4.0, 4.0]),
         "blocks_skipped": jnp.array([0.0, 0.0]),
         "audit_err_sum": jnp.array([0.0, 0.0]),
         "audit_steps": jnp.array([0.0, 0.0])},
        {"blocks_computed": jnp.array([6.0, 6.0]),
         "blocks_skipped": jnp.array([2.0, 2.0]),
         "audit_err_sum": jnp.array([0.3, 0.1]),
         "audit_steps": jnp.array([2.0, 2.0])},
    ]
    for step, st in enumerate(snaps):
        rec.snapshot_slots(step, active, st)
    doc = rec.to_json()
    validate_trace(doc)
    counters = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    ratios = [e["args"]["cache_ratio"] for e in counters
              if e["name"] == "cache ratio (running)"]
    errs = [e["args"]["audit_err_mean"] for e in counters
            if e["name"] == "audit error (running mean)"]
    assert ratios == [0.0, 4.0 / 16.0]
    assert errs[0] == 0.0 and np.isclose(errs[1], 0.4 / 4.0)
    # without audit accumulators only the cache-ratio track is emitted
    rec2 = TraceRecorder()
    rec2.snapshot_slots(0, active,
                       {"blocks_computed": jnp.array([4.0, 4.0])})
    names = [e["name"] for e in rec2.to_json()["traceEvents"]
             if e["ph"] == "C"]
    assert names == ["cache ratio (running)"]


# ---------------------------------------------------------------------------
# Calibration recorder
# ---------------------------------------------------------------------------

def test_calibration_round_trip_feeds_smoothcache(dit, tmp_path):
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="nocache")
    res = record_calibration(runner, params, batch=2, num_steps=4,
                             guidance_scale=4.0, seed=0)
    L = runner.L
    assert res["rel_delta"].shape == (4, L, 4)   # CFG doubles the batch
    assert res["errors_mean"].shape == (L, 4)
    np.testing.assert_array_equal(res["rel_delta"][0], 1.0)
    assert np.all(res["rel_delta"][1:] > 0.0)
    path = str(tmp_path / "calib.npz")
    save_calibration(path, res)
    loaded = load_calibration(path)
    np.testing.assert_array_equal(loaded["errors_mean"],
                                  res["errors_mean"])
    sched = smooth_schedule_from_errors(loaded["errors_mean"],
                                        threshold=0.5)
    assert sched.shape == (L, 4)
    assert not bool(sched[:, 0].any())  # column 0 always computes


def test_calibration_refuses_caching_policy(dit):
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    with pytest.raises(ValueError, match="uncached"):
        record_calibration(runner, params, batch=1, num_steps=2)


def test_load_calibration_rejects_foreign_npz(tmp_path):
    p = str(tmp_path / "other.npz")
    np.savez(p, foo=np.zeros(3))
    with pytest.raises(ValueError, match="calibration artifact"):
        load_calibration(p)


# ---------------------------------------------------------------------------
# End-to-end engine wiring
# ---------------------------------------------------------------------------

def test_engine_metrics_and_trace_end_to_end(dit):
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    collector = MetricsCollector(labels={"policy": "fastcache"},
                                 window_steps=4)
    tracer = TraceRecorder()
    eng = DiffusionServingEngine(runner, params, max_slots=2, num_steps=8,
                                 guidance_scale=4.0, collector=collector,
                                 tracer=tracer)
    reqs = [DiffusionRequest(rid=i, label=i + 1, seed=10 + i,
                             arrival_step=i) for i in range(3)]
    done = eng.run(reqs)
    assert sorted(r.rid for r in done) == [0, 1, 2]

    totals = collector.totals()
    assert totals[obs_metrics.ADMISSIONS] == 3.0
    assert totals[obs_metrics.REQUESTS_FINISHED] == 3.0
    assert totals[obs_metrics.SERVE_STEPS] == eng.model_steps
    # every request holds a slot for exactly its 8-step plan
    assert totals[obs_metrics.ACTIVE_SLOT_STEPS] == 24.0
    per_slot = collector.windows[-1]["per_slot"]
    assert sum(per_slot[obs_metrics.SLOT_ACTIVE_STEPS]) == 24.0
    # periodic windows (every 4 steps) plus the run-end harvest
    assert len(collector.windows) >= 2
    parse_prometheus(collector.to_prometheus())

    doc = tracer.to_json()
    validate_trace(doc)
    names = [e["name"] for e in doc["traceEvents"]]
    assert names.count("admit") == 3 and names.count("finish") == 3
    assert any(n.startswith("request rid=") for n in names)


def test_engine_metrics_disabled_is_supported(dit):
    """enable_metrics=False traces a metrics-free step (the A/B baseline
    for the telemetry-overhead row in BENCH_serving.json)."""
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    eng = DiffusionServingEngine(runner, params, max_slots=2, num_steps=4,
                                 guidance_scale=4.0, enable_metrics=False)
    assert eng.metrics == {}
    done = eng.run([DiffusionRequest(rid=0, label=1, seed=3,
                                     arrival_step=0)])
    assert len(done) == 1 and eng.harvest_metrics() is None
