import dataclasses

import jax
import pytest

# Tests run on the single CPU device; the dry-run subprocess sets its own
# XLA_FLAGS (do NOT force a device count here — see the brief).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def f32_cfg(cfg, *, big_capacity: bool = True):
    """Reduced configs in f32 with ample MoE capacity (drop-free) so
    numerical-consistency tests are exact."""
    cfg = cfg.replace(dtype="float32")
    if cfg.moe is not None and big_capacity:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    return cfg
