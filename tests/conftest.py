import contextlib
import dataclasses

import jax
import pytest

# Tests run on the single CPU device; the dry-run subprocess sets its own
# XLA_FLAGS (do NOT force a device count here — see the brief).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def f32_cfg(cfg, *, big_capacity: bool = True):
    """Reduced configs in f32 with ample MoE capacity (drop-free) so
    numerical-consistency tests are exact."""
    cfg = cfg.replace(dtype="float32")
    if cfg.moe is not None and big_capacity:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    return cfg


@contextlib.contextmanager
def steady_state_guard(*jitted_fns, transfers="disallow"):
    """Steady-state serving invariant: the guarded region must trigger zero
    new jit compilations on the given jitted callables and (by default) no
    device->host transfers.  The transfer guard bites on accelerator
    backends (CPU jax implements it as a no-op since host and device memory
    coincide), so the compilation-cache assertion is the portably enforced
    half.  Pass ``transfers="allow"`` for engines whose step loop
    legitimately fetches (e.g. per-token AR sampling)."""
    before = [f._cache_size() for f in jitted_fns]
    with jax.transfer_guard_device_to_host(transfers):
        yield
    after = [f._cache_size() for f in jitted_fns]
    assert after == before, (
        "steady-state region triggered a recompile: jit cache sizes "
        f"{before} -> {after}")


def assert_solo_replay_parity(eng, model, params, policy, done, fc=None):
    """Serving contract shared by the single-device and sharded suites:
    every finished request must match a solo ``sample()`` replay under ITS
    OWN resolved (num_steps, guidance_scale) bitwise.  ``params`` must be
    the UNPLACED tree (sharded engines hold device_put copies whose
    committed shardings would leak into the solo jit).  ``fc`` overrides
    the solo runner's FastCacheConfig — pass the engine runner's config so
    a token-merge-enabled engine is replayed with the merge stage on."""
    import numpy as np
    import jax.numpy as jnp
    from repro.configs.base import FastCacheConfig
    from repro.core import CachedDiT
    from repro.diffusion import sample
    for r in done:
        solo = CachedDiT(model, fc or FastCacheConfig(), policy=policy)
        x, _ = sample(solo, params, jax.random.PRNGKey(0), batch=1,
                      labels=jnp.array([r.label]), num_steps=r.num_steps,
                      guidance_scale=r.guidance_scale,
                      x_init=np.asarray(eng.request_noise(r))[None])
        np.testing.assert_array_equal(
            np.asarray(x[0]), np.asarray(r.latents),
            err_msg=f"policy={policy} rid={r.rid} plan=({r.num_steps}, "
                    f"{r.guidance_scale}) admit_step={r.admit_step}")
