"""End-to-end behaviour: the full FastCache-accelerated diffusion pipeline,
training convergence, serving, checkpoints, and the dry-run subprocess."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT, summarize_stats
from repro.diffusion import sample
from repro.models import build_model
from tests.conftest import f32_cfg

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_fastcache_sampling_end_to_end(key):
    """Full DDIM sampling with CFG under FastCache: correct shapes, no NaNs,
    real cache usage, and bounded deviation from the exact sampler."""
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    params = model.init(key)

    r_exact = CachedDiT(model, FastCacheConfig(), policy="nocache")
    x_exact, st_exact = sample(r_exact, params, key, batch=2, num_steps=10,
                               guidance_scale=4.0)
    r_fc = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    x_fc, st_fc = sample(r_fc, params, key, batch=2, num_steps=10,
                         guidance_scale=4.0)
    assert x_fc.shape == x_exact.shape
    assert not bool(jnp.isnan(x_fc).any())
    s = summarize_stats(st_fc)
    assert s["steps"] == 10.0
    rel = float(jnp.linalg.norm(x_fc - x_exact)
                / (jnp.linalg.norm(x_exact) + 1e-9))
    assert rel < 1.0, (rel, s)


def test_training_learns_synthetic_structure(key):
    """A tiny LM must beat its initial loss clearly on the Markov stream."""
    from repro.data import token_stream
    from repro.training import AdamW, cosine_schedule, train
    cfg = f32_cfg(get_reduced("qwen3-0.6b"))
    model = build_model(cfg)
    params = model.init(key)
    it = token_stream(cfg.vocab_size, 8, 64, seed=3)
    _, _, hist = train(model, params, AdamW(weight_decay=0.0),
                       cosine_schedule(1e-3, 5, 60), it, steps=60,
                       log_every=59)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5, hist


def test_dit_training_reduces_mse(key):
    from repro.data import latent_stream
    from repro.training import AdamW, cosine_schedule, train
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    params = model.init(key)
    it = latent_stream(4, cfg.dit.image_size, cfg.dit.in_channels,
                       num_classes=cfg.dit.num_classes, seed=1)
    _, _, hist = train(model, params, AdamW(weight_decay=0.0),
                       cosine_schedule(1e-3, 5, 40), it, steps=40,
                       log_every=39)
    assert hist[-1]["loss"] < hist[0]["loss"], hist


def test_serving_engine_slot_reuse(key):
    from repro.serving import Request, ServingEngine
    cfg = f32_cfg(get_reduced("qwen3-0.6b"))
    model = build_model(cfg)
    params = model.init(key)
    eng = ServingEngine(model, params, max_batch=2, window=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 4 + i)
                    .astype(np.int32), max_new_tokens=5) for i in range(5)]
    done = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.generated) == 5 for r in done)


def test_serving_with_fastcache_gate(key):
    from repro.serving import Request, ServingEngine
    cfg = f32_cfg(get_reduced("qwen3-0.6b"))
    model = build_model(cfg)
    params = model.init(key)
    eng = ServingEngine(model, params, max_batch=2, window=64,
                        fastcache=FastCacheConfig())
    rng = np.random.default_rng(0)
    done = eng.run([Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=8)
        for i in range(2)])
    assert all(len(r.generated) == 8 for r in done)
    assert eng.cache_stats()["block_cache_ratio"] >= 0.0


def test_checkpoint_roundtrip(tmp_path, key):
    import repro.checkpoint as ckpt
    cfg = f32_cfg(get_reduced("xlstm-1.3b"))
    model = build_model(cfg)
    params = model.init(key)
    path = str(tmp_path / "ck")
    ckpt.save(path, params, {"arch": cfg.name})
    restored = ckpt.load(path, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.load_metadata(path)["metadata"]["arch"] == cfg.name


@pytest.mark.slow
def test_dryrun_subprocess_small_mesh():
    """The dry-run driver lowers+compiles on a (2,2) host-device mesh —
    validates mesh/sharding/bundle plumbing end-to-end (the 512-device
    production run is exercised offline, see EXPERIMENTS.md)."""
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="4",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-0.6b",
         "--shape", "long_500k", "--mesh", "single", "--mesh-shape", "2,2",
         "--out", ""],
        env=env, capture_output=True, text=True, timeout=900)
    assert "1 ok" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]


@pytest.mark.slow
def test_dryrun_subprocess_multipod_small():
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-0.6b",
         "--shape", "decode_32k", "--mesh", "multi", "--mesh-shape", "2,2,2",
         "--out", ""],
        env=env, capture_output=True, text=True, timeout=900)
    assert "1 ok" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
