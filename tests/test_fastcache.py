"""FastCache core semantics: saliency partition, chi^2 gate, linear
calibration, token merging, cache policies, and the paper's claimed
behaviours (error bound Eq. 9, alpha-monotone cache rate — Fig. 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import (CachedDecoder, CachedDiT, chi2_ppf, error_bound,
                        summarize_stats)
from repro.core import linear_approx, saliency, statcache, token_merge
from repro.models import build_model
from tests.conftest import f32_cfg


# ---------------------------------------------------------------------------
# chi^2 / statistical gate
# ---------------------------------------------------------------------------

def test_chi2_ppf_matches_scipy():
    scipy = pytest.importorskip("scipy.stats")
    for df in (30, 1000, 300_000):
        for p in (0.9, 0.95, 0.99):
            assert abs(chi2_ppf(p, df) - scipy.chi2.ppf(p, df)) \
                / scipy.chi2.ppf(p, df) < 1e-3


def test_error_bound_eq9_shrinks_with_alpha():
    # higher confidence (smaller alpha) => larger threshold => larger bound
    nd = 64 * 256
    bounds = [error_bound(a, nd) for a in (0.2, 0.1, 0.05, 0.01)]
    assert all(b2 > b1 for b1, b2 in zip(bounds, bounds[1:]))
    # and the bound is ~1 for big ND (relative-change scale)
    assert 0.9 < bounds[0] < 1.2


def test_gate_decision_normalized_alpha_monotone(key):
    """Larger alpha => smaller threshold => fewer skips (Fig. 3 direction)."""
    nd = 4096
    h_prev = jax.random.normal(key, (64, 64))
    noise = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (64, 64))
    h = h_prev + noise
    diff, prev = statcache.delta_stats(h, h_prev)
    sigma2 = jnp.asarray(0.01)  # matched to the noise scale
    skips = []
    for alpha in (0.5, 0.1, 0.01):
        thr = statcache.make_threshold(alpha, nd)
        skips.append(bool(statcache.gate_decision(diff, prev, sigma2, nd,
                                                  thr)))
    # thresholds increase as alpha decreases
    t1 = statcache.make_threshold(0.5, nd)
    t2 = statcache.make_threshold(0.01, nd)
    assert t2 > t1


def test_gate_identical_hidden_always_caches(key):
    h = jax.random.normal(key, (32, 32))
    diff, prev = statcache.delta_stats(h, h)
    thr = statcache.make_threshold(0.05, h.size)
    assert bool(statcache.gate_decision(diff, prev, jnp.asarray(1.0), h.size,
                                        thr))


def test_gate_huge_change_never_caches(key):
    h = jax.random.normal(key, (32, 32))
    diff, prev = statcache.delta_stats(h * 100.0, h)
    thr = statcache.make_threshold(0.05, h.size)
    assert not bool(statcache.gate_decision(diff, prev, jnp.asarray(1.0),
                                            h.size, thr))


# ---------------------------------------------------------------------------
# Saliency / partition
# ---------------------------------------------------------------------------

def test_partition_invariants(key):
    x = jax.random.normal(key, (2, 32, 16))
    xp = x.at[:, :8].add(3.0)  # first 8 tokens moved
    sal = saliency.token_saliency(x, xp)
    part = saliency.partition_tokens(sal, tau_s=0.5, capacity=8)
    # exactly the moved tokens are motion
    assert bool(jnp.all(part.is_motion[:, :8]))
    assert not bool(jnp.any(part.is_motion[:, 8:]))
    # gather/scatter roundtrip: scatter(gather(x)) == x at motion positions
    xm = saliency.gather_motion(x, part)
    back = saliency.scatter_motion(jnp.zeros_like(x), xm, part)
    np.testing.assert_allclose(back[:, :8], x[:, :8], atol=1e-6)
    np.testing.assert_allclose(back[:, 8:], 0.0)


def test_partition_capacity_overflow_is_conservative(key):
    x = jax.random.normal(key, (1, 16, 8))
    xp = x + 1.0  # every token moved
    sal = saliency.token_saliency(x, xp)
    part = saliency.partition_tokens(sal, tau_s=0.0, capacity=4)
    assert int(part.is_motion.sum()) == 4  # capacity-bounded


# ---------------------------------------------------------------------------
# Linear approximation + calibration
# ---------------------------------------------------------------------------

def test_fit_linear_recovers_exact_map(key):
    d = 16
    w_true = jax.random.normal(key, (d, d)) * 0.3
    b_true = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    x = jax.random.normal(jax.random.fold_in(key, 2), (512, d))
    y = x @ w_true + b_true
    w, b = linear_approx.fit_linear(x, y, ridge=1e-8)
    np.testing.assert_allclose(w, w_true, atol=1e-3)
    np.testing.assert_allclose(b, b_true, atol=1e-3)


def test_identity_init_is_passthrough(key):
    p = linear_approx.init_linear_params(3, 8)
    x = jax.random.normal(key, (4, 8))
    np.testing.assert_allclose(
        linear_approx.apply_linear(p["W_l"][1], p["b_l"][1], x), x,
        atol=1e-6)


def test_calibration_reduces_block_approx_error(key):
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    params = model.init(key)
    # adaLN-zero init makes blocks the identity — un-zero the gates so the
    # blocks actually transform (as a trained model would)
    params["blocks"]["ada_w"] = 0.05 * jax.random.normal(
        jax.random.fold_in(key, 7), params["blocks"]["ada_w"].shape)
    params["blocks"]["ada_b"] = 0.2 * jax.random.normal(
        jax.random.fold_in(key, 8), params["blocks"]["ada_b"].shape)
    img, ch = cfg.dit.image_size, cfg.dit.in_channels
    batches = [{"latents": jax.random.normal(jax.random.fold_in(key, i),
                                             (2, img, img, ch)),
                "t": jnp.array([10 * i + 1, 20 * i + 2]),
                "labels": jnp.array([i % 10, (i + 1) % 10])}
               for i in range(3)]
    ident = linear_approx.init_linear_params(cfg.num_layers, cfg.d_model)
    fit = linear_approx.calibrate_dit(model, params, ident, batches)

    # in-sample: least squares must beat the identity bypass (identity+0 is
    # inside the hypothesis class) — this is the paper's quality edge over
    # reuse-style caches (§ Zero-Shot Redundancy Reduction)
    err_ident, err_fit, n = 0.0, 0.0, 0
    for b in batches:
        x = model.tokens_in(params, b["latents"])
        c = model.conditioning(params, b["t"], b["labels"])
        bp = jax.tree.map(lambda a: a[0], params["blocks"])
        y = model.block_apply(bp, x, c)
        err_ident += float(jnp.sum((x - y) ** 2))
        approx = linear_approx.apply_linear(fit["W_l"][0], fit["b_l"][0], x)
        err_fit += float(jnp.sum((approx - y) ** 2))
        n += y.size
    assert err_fit < err_ident


# ---------------------------------------------------------------------------
# Token merging (CTM)
# ---------------------------------------------------------------------------

def test_merge_unmerge_shapes_and_identity_clusters(key):
    b, n, d, w = 2, 64, 16, 16
    h = jax.random.normal(key, (b, n, d))
    merged, mm = token_merge.merge_tokens(h, h, window=w, keep_ratio=0.5,
                                          k=5, lam=1.0)
    assert merged.shape == (b, n // 2, d)
    restored = token_merge.unmerge_tokens(merged, mm, window=w, n_tokens=n)
    assert restored.shape == h.shape
    # keep_ratio=1: every token is its own center -> lossless roundtrip
    merged2, mm2 = token_merge.merge_tokens(h, h, window=w, keep_ratio=1.0,
                                            k=5, lam=1.0)
    restored2 = token_merge.unmerge_tokens(merged2, mm2, window=w,
                                           n_tokens=n)
    np.testing.assert_allclose(restored2, h, atol=1e-4)
    # every restored token equals one of its window's merged representatives
    # (the stored mapping M of Alg. 2 is valid)
    mw = merged.reshape(2, n // w, -1, d)
    for bi in range(2):
        for wi in range(n // w):
            rw = restored.reshape(2, n // w, w, d)[bi, wi]
            d2 = jnp.sum((rw[:, None] - mw[bi, wi][None]) ** 2, -1)
            assert float(d2.min(axis=1).max()) < 1e-8


def test_merged_token_is_weighted_mean_in_hull(key):
    b, n, d, w = 1, 16, 8, 16
    h = jax.random.normal(key, (b, n, d))
    merged, _ = token_merge.merge_tokens(h, h, window=w, keep_ratio=0.25,
                                         k=3, lam=0.5)
    lo = h.min(axis=1, keepdims=True)
    hi = h.max(axis=1, keepdims=True)
    assert bool(jnp.all(merged >= lo - 1e-4))
    assert bool(jnp.all(merged <= hi + 1e-4))


def test_knn_density_higher_in_clusters(key):
    # one tight cluster + outliers: cluster tokens must have higher rho
    cluster = 0.01 * jax.random.normal(key, (1, 8, 4))
    outliers = 5.0 + jax.random.normal(jax.random.fold_in(key, 1), (1, 8, 4)) * 3
    h = jnp.concatenate([cluster, outliers], axis=1)
    rho = token_merge.knn_density(h, k=3)
    assert float(rho[0, :8].min()) > float(rho[0, 8:].max())


# ---------------------------------------------------------------------------
# Policies / runners
# ---------------------------------------------------------------------------

def _setup_dit(key, policy, fc=None, **kw):
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    params = model.init(key)
    runner = CachedDiT(model, fc or FastCacheConfig(), policy=policy, **kw)
    return cfg, model, params, runner


def _drive(runner, params, key, cfg, steps=6, shrink=0.02):
    b = 2
    img, ch = cfg.dit.image_size, cfg.dit.in_channels
    x = jax.random.normal(key, (b, img, img, ch))
    state = runner.init_state(b)
    step = jax.jit(runner.step)
    labels = jnp.array([1, 2])
    outs = []
    for t in range(steps):
        eps, state = step(params, state, x, jnp.full((b,), 50 - t), labels)
        outs.append(eps)
        x = x - shrink * eps
    return outs, state


def test_nocache_counts_all_blocks(key):
    cfg, model, params, runner = _setup_dit(key, "nocache")
    outs, state = _drive(runner, params, key, cfg)
    s = summarize_stats(state)
    assert s["block_cache_ratio"] == 0.0
    assert s["steps_reused"] == 0.0


def test_fora_reuses_fixed_interval(key):
    cfg, model, params, runner = _setup_dit(key, "fora", fora_interval=3)
    outs, state = _drive(runner, params, key, cfg, steps=6)
    s = summarize_stats(state)
    assert s["steps_reused"] == 4.0  # steps 1,2,4,5


def test_fastcache_skips_when_static(key):
    cfg, model, params, runner = _setup_dit(key, "fastcache")
    # identical inputs after step 2 -> gate must cache heavily
    b = 2
    img, ch = cfg.dit.image_size, cfg.dit.in_channels
    x = jax.random.normal(key, (b, img, img, ch))
    state = runner.init_state(b)
    step = jax.jit(runner.step)
    labels = jnp.array([1, 2])
    for t in range(6):
        eps, state = step(params, state, x, jnp.full((b,), 25), labels)
    s = summarize_stats(state)
    assert s["block_cache_ratio"] > 0.4, s
    # and the static-token fraction must be high (inputs identical)
    assert s["mean_motion_fraction"] < 0.5, s


def test_fastcache_output_close_to_nocache(key):
    cfg, model, params, r_nc = _setup_dit(key, "nocache")
    _, _, _, r_fc = _setup_dit(key, "fastcache")
    outs_nc, _ = _drive(r_nc, params, key, cfg)
    outs_fc, state = _drive(r_fc, params, key, cfg)
    rel = [float(jnp.linalg.norm(a - b) / (jnp.linalg.norm(a) + 1e-9))
           for a, b in zip(outs_nc, outs_fc)]
    # Eq. 9-style bounded deviation (loose engineering bound)
    assert max(rel) < 1.5, rel


def test_l2c_respects_mask(key):
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mask = jnp.zeros((cfg.num_layers,), bool).at[0].set(True)
    runner = CachedDiT(model, FastCacheConfig(), policy="l2c",
                       l2c_mask=mask)
    outs, state = _drive(runner, params, jax.random.PRNGKey(1), cfg,
                         steps=4)
    s = summarize_stats(state)
    assert s["blocks_skipped"] == 4.0  # 1 layer x 4 steps


def test_decode_runner_matches_exact_when_gate_off(key):
    cfg = f32_cfg(get_reduced("qwen3-0.6b"))
    model = build_model(cfg)
    params = model.init(key)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    fc = FastCacheConfig(use_sc=False)     # gate disabled -> exact decode
    dec = CachedDecoder(model, fc)
    st = dec.init_state(2)
    logits_ref, cache_ref = model.prefill(params, {"tokens": toks},
                                          window=32)
    logits_fc, cache_fc = model.prefill(params, {"tokens": toks}, window=32)
    for t in range(4):
        nxt = jnp.argmax(logits_ref, -1).astype(jnp.int32)
        logits_ref, cache_ref = model.decode_step(params, nxt, cache_ref)
        logits_fc, cache_fc, st = dec.decode_step(params, nxt, cache_fc, st)
        np.testing.assert_allclose(logits_fc, logits_ref, atol=1e-4)
    assert float(jnp.sum(st["stats"]["blocks_skipped"])) == 0.0
