"""Sharded multi-device diffusion serving: `ShardedDiffusionEngine` on a
(data, model) mesh must be **bitwise** identical to the single-device
`DiffusionServingEngine` for every cache policy — including mid-flight
admission and straggler warm-up — and the donated serve_step must keep
cache state device-resident (no per-step host round-trip).

Full multi-device coverage needs 8 virtual CPU devices:

    make test-sharded        # XLA_FLAGS=--xla_force_host_platform_device_count=8

On a single device the multi-device cases skip; the (1,1)-mesh parity,
donation and scheduler tests still run in the tier-1 suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT, POLICIES
from repro.distributed.sharding import (ShardingCtx, make_rules,
                                        serve_state_specs,
                                        serve_state_shardings)
from repro.models import build_model
from repro.serving import (DiffusionRequest, DiffusionServingEngine,
                           ShardedDiffusionEngine, make_serving_mesh,
                           poisson_trace)
from tests.conftest import assert_solo_replay_parity, f32_cfg

pytestmark = [pytest.mark.serving, pytest.mark.distributed]

STEPS = 4

multi_device = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(run via `make test-sharded`)")


@pytest.fixture(scope="module")
def dit():
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _staggered_trace():
    """Mid-flight admission AND straggler warm-up AND heterogeneous
    sampling plans: r0/r1 start (different step budgets + guidance), r2-r4
    queue and are admitted next to warm residents running different plans
    once slots free (r3 keeps the engine defaults)."""
    return [DiffusionRequest(rid=0, label=1, seed=10, arrival_step=0,
                             num_steps=4, guidance_scale=4.0),
            DiffusionRequest(rid=1, label=2, seed=11, arrival_step=1,
                             num_steps=2, guidance_scale=1.0),
            DiffusionRequest(rid=2, label=3, seed=12, arrival_step=2,
                             num_steps=3, guidance_scale=2.0),
            DiffusionRequest(rid=3, label=4, seed=13, arrival_step=3),
            DiffusionRequest(rid=4, label=5, seed=14, arrival_step=3,
                             num_steps=3, guidance_scale=1.0)]


def _base(model, params, policy, *, slots=4):
    runner = CachedDiT(model, FastCacheConfig(), policy=policy)
    return DiffusionServingEngine(runner, params, max_slots=slots,
                                  num_steps=STEPS)


def _sharded(model, params, policy, *, topo, slots=4, async_admission=True):
    runner = CachedDiT(model, FastCacheConfig(), policy=policy)
    return ShardedDiffusionEngine(runner, params, max_slots=slots,
                                  num_steps=STEPS,
                                  mesh=make_serving_mesh(*topo),
                                  async_admission=async_admission)


def _run_latents(eng):
    done = eng.run(_staggered_trace())
    assert len(done) == 5
    return {r.rid: np.asarray(r.latents) for r in done}


def _assert_same_serving(base_eng, sharded_eng):
    """Bitwise parity of latents, headline cache stats AND the full
    per-slot cache/gate state (payloads, chi^2 trackers, counters, plan
    tables, request-scoped accumulators) — the state comparison keeps this
    meaningful even where latents alone would be insensitive to caching
    decisions."""
    a = _run_latents(base_eng)
    b = _run_latents(sharded_eng)
    for rid in a:
        np.testing.assert_array_equal(a[rid], b[rid], err_msg=f"rid={rid}")
    sa, sb = base_eng.cache_stats(), sharded_eng.cache_stats()
    for k in ("blocks_skipped", "blocks_computed", "steps_reused",
              "block_cache_ratio", "engine_steps", "model_steps"):
        assert sa[k] == sb[k], (k, sa[k], sb[k])
    flat = getattr(jax.tree, "flatten_with_path", None) \
        or jax.tree_util.tree_flatten_with_path
    tree_a = (base_eng.state, base_eng.plan, base_eng.slot_acc)
    tree_b = (sharded_eng.state, sharded_eng.plan, sharded_eng.slot_acc)
    for (path, la), lb in zip(flat(tree_a)[0], jax.tree.leaves(tree_b)):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb),
            err_msg=f"state leaf {jax.tree_util.keystr(path)}")


# ---------------------------------------------------------------------------
# kind="serve" sharding rules + state sharding trees
# ---------------------------------------------------------------------------

def test_serve_rules_shard_slots_over_data():
    r = make_rules("serve")
    assert r["slot"] == ("data",)
    assert r["act_batch"] == ("data",)
    assert r["layers"] is None          # layer-stacked trackers replicated
    # weights stay tensor-parallel over `model`
    assert r["ffn"] == ("model",) and r["heads"] == ("model",)
    # non-serve kinds leave slot rows unmapped
    assert make_rules("train")["slot"] is None


@pytest.mark.parametrize("policy", POLICIES)
def test_serve_state_specs_cover_every_leaf(dit, policy):
    """The opaque-pytree walker covers EVERY registered policy's state: it
    derives each leaf's spec from rank/extents alone (no state keys), so a
    new policy module shards without touching distributed/sharding.py."""
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy=policy)
    state = runner.init_state(4)
    ctx = ShardingCtx(jax.make_mesh((1, 1), ("data", "model")),
                      make_rules("serve"))
    specs = serve_state_specs(state, ctx, batch=4, layers=runner.L)
    flat_state = jax.tree.leaves(state)
    flat_specs = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, P))
    assert len(flat_state) == len(flat_specs)
    for leaf, spec in zip(flat_state, flat_specs):
        assert len(spec) == leaf.ndim, (leaf.shape, spec)
    sh = serve_state_shardings(state, ctx, batch=4, layers=runner.L)
    assert jax.tree.structure(jax.tree.map(lambda _: 0, state)) == \
        jax.tree.structure(jax.tree.map(lambda _: 0, sh))


def test_slot_axis_rank_rules(dit):
    """The walker's rank/leading-axis contract: leading batch dim -> slot;
    layer-stacked (L or L+1 leading, batch second) -> slot on axis 1 (the
    layer rule wins even when L == batch); no batch extent -> replicated."""
    from repro.distributed.sharding import _slot_axis
    assert _slot_axis((8,), 8, 2) == 0
    assert _slot_axis((8, 16, 128), 8, 2) == 0
    assert _slot_axis((2, 8), 8, 2) == 1          # (L, B) trackers
    assert _slot_axis((3, 8, 16, 128), 8, 2) == 1  # (L+1, B, N, D) payloads
    assert _slot_axis((4, 4), 4, 4) == 1          # L == batch: layer rule
    assert _slot_axis((), 8, 2) is None
    assert _slot_axis((5, 7), 8, 2) is None       # no batch extent


def test_serve_plan_specs_shard_slot_rows():
    from repro.distributed.sharding import serve_plan_specs
    ctx = ShardingCtx(jax.make_mesh((1, 1), ("data", "model")),
                      make_rules("serve"))
    plan = {"ts": jnp.zeros((4, 8), jnp.int32),
            "ts_prev": jnp.zeros((4, 8), jnp.int32),
            "guidance": jnp.zeros((4,), jnp.float32)}
    specs = serve_plan_specs(plan, ctx)
    assert set(specs) == {"ts", "ts_prev", "guidance"}
    # slot dim carries the "slot" logical axis -> `data` on serve meshes
    # (this (1,1) mesh collapses it, but the spec rank must match)
    assert all(len(specs[k]) == plan[k].ndim for k in specs)


# ---------------------------------------------------------------------------
# Satellite: donated serve_step — cache state never round-trips the host
# ---------------------------------------------------------------------------

def test_serve_step_donates_state_no_host_transfer(dit):
    cfg, model, params = dit
    eng = _base(model, params, "fastcache", slots=2)
    eng.add_request(DiffusionRequest(rid=0, label=1, seed=5))
    eng.step()                          # compile outside the guard
    old_state_leaves = jax.tree.leaves(eng.state)
    old_x, old_acc = eng.x, dict(eng.acc)
    # no slot completes on this step, so nothing may touch the host
    with jax.transfer_guard_device_to_host("disallow"):
        eng.step()
    # donation: the previous step's buffers were aliased, not copied
    assert all(leaf.is_deleted() for leaf in old_state_leaves)
    assert old_x.is_deleted()
    assert all(v.is_deleted() for v in old_acc.values())


def test_admission_is_donated_too(dit):
    cfg, model, params = dit
    eng = _base(model, params, "fastcache", slots=2)
    eng.add_request(DiffusionRequest(rid=0, label=1, seed=5))
    eng.step()
    old_state_leaves = jax.tree.leaves(eng.state)
    with jax.transfer_guard_device_to_host("disallow"):
        assert eng.add_request(DiffusionRequest(rid=1, label=2, seed=6))
    assert all(leaf.is_deleted() for leaf in old_state_leaves)


# ---------------------------------------------------------------------------
# (1,1)-mesh parity: the sharded runtime is a pure refactor of the math
# ---------------------------------------------------------------------------

def test_sharded_1x1_matches_base_bitwise(dit):
    cfg, model, params = dit
    _assert_same_serving(_base(model, params, "fastcache"),
                         _sharded(model, params, "fastcache", topo=(1, 1)))


def test_sharded_no_cfg_fast_path_matches_base(dit):
    """cfg_rows=False rides the sharded runtime unchanged: single-row
    slots (state batch S), bitwise-equal latents to the single-device
    fast-path engine."""
    cfg, model, params = dit
    mk = lambda: CachedDiT(model, FastCacheConfig(), policy="fastcache")
    base = DiffusionServingEngine(mk(), params, max_slots=4,
                                  num_steps=STEPS, guidance_scale=1.0,
                                  cfg_rows=False)
    sh = ShardedDiffusionEngine(mk(), params, max_slots=4, num_steps=STEPS,
                                guidance_scale=1.0, cfg_rows=False,
                                mesh=make_serving_mesh(1, 1))
    assert sh.rows_per_slot == 1
    assert sh.state["have_cache"].shape == (4,)
    trace = [DiffusionRequest(rid=i, label=i, seed=20 + i, arrival_step=i)
             for i in range(5)]
    a = {r.rid: np.asarray(r.latents) for r in base.run(list(trace))}
    b = {r.rid: np.asarray(r.latents) for r in sh.run(list(trace))}
    for rid in a:
        np.testing.assert_array_equal(a[rid], b[rid], err_msg=f"rid={rid}")


def test_sharded_merge_1x1_matches_base_and_solo(dit):
    """Token compression rides the sharded runtime unchanged: merge-on
    (r=0.5) sharded serving is bitwise-equal to the single-device merge-on
    engine — including the reducer's per-slot saliency rows in the state
    pytree — and every finished request matches its merge-on solo replay,
    mid-flight admission included."""
    cfg, model, params = dit
    fc = FastCacheConfig(merge_enabled=True, merge_ratio=0.5,
                         merge_window=8)
    mk = lambda: CachedDiT(model, fc, policy="fastcache")
    assert mk().reducer is not None
    base = DiffusionServingEngine(mk(), params, max_slots=4,
                                  num_steps=STEPS)
    sh = ShardedDiffusionEngine(mk(), params, max_slots=4, num_steps=STEPS,
                                mesh=make_serving_mesh(1, 1))
    assert "tokred" in sh.state
    _assert_same_serving(base, sh)
    done = sh.run(_staggered_trace())
    assert_solo_replay_parity(sh, model, params, "fastcache", done, fc=fc)


@multi_device
def test_sharded_merge_parity_data4(dit):
    """Merge-on parity on the real (data=4) mesh: the reducer's
    prev_full/have_prev rows shard over `data` with the other slot state
    and the served latents still match the single-device engine bitwise."""
    cfg, model, params = dit
    fc = FastCacheConfig(merge_enabled=True, merge_ratio=0.5,
                         merge_window=8)
    mk = lambda: CachedDiT(model, fc, policy="fastcache")
    base = DiffusionServingEngine(mk(), params, max_slots=4,
                                  num_steps=STEPS)
    sh = ShardedDiffusionEngine(mk(), params, max_slots=4, num_steps=STEPS,
                                mesh=make_serving_mesh(4, 1))
    assert sh.state["tokred"]["prev_full"].sharding.spec[0] == "data"
    _assert_same_serving(base, sh)


def test_async_admission_matches_sync(dit):
    cfg, model, params = dit
    a = _run_latents(_sharded(model, params, "fastcache", topo=(1, 1),
                              async_admission=True))
    b = _run_latents(_sharded(model, params, "fastcache", topo=(1, 1),
                              async_admission=False))
    for rid in a:
        np.testing.assert_array_equal(a[rid], b[rid])


def test_admission_noise_lands_with_slot_spec(dit):
    cfg, model, params = dit
    eng = _sharded(model, params, "fastcache", topo=(1, 1))
    # one slot's row spec = the latent spec minus the slot axis
    assert eng._slot_row_sh.spec == P(*eng._x_sh.spec[1:])
    req = DiffusionRequest(rid=0, label=1, seed=5)
    staged = eng._staged_noise(req)
    assert staged.sharding == eng._slot_row_sh
    eng.add_request(req)
    assert eng.x.sharding.spec == eng._x_sh.spec  # layout undisturbed


# ---------------------------------------------------------------------------
# Multi-device: bitwise parity per policy on the 8-virtual-device mesh
# ---------------------------------------------------------------------------

@multi_device
@pytest.mark.parametrize("policy", POLICIES)
def test_sharded_parity_data4(dit, policy):
    """(data=4, model=1): slots and all per-slot cache/gate/stat rows —
    including the (S, max_steps) sampling-plan tables — shard 4-way;
    latents and cache-ratio stats must match the single-device engine
    bitwise, mid-flight admissions of HETEROGENEOUS plans included (the
    shared trace mixes 2/3/4-step budgets and guidance 1.0/2.0/4.0)."""
    cfg, model, params = dit
    _assert_same_serving(_base(model, params, policy),
                         _sharded(model, params, policy, topo=(4, 1)))


@multi_device
@pytest.mark.parametrize("policy", POLICIES)
def test_sharded_mixed_plans_match_solo_replay(dit, policy):
    """Tentpole acceptance on the mesh: every request of a mixed-plan batch
    served by the (4, 1) sharded engine is bitwise-equal to a solo
    ``sample()`` replay under its own resolved (num_steps,
    guidance_scale)."""
    cfg, model, params = dit
    eng = _sharded(model, params, policy, topo=(4, 1))
    done = eng.run(_staggered_trace())
    assert len(done) == 5
    # per-request budgets resolved (rid 3 fell back to the engine default)
    assert {r.rid: r.num_steps for r in done} == \
        {0: 4, 1: 2, 2: 3, 3: STEPS, 4: 3}
    assert_solo_replay_parity(eng, model, params, policy, done)


@multi_device
def test_model_axis_numerics_guard(dit):
    """model>1 meshes auto-run the startup numerics self-check.  On this
    jax/XLA CPU version the partitioner miscompiles the serve_step for any
    model>1 topology (NaNs / double-counted reductions observed during
    bring-up), so the engine must refuse to serve rather than emit garbage
    — on a backend that partitions correctly this constructs fine and the
    engine serves validated."""
    cfg, model, params = dit
    try:
        eng = _sharded(model, params, "fastcache", topo=(4, 2))
    except RuntimeError as e:
        assert "numerics self-check" in str(e)
        return
    # backend partitions model>1 correctly: the validated engine must
    # still match the single-device run end to end
    _assert_same_serving(_base(model, params, "fastcache"), eng)


@multi_device
def test_state_is_actually_sharded(dit):
    cfg, model, params = dit
    eng = _sharded(model, params, "fastcache", topo=(4, 1))
    # CFG doubles the slot rows: 8 state rows over data=4
    assert eng.state["prev_hidden"].sharding.spec[1] == "data"
    assert eng.state["gate"].sigma2.sharding.spec[1] == "data"
    assert eng.state["stats"]["blocks_skipped"].sharding.spec[0] == "data"
    assert eng.x.sharding.spec[0] == "data"
    # sampling-plan tables shard with the slot rows over `data`
    assert eng.plan["ts"].sharding.spec[0] == "data"
    assert eng.plan["ts_prev"].sharding.spec[0] == "data"
    assert eng.plan["guidance"].sharding.spec[0] == "data"
    assert all(v.sharding.spec[0] == "data"
               for v in eng.slot_acc.values())
    assert eng.topology() == {"data": 4, "model": 1, "devices": 4}


def test_admission_plan_rows_land_with_table_row_spec(dit):
    """Plan rows ride the same per-slot device_put mechanism as the
    admission noise: staged with one table-row's spec (the plan spec minus
    the slot axis), consumed by the fused _admit without resharding."""
    cfg, model, params = dit
    eng = _sharded(model, params, "fastcache", topo=(1, 1))
    assert eng._plan_row_sh.spec == P(*eng._plan_sh["ts"].spec[1:])
    req = DiffusionRequest(rid=0, label=1, seed=5, num_steps=3,
                           guidance_scale=2.0)
    plan = eng.resolve_plan(req)
    ts_row, prev_row = plan.rows(eng.max_steps, eng.num_train_steps)
    staged = eng._staged_plan(ts_row, prev_row)
    assert all(s.sharding == eng._plan_row_sh for s in staged)
    eng.add_request(req)
    assert eng.plan["ts"].sharding.spec == eng._plan_sh["ts"].spec


@multi_device
def test_sharded_bench_weights_schedule_parity():
    """Real (non-adaLN-zero) weights: XLA:CPU gemms are batch-shape
    sensitive — the same row in a 2-row and an 8-row matmul can differ in
    the last bits, so sharded latents drift from the single-device run at
    fp-reassociation scale (the topology benchmark reports the honest
    max-abs-diff).  The *runtime* contract still holds exactly: identical
    admission/finish scheduling, step counts and per-request latencies,
    with latents equal to tolerance."""
    from benchmarks.common import build_dit
    cfg, model, params = build_dit("dit-b2")
    res = {}
    for topo in (None, (4, 1)):
        runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
        eng = (DiffusionServingEngine(runner, params, max_slots=4,
                                      num_steps=STEPS) if topo is None else
               ShardedDiffusionEngine(runner, params, max_slots=4,
                                      num_steps=STEPS,
                                      mesh=make_serving_mesh(*topo)))
        done = eng.run(_staggered_trace())
        res[topo] = ({r.rid: (r.admit_step, r.finish_step, r.latency_steps)
                      for r in done},
                     {r.rid: np.asarray(r.latents) for r in done},
                     (eng.clock, eng.model_steps))
    sched_a, lat_a, steps_a = res[None]
    sched_b, lat_b, steps_b = res[(4, 1)]
    assert sched_a == sched_b
    assert steps_a == steps_b
    for rid in lat_a:
        np.testing.assert_allclose(lat_a[rid], lat_b[rid], atol=0.5,
                                   err_msg=f"rid={rid}")


@multi_device
def test_sharded_lockstep_mode(dit):
    cfg, model, params = dit
    eng = _sharded(model, params, "fastcache", topo=(4, 1))
    done = eng.run(_staggered_trace(), lockstep=True)
    assert len(done) == 5 and all(r.done for r in done)


# ---------------------------------------------------------------------------
# Satellite: reproducible Poisson traces (explicit seed or jax.random key)
# ---------------------------------------------------------------------------

def test_poisson_trace_requires_explicit_seed_or_key():
    with pytest.raises(TypeError):
        poisson_trace(4, 0.5, num_classes=10)
    with pytest.raises(TypeError):
        poisson_trace(4, 0.5, seed=1, key=jax.random.PRNGKey(1),
                      num_classes=10)
    # num_classes has no default either: it must come from the model config
    with pytest.raises(TypeError):
        poisson_trace(4, 0.5, seed=1)


def test_poisson_trace_key_is_deterministic():
    a = poisson_trace(16, 0.5, key=jax.random.PRNGKey(42), num_classes=10)
    b = poisson_trace(16, 0.5, key=jax.random.PRNGKey(42), num_classes=10)
    assert [(r.arrival_step, r.label, r.seed) for r in a] == \
        [(r.arrival_step, r.label, r.seed) for r in b]
    c = poisson_trace(16, 0.5, key=jax.random.PRNGKey(43), num_classes=10)
    assert [r.arrival_step for r in a] != [r.arrival_step for r in c] or \
        [r.label for r in a] != [r.label for r in c]
