"""Model-substrate correctness: decode==full-forward consistency, causality,
GQA equivalence, RoPE behaviour, sliding-window semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.models.attention import attend_chunked, attend_direct, attention
from tests.conftest import f32_cfg

DECODE_ARCHS = ["qwen3-0.6b", "stablelm-3b", "yi-9b", "xlstm-1.3b",
                "jamba-v0.1-52b", "kimi-k2-1t-a32b", "arctic-480b",
                "qwen2-vl-2b"]


def _batches(cfg, key, s_total, s_pre):
    toks = jax.random.randint(key, (2, s_total), 0, cfg.vocab_size)
    full = {"tokens": toks}
    pre = {"tokens": toks[:, :s_pre]}
    if cfg.family == "vlm":
        nv = min(cfg.vision_tokens, s_pre - 2)
        vm = jnp.zeros((2, s_total), bool).at[:, 1:1 + nv].set(True)
        ve = jax.random.normal(key, (2, cfg.vision_tokens, cfg.d_model))
        full.update(vision_embeds=ve, vision_mask=vm)
        pre.update(vision_embeds=ve, vision_mask=vm[:, :s_pre])
    return toks, full, pre


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_full_forward(arch, key):
    cfg = f32_cfg(get_reduced(arch))
    model = build_model(cfg)
    params = model.init(key)
    s_pre, extra = 24, 4
    toks, full, pre = _batches(cfg, key, s_pre + extra, s_pre)
    hidden, _ = model.apply(params, full)
    ref_logits = model.unembed(params, hidden)

    logits, cache = model.prefill(params, pre, window=48)
    np.testing.assert_allclose(logits, ref_logits[:, s_pre - 1], atol=2e-3)
    for t in range(extra):
        logits, cache = model.decode_step(params, toks[:, s_pre + t], cache)
        np.testing.assert_allclose(logits, ref_logits[:, s_pre + t],
                                   atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "jamba-v0.1-52b",
                                  "xlstm-1.3b"])
def test_causality(arch, key):
    """Future tokens must not influence earlier hidden states."""
    cfg = f32_cfg(get_reduced(arch))
    model = build_model(cfg)
    params = model.init(key)
    toks = jax.random.randint(key, (1, 16), 0, cfg.vocab_size)
    h1, _ = model.apply(params, {"tokens": toks})
    toks2 = toks.at[:, 12:].set((toks[:, 12:] + 7) % cfg.vocab_size)
    h2, _ = model.apply(params, {"tokens": toks2})
    np.testing.assert_allclose(h1[:, :12], h2[:, :12], atol=1e-4)
    assert not bool(jnp.allclose(h1[:, 12:], h2[:, 12:], atol=1e-4))


def test_encoder_is_bidirectional(key):
    cfg = f32_cfg(get_reduced("hubert-xlarge"))
    model = build_model(cfg)
    params = model.init(key)
    feats = jax.random.normal(key, (1, 16, cfg.frontend_dim))
    h1, _ = model.apply(params, {"features": feats})
    feats2 = feats.at[:, 12:].add(1.0)
    h2, _ = model.apply(params, {"features": feats2})
    # changing late frames must change EARLY hidden states (bidirectional)
    assert not bool(jnp.allclose(h1[:, :8], h2[:, :8], atol=1e-5))


def test_gqa_equals_mha_when_kv_heads_match(key):
    b, s, h, dh = 2, 16, 4, 16
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
    pos = jnp.arange(s)
    out_full = attend_direct(q, k, v, pos, pos, causal=True)
    # group heads: same inputs tiled as GQA with kvh=2
    k2 = k[:, :, :2]
    v2 = v[:, :, :2]
    q2 = q.reshape(b, s, 2, 2, dh).reshape(b, s, 4, dh)
    out_gqa = attend_direct(q2, k2, v2, pos, pos, causal=True)
    assert out_gqa.shape == out_full.shape


def test_chunked_equals_direct_attention(key):
    b, sq, h, kvh, dh = 2, 64, 8, 2, 32
    q = jax.random.normal(key, (b, sq, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, sq, kvh, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, sq, kvh, dh))
    pos = jnp.arange(sq)
    for causal in (True, False):
        for window in (0, 24):
            ref = attend_direct(q, k, v, pos, pos, causal=causal,
                                window=window)
            out = attend_chunked(q, k, v, pos, pos, causal=causal,
                                 window=window, chunk_kv=16)
            np.testing.assert_allclose(out, ref, atol=2e-5)


def test_prefix_grouped_equals_plain_causal(key):
    b, sq, h, kvh, dh = 1, 64, 4, 2, 16
    q = jax.random.normal(key, (b, sq, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, sq, kvh, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, sq, kvh, dh))
    pos = jnp.arange(sq)
    ref = attend_direct(q, k, v, pos, pos, causal=True)
    out = attention(q, k, v, pos, pos, causal=True, impl="chunked",
                    chunk_kv=8, prefix_groups=4)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_sliding_window_cache_ring_buffer(key):
    """Decode with cache window W must equal full attention restricted to
    the last W positions."""
    cfg = f32_cfg(get_reduced("yi-9b")).replace(num_layers=2)
    model = build_model(cfg)
    params = model.init(key)
    w = 8
    toks = jax.random.randint(key, (1, 20), 0, cfg.vocab_size)
    _, cache = model.prefill(params, {"tokens": toks[:, :12]}, window=w)
    logits_ring, cache = model.decode_step(params, toks[:, 12], cache)
    # reference: SWA over full history with window w
    model_swa = build_model(cfg.replace(sliding_window=w))
    assert logits_ring.shape == (1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits_ring).any())
    # cache holds only w slots
    blk = cache["blocks"]["pos0"]
    assert blk["k"].shape[2] == w


def test_mrope_equals_rope_for_text(key):
    from repro.models.common import apply_mrope, apply_rope
    b, s, h, dh = 1, 8, 2, 16
    x = jax.random.normal(key, (b, s, h, dh))
    pos = jnp.arange(s)[None]
    r1 = apply_rope(x, pos, theta=10000.0)
    pos3 = jnp.repeat(pos[..., None], 3, axis=-1)
    r2 = apply_mrope(x, pos3, (3, 3, 2), theta=10000.0)
    np.testing.assert_allclose(r1, r2, atol=1e-5)


def test_rope_preserves_norm(key):
    from repro.models.common import apply_rope
    x = jax.random.normal(key, (2, 8, 2, 16))
    r = apply_rope(x, jnp.arange(8)[None], theta=500.0)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(r, axis=-1), rtol=1e-5)
