"""Steady-state serving invariants (the runtime half of reprolint).

After warm-up, a serving engine's inner loop must be compile-free: every
``step()`` reuses the jitted executables traced during warm-up, and — for
the diffusion engine, whose step loop is fully device-resident — performs
no device->host transfer unless a request actually finishes (harvest).
A recompile in steady state means a shape or dtype leaked into a trace
(e.g. a host int that should have been a device array), which silently
multiplies serving latency; these tests pin that down with
``jitted_fn._cache_size()`` snapshots inside ``jax.transfer_guard``.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT
from repro.models import build_model
from repro.obs import MetricsCollector
from repro.obs import metrics as obs_metrics
from repro.serving import (DiffusionRequest, DiffusionServingEngine,
                           Request, ServingEngine)
from tests.conftest import f32_cfg, steady_state_guard

pytestmark = pytest.mark.serving


@pytest.fixture(scope="module")
def dit():
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_diffusion_engine_steady_state(dit):
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    eng = DiffusionServingEngine(runner, params, max_slots=2,
                                 num_steps=12, guidance_scale=4.0)

    # Warm every jitted entry point: an admission traces _admit, the first
    # step traces _step, and running a short request to completion traces
    # _reset (slot free) — after this, steady state must be compile-free.
    warm = DiffusionRequest(rid=0, label=1, seed=10, arrival_step=0,
                            num_steps=4)
    if not eng.add_request(warm):
        raise AssertionError("warm-up admission must land in a free slot")
    done = []
    while not done:
        done += eng.step()

    residents = [DiffusionRequest(rid=1, label=2, seed=11, arrival_step=0),
                 DiffusionRequest(rid=2, label=3, seed=12, arrival_step=0)]
    for r in residents:
        if not eng.add_request(r):
            raise AssertionError("resident admission must land")
    eng.step()  # settle: one post-admission step outside the window

    # Both residents run 12-step plans and have consumed 1; an 8-step
    # window therefore sees no completions, so the loop must be pure
    # device compute: zero recompiles, zero host fetches.
    with steady_state_guard(eng._step, eng._reset, eng._admit):
        for _ in range(8):
            finished = eng.step()
            assert finished == [], \
                f"no request should finish inside the window: {finished}"

    while len(done) < 3:
        done += eng.step()
    assert sorted(r.rid for r in done) == [0, 1, 2]


def test_diffusion_mid_window_admission_is_compile_free(dit):
    """Admitting into a warm engine reuses the traced _admit executable —
    mid-flight admission must not pay a compile either."""
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    eng = DiffusionServingEngine(runner, params, max_slots=2,
                                 num_steps=10, guidance_scale=4.0)
    if not eng.add_request(DiffusionRequest(rid=0, label=1, seed=10,
                                            arrival_step=0)):
        raise AssertionError("first admission must land")
    eng.step()
    eng.step()
    with steady_state_guard(eng._step, eng._admit):
        if not eng.add_request(DiffusionRequest(rid=1, label=2, seed=11,
                                                arrival_step=2)):
            raise AssertionError("mid-flight admission must land")
        for _ in range(4):
            assert eng.step() == []


def test_diffusion_steady_state_with_metrics_plane(dit):
    """The telemetry tentpole's acceptance bar: with the device metrics
    plane live AND a collector attached, the steady-state window is still
    compile-free and transfer-free — metric updates are pure jnp inside
    the jitted step, and ``harvest`` (the only sync) stays outside the
    window.  The post-window harvest then proves the plane actually
    counted the window's steps."""
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    collector = MetricsCollector(labels={"policy": "fastcache"})
    eng = DiffusionServingEngine(runner, params, max_slots=2,
                                 num_steps=12, guidance_scale=4.0,
                                 collector=collector)
    assert eng.metrics, "metrics plane must be on by default"
    warm = DiffusionRequest(rid=0, label=1, seed=10, arrival_step=0,
                            num_steps=4)
    if not eng.add_request(warm):
        raise AssertionError("warm-up admission must land in a free slot")
    done = []
    while not done:
        done += eng.step()
    for r in (DiffusionRequest(rid=1, label=2, seed=11, arrival_step=0),
              DiffusionRequest(rid=2, label=3, seed=12, arrival_step=0)):
        if not eng.add_request(r):
            raise AssertionError("resident admission must land")
    eng.step()  # settle: one post-admission step outside the window

    clock_before = eng.clock
    with steady_state_guard(eng._step, eng._reset, eng._admit):
        for _ in range(8):
            assert eng.step() == []

    harvested = eng.harvest_metrics()
    assert harvested["counters"][obs_metrics.SERVE_STEPS] \
        == eng.model_steps
    assert eng.clock - clock_before == 8


def test_diffusion_steady_state_with_audit_plane(dit):
    """The audit tentpole's acceptance bar: with the shadow-compute audit
    plane armed (``audit_fraction=0.5`` — the window mixes audited and
    non-audited steps, exercising BOTH ``lax.cond`` branches), the
    steady-state window stays compile- and transfer-free.  The audit
    decision is a host-side hash of the step counter handed to the jit as
    a traced flag, so one executable serves every step."""
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    collector = MetricsCollector(labels={"policy": "fastcache"})
    eng = DiffusionServingEngine(runner, params, max_slots=2,
                                 num_steps=12, guidance_scale=4.0,
                                 collector=collector, audit_fraction=0.5)
    warm = DiffusionRequest(rid=0, label=1, seed=10, arrival_step=0,
                            num_steps=4)
    if not eng.add_request(warm):
        raise AssertionError("warm-up admission must land in a free slot")
    done = []
    while not done:
        done += eng.step()
    for r in (DiffusionRequest(rid=1, label=2, seed=11, arrival_step=0),
              DiffusionRequest(rid=2, label=3, seed=12, arrival_step=0)):
        if not eng.add_request(r):
            raise AssertionError("resident admission must land")
    eng.step()  # settle: one post-admission step outside the window

    with steady_state_guard(eng._step, eng._reset, eng._admit):
        for _ in range(8):
            assert eng.step() == []

    harvested = eng.harvest_metrics()
    audited = harvested["counters"][obs_metrics.AUDIT_STEPS]
    # fraction=0.5 over 13+ model steps: both branches must have run
    assert 0 < audited < eng.model_steps
    assert harvested["counters"][obs_metrics.AUDIT_SLOT_STEPS] > 0


def test_sharded_diffusion_steady_state_with_audit_plane(dit):
    """Same bar for the sharded engine (1x1 mesh runs single-device): the
    SPMD serve_step with the audit plane armed must be compile- and
    transfer-free across the steady window."""
    from repro.serving import ShardedDiffusionEngine, make_serving_mesh
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    collector = MetricsCollector(labels={"policy": "fastcache"})
    eng = ShardedDiffusionEngine(runner, params, max_slots=2,
                                 num_steps=12, guidance_scale=4.0,
                                 mesh=make_serving_mesh(1, 1),
                                 collector=collector, audit_fraction=0.5)
    warm = DiffusionRequest(rid=0, label=1, seed=10, arrival_step=0,
                            num_steps=4)
    if not eng.add_request(warm):
        raise AssertionError("warm-up admission must land in a free slot")
    done = []
    while not done:
        done += eng.step()
    for r in (DiffusionRequest(rid=1, label=2, seed=11, arrival_step=0),
              DiffusionRequest(rid=2, label=3, seed=12, arrival_step=0)):
        if not eng.add_request(r):
            raise AssertionError("resident admission must land")
    eng.step()  # settle: one post-admission step outside the window

    with steady_state_guard(eng._step, eng._reset, eng._admit):
        for _ in range(8):
            assert eng.step() == []

    harvested = eng.harvest_metrics()
    audited = harvested["counters"][obs_metrics.AUDIT_STEPS]
    assert 0 < audited < eng.model_steps
    assert harvested["counters"][obs_metrics.AUDIT_SLOT_STEPS] > 0


def _merge_fc():
    return FastCacheConfig(merge_enabled=True, merge_ratio=0.5,
                           merge_window=8)


def test_diffusion_steady_state_with_token_merge(dit):
    """Token-compression acceptance bar: with the merge stage on (r=0.5)
    plus live metrics AND audit planes, the steady-state window stays
    compile- and transfer-free — the reducer's saliency/merge/unmerge all
    run statically shaped inside the jitted serve_step.  The post-window
    harvest proves the token counters actually advanced."""
    cfg, model, params = dit
    runner = CachedDiT(model, _merge_fc(), policy="fastcache")
    assert runner.reducer is not None
    collector = MetricsCollector(labels={"policy": "fastcache"})
    eng = DiffusionServingEngine(runner, params, max_slots=2,
                                 num_steps=12, guidance_scale=4.0,
                                 collector=collector, audit_fraction=0.5)
    warm = DiffusionRequest(rid=0, label=1, seed=10, arrival_step=0,
                            num_steps=4)
    if not eng.add_request(warm):
        raise AssertionError("warm-up admission must land in a free slot")
    done = []
    while not done:
        done += eng.step()
    for r in (DiffusionRequest(rid=1, label=2, seed=11, arrival_step=0),
              DiffusionRequest(rid=2, label=3, seed=12, arrival_step=0)):
        if not eng.add_request(r):
            raise AssertionError("resident admission must land")
    eng.step()  # settle: one post-admission step outside the window

    with steady_state_guard(eng._step, eng._reset, eng._admit):
        for _ in range(8):
            assert eng.step() == []

    harvested = eng.harvest_metrics()
    kept = harvested["counters"][obs_metrics.TOKENS_KEPT]
    assert kept == harvested["counters"][obs_metrics.TOKENS_MERGED] > 0
    assert 0 < harvested["counters"][obs_metrics.AUDIT_STEPS] \
        < eng.model_steps


def test_sharded_diffusion_steady_state_with_token_merge(dit):
    """Same bar on the sharded engine (1x1 mesh): merge stage + metrics +
    audit, zero recompiles and zero host fetches across the window."""
    from repro.serving import ShardedDiffusionEngine, make_serving_mesh
    cfg, model, params = dit
    runner = CachedDiT(model, _merge_fc(), policy="fastcache")
    collector = MetricsCollector(labels={"policy": "fastcache"})
    eng = ShardedDiffusionEngine(runner, params, max_slots=2,
                                 num_steps=12, guidance_scale=4.0,
                                 mesh=make_serving_mesh(1, 1),
                                 collector=collector, audit_fraction=0.5)
    warm = DiffusionRequest(rid=0, label=1, seed=10, arrival_step=0,
                            num_steps=4)
    if not eng.add_request(warm):
        raise AssertionError("warm-up admission must land in a free slot")
    done = []
    while not done:
        done += eng.step()
    for r in (DiffusionRequest(rid=1, label=2, seed=11, arrival_step=0),
              DiffusionRequest(rid=2, label=3, seed=12, arrival_step=0)):
        if not eng.add_request(r):
            raise AssertionError("resident admission must land")
    eng.step()  # settle: one post-admission step outside the window

    with steady_state_guard(eng._step, eng._reset, eng._admit):
        for _ in range(8):
            assert eng.step() == []

    harvested = eng.harvest_metrics()
    assert harvested["counters"][obs_metrics.TOKENS_KEPT] > 0
    assert harvested["counters"][obs_metrics.AUDIT_STEPS] > 0


def test_diffusion_steady_state_with_slo_plane(dit):
    """Acceptance bar for the SLO control plane: once every executable is
    warm — including one full preempt/resume cycle — a control-plane tick
    (pressure observation, shedding hysteresis, preemption scan,
    deadline admission, engine step) is exactly as compile- and
    transfer-free as a bare ``engine.step()``, and the preemption pair
    itself (``_snapshot``/``_restore``) stays compile- and fetch-free
    when exercised INSIDE the guarded window: the snapshot is device
    buffers end to end."""
    from repro.serving import DegradationController, RequestQueue, \
        SLOScheduler

    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    eng = DiffusionServingEngine(runner, params, max_slots=2,
                                 num_steps=16, guidance_scale=4.0)
    sched = SLOScheduler(eng, sched_policy="edf",
                         controller=DegradationController())
    queue = RequestQueue(policy="edf")

    # warm _admit/_step/_reset with a short request driven through ticks
    queue.push(DiffusionRequest(rid=0, label=1, seed=10, arrival_step=0,
                                num_steps=4))
    done = []
    while not done:
        done += sched.tick(queue)

    # warm _snapshot/_restore with one preempt/resume cycle
    residents = [DiffusionRequest(rid=1, label=2, seed=11, arrival_step=0,
                                  num_steps=16),
                 DiffusionRequest(rid=2, label=3, seed=12, arrival_step=0,
                                  num_steps=16)]
    for r in residents:
        queue.push(r)
    sched.tick(queue)                    # admits both, steps once
    queue.push(eng.preempt(0))
    sched.tick(queue)                    # resumes from the snapshot

    # 16-step budgets with <=4 steps consumed: an 8-tick window sees no
    # completions, so every tick must be pure warm device compute — even
    # the one that preempts a resident and the one that resumes it.
    with steady_state_guard(eng._step, eng._reset, eng._admit,
                            eng._snapshot, eng._restore):
        for i in range(8):
            finished = sched.tick(queue)
            assert finished == [], \
                f"no request should finish inside the window: {finished}"
            if i == 2:
                queue.push(eng.preempt(1))

    while len(done) < 3:
        done += sched.tick(queue)
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert sum(r.preemptions for r in done) == 2


def test_ar_engine_steady_state_with_collector():
    """Host-plane metrics on the AR engine (per-step token fetch is by
    design there): a live collector must not add recompiles."""
    cfg = f32_cfg(get_reduced("qwen3-0.6b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    collector = MetricsCollector()
    eng = ServingEngine(model, params, max_batch=2, window=64,
                        fastcache=FastCacheConfig(), collector=collector)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=32)
            for i in range(2)]
    for r in reqs:
        if not eng.add_request(r):
            raise AssertionError("admission must land in a free slot")
    for _ in range(3):
        eng.step()
    with steady_state_guard(eng._prefill, eng._decode, transfers="allow"):
        for _ in range(16):
            eng.step()
    totals = collector.totals()
    assert totals[obs_metrics.ADMISSIONS] == 2.0
    assert totals[obs_metrics.PREFILLS] == 2.0
    assert totals[obs_metrics.DECODE_TOKENS] > 0.0


def test_ar_engine_steady_state():
    cfg = f32_cfg(get_reduced("qwen3-0.6b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=2, window=64,
                        fastcache=FastCacheConfig())
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=32)
            for i in range(2)]
    for r in reqs:
        if not eng.add_request(r):
            raise AssertionError("admission must land in a free slot")
    for _ in range(3):  # warm the batched decode trace
        eng.step()

    # AR decode fetches the sampled token every step by design, so host
    # transfers stay allowed; the enforced invariant is zero recompiles
    # of the prefill/decode executables across the steady window.
    with steady_state_guard(eng._prefill, eng._decode, transfers="allow"):
        for _ in range(16):
            eng.step()
    assert not any(r.done for r in reqs), \
        "window sized to finish no request (budget 32, used 20)"
