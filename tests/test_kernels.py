"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,d", [(128, 512), (256, 1024), (384, 768)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_saliency_delta(n, d, dtype, key):
    x = jax.random.normal(key, (n, d)).astype(dtype)
    xp = jax.random.normal(jax.random.fold_in(key, 1), (n, d)).astype(dtype)
    sal, diff, prev = ops.saliency_delta(x, xp, bn=128, bd=256,
                                         interpret=True)
    sal_r, diff_r, prev_r = ref.saliency_delta(x, xp)
    tol = 1e-4 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(sal, sal_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(diff, diff_r, rtol=tol)
    np.testing.assert_allclose(prev, prev_r, rtol=tol)


@pytest.mark.parametrize("m,d,f", [(128, 256, 256), (256, 512, 256),
                                   (128, 768, 512)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("gamma", [0.0, 0.5, 1.0])
def test_linear_blend(m, d, f, dtype, gamma, key):
    ks = jax.random.split(key, 4)
    x = (jax.random.normal(ks[0], (m, d)) * 0.5).astype(dtype)
    w = (jax.random.normal(ks[1], (d, f)) * 0.05).astype(dtype)
    b = jax.random.normal(ks[2], (f,)).astype(dtype)
    prev = jax.random.normal(ks[3], (m, f)).astype(dtype)
    out = ops.linear_blend(x, w, b, prev, gamma=gamma, bm=128, bf=128,
                           bk=128, interpret=True)
    out_r = ref.linear_blend(x, w, b, prev, gamma)
    tol = 1e-4 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,h,kvh,sq,skv,dh", [
    (1, 4, 4, 128, 128, 64),     # MHA square
    (2, 8, 2, 128, 128, 64),     # GQA
    (1, 4, 1, 64, 256, 32),      # cross / decode-ish (Sq < Skv)
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 96),
                                           (False, 0)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention(b, h, kvh, sq, skv, dh, causal, window, dtype, key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, sq, dh)).astype(dtype)
    k = jax.random.normal(ks[1], (b, kvh, skv, dh)).astype(dtype)
    v = jax.random.normal(ks[2], (b, kvh, skv, dh)).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=64, bk=64, interpret=True)
    out_r = ref.flash_attention(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("nw,w,d,k", [(4, 16, 32, 5), (2, 32, 64, 3),
                                      (8, 8, 16, 7)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_knn_density(nw, w, d, k, dtype, key):
    h = jax.random.normal(key, (nw, w, d)).astype(dtype)
    out = ops.knn_density(h, k=k, interpret=True)
    out_r = ref.knn_density(h, min(k, w - 1))
    tol = 1e-4 if dtype == "float32" else 6e-2
    np.testing.assert_allclose(out, out_r, rtol=tol, atol=tol)


def test_flash_attention_matches_model_attention(key):
    """Kernel layout (B,H,S,dh) agrees with the model's (B,S,H,dh) path."""
    from repro.models.attention import attend_direct
    b, h, kvh, s, dh = 1, 4, 2, 128, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kvh, dh))
    v = jax.random.normal(ks[2], (b, s, kvh, dh))
    pos = jnp.arange(s)
    ref_out = attend_direct(q, k, v, pos, pos, causal=True)
    kern = ops.flash_attention(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=True,
                               bq=64, bk=64, interpret=True)
    np.testing.assert_allclose(kern.transpose(0, 2, 1, 3), ref_out,
                               atol=2e-5)
