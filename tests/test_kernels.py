"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,d", [(128, 512), (256, 1024), (384, 768)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_saliency_delta(n, d, dtype, key):
    x = jax.random.normal(key, (n, d)).astype(dtype)
    xp = jax.random.normal(jax.random.fold_in(key, 1), (n, d)).astype(dtype)
    sal, diff, prev = ops.saliency_delta(x, xp, bn=128, bd=256,
                                         interpret=True)
    sal_r, diff_r, prev_r = ref.saliency_delta(x, xp)
    tol = 1e-4 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(sal, sal_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(diff, diff_r, rtol=tol)
    np.testing.assert_allclose(prev, prev_r, rtol=tol)


@pytest.mark.parametrize("m,d,f", [(128, 256, 256), (256, 512, 256),
                                   (128, 768, 512)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("gamma", [0.0, 0.5, 1.0])
def test_linear_blend(m, d, f, dtype, gamma, key):
    ks = jax.random.split(key, 4)
    x = (jax.random.normal(ks[0], (m, d)) * 0.5).astype(dtype)
    w = (jax.random.normal(ks[1], (d, f)) * 0.05).astype(dtype)
    b = jax.random.normal(ks[2], (f,)).astype(dtype)
    prev = jax.random.normal(ks[3], (m, f)).astype(dtype)
    out = ops.linear_blend(x, w, b, prev, gamma=gamma, bm=128, bf=128,
                           bk=128, interpret=True)
    out_r = ref.linear_blend(x, w, b, prev, gamma)
    tol = 1e-4 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,c,d", [(2, 32, 128), (4, 64, 256), (3, 16, 64)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("use_blend", [True, False])
def test_fused_gate(b, c, d, dtype, use_blend, key):
    from repro.core import statcache
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, c, d)).astype(dtype)
    prev = (x + 0.01 * jax.random.normal(ks[1], (b, c, d))).astype(dtype)
    prev = prev.at[0].add(5.0)              # sample 0 moved a lot
    po = jax.random.normal(ks[2], (b, c, d)).astype(dtype)
    w = (jnp.eye(d) + 0.01 * jax.random.normal(ks[3], (d, d))).astype(dtype)
    bias = (0.1 * jax.random.normal(ks[4], (d,))).astype(dtype)
    sigma2 = jnp.full((b,), 1e-4, jnp.float32)
    eligible = jnp.arange(b) != b - 1       # last sample ineligible
    thr = statcache.make_threshold(0.05, c * d)
    out, gate, diff, prevsq = ops.fused_gate(
        x, prev, po, w, bias, sigma2, eligible, threshold=thr, gamma=0.5,
        use_blend=use_blend, interpret=True)
    out_r, gate_r, diff_r, prevsq_r = ref.fused_gate(
        x, prev, po, w, bias, sigma2, eligible, threshold=thr, gamma=0.5,
        use_blend=use_blend)
    tol = 1e-4 if dtype == "float32" else 5e-2
    np.testing.assert_array_equal(np.asarray(gate), np.asarray(gate_r))
    assert not bool(gate[0]) and not bool(gate[b - 1])  # moved / ineligible
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(diff, diff_r, rtol=tol)
    np.testing.assert_allclose(prevsq, prevsq_r, rtol=tol)


def test_fused_gate_blocked_token_axis(key):
    """C-axis blocking (two-phase grid revisit) agrees with one-shot."""
    from repro.core import statcache
    x = jax.random.normal(key, (2, 64, 128))
    prev = x + 0.05
    po = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 128))
    w = jnp.eye(128)
    thr = statcache.make_threshold(0.05, 64 * 128)
    args = (x, prev, po, w, jnp.zeros((128,)), jnp.full((2,), 0.01),
            jnp.ones((2,), bool))
    a = ops.fused_gate(*args, threshold=thr, bc=16, interpret=True)
    b = ref.fused_gate(*args, threshold=thr)
    np.testing.assert_allclose(a[0], b[0], atol=1e-5)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


@pytest.mark.parametrize("b,h,kvh,sq,skv,dh", [
    (1, 4, 4, 128, 128, 64),     # MHA square
    (2, 8, 2, 128, 128, 64),     # GQA
    (1, 4, 1, 64, 256, 32),      # cross / decode-ish (Sq < Skv)
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 96),
                                           (False, 0)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention(b, h, kvh, sq, skv, dh, causal, window, dtype, key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, sq, dh)).astype(dtype)
    k = jax.random.normal(ks[1], (b, kvh, skv, dh)).astype(dtype)
    v = jax.random.normal(ks[2], (b, kvh, skv, dh)).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=64, bk=64, interpret=True)
    out_r = ref.flash_attention(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("nw,w,d,k", [(4, 16, 32, 5), (2, 32, 64, 3),
                                      (8, 8, 16, 7)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_knn_density(nw, w, d, k, dtype, key):
    h = jax.random.normal(key, (nw, w, d)).astype(dtype)
    out = ops.knn_density(h, k=k, interpret=True)
    out_r = ref.knn_density(h, min(k, w - 1))
    tol = 1e-4 if dtype == "float32" else 6e-2
    np.testing.assert_allclose(out, out_r, rtol=tol, atol=tol)


@pytest.mark.parametrize("nw,w,d,m", [(4, 16, 32, 8), (2, 32, 64, 8),
                                      (8, 8, 16, 3), (3, 16, 48, 1)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_merge_assign(nw, w, d, m, dtype, key):
    """Fused merge kernel (top-M centers -> nearest-center assign ->
    importance-weighted cluster means) vs the pure-jnp ref, including the
    integer outputs bitwise (same centers, same assignment)."""
    h = jax.random.normal(key, (nw, w, d)).astype(dtype)
    s = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1),
                                         (nw, w)))
    merged, assign, centers = ops.merge_assign(h, s, m=m, interpret=True)
    merged_r, assign_r, centers_r = ref.merge_assign(h, s, m)
    np.testing.assert_array_equal(np.asarray(centers),
                                  np.asarray(centers_r))
    np.testing.assert_array_equal(np.asarray(assign), np.asarray(assign_r))
    assert merged.dtype == h.dtype and merged.shape == (nw, m, d)
    tol = 1e-5 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(np.asarray(merged, np.float32),
                               np.asarray(merged_r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("nw,w,d,m", [(4, 16, 32, 8), (2, 8, 64, 4)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_unmerge_scatter(nw, w, d, m, dtype, key):
    merged = jax.random.normal(key, (nw, m, d)).astype(dtype)
    assign = jax.random.randint(jax.random.fold_in(key, 1), (nw, w), 0, m,
                                jnp.int32)
    out = ops.unmerge_scatter(merged, assign, interpret=True)
    out_r = ref.unmerge_scatter(merged, assign)
    assert out.dtype == merged.dtype and out.shape == (nw, w, d)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=1e-6, atol=1e-6)


def test_merge_unmerge_identity_at_full_m(key):
    """m == w keeps every token a center: unmerge(merge) is the identity
    up to the kernel's f32 accumulate (cluster mean of one token)."""
    h = jax.random.normal(key, (2, 16, 32))
    s = jnp.ones((2, 16)) / 16.0
    merged, assign, centers = ops.merge_assign(h, s, m=16, interpret=True)
    out = ops.unmerge_scatter(merged, assign, interpret=True)
    # every token is its own cluster: the "mean" is the token itself
    np.testing.assert_allclose(
        np.sort(np.asarray(centers), axis=1),
        np.tile(np.arange(16), (2, 1)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(h), atol=1e-5)


@pytest.mark.parametrize("k", [0, 16, 20])
def test_knn_density_k_bounds_raise_in_both_paths(k, key):
    """Out-of-range K raises the SAME error from the Pallas wrapper and
    the pure-jnp ref (the pre-fix wrapper silently clamped, letting the
    two paths compute different K)."""
    h = jax.random.normal(key, (2, 16, 8))
    with pytest.raises(ValueError, match="out of range for window"):
        ops.knn_density(h, k=k, interpret=True)
    with pytest.raises(ValueError, match="out of range for window"):
        ref.knn_density(h, k)


@pytest.mark.parametrize("m", [0, 17])
def test_merge_assign_m_bounds_raise(m, key):
    h = jax.random.normal(key, (2, 16, 8))
    s = jnp.ones((2, 16))
    with pytest.raises(ValueError, match="out of range"):
        ops.merge_assign(h, s, m=m, interpret=True)


def test_flash_attention_matches_model_attention(key):
    """Kernel layout (B,H,S,dh) agrees with the model's (B,S,H,dh) path."""
    from repro.models.attention import attend_direct
    b, h, kvh, s, dh = 1, 4, 2, 128, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kvh, dh))
    v = jax.random.normal(ks[2], (b, s, kvh, dh))
    pos = jnp.arange(s)
    ref_out = attend_direct(q, k, v, pos, pos, causal=True)
    kern = ops.flash_attention(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=True,
                               bq=64, bk=64, interpret=True)
    np.testing.assert_allclose(kern.transpose(0, 2, 1, 3), ref_out,
                               atol=2e-5)
