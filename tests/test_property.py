"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import chi2_ppf, saliency, statcache, token_merge
from repro.models.attention import attend_chunked, attend_direct
from repro.models.common import apply_rope
from repro.training.optimizer import AdamW

SET = dict(max_examples=20, deadline=None)


@given(df=st.integers(30, 500_000), p=st.floats(0.5, 0.999))
@settings(**SET)
def test_chi2_ppf_monotone_in_p(df, p):
    assert chi2_ppf(p + 1e-3 * (1 - p), df) >= chi2_ppf(p, df) - 1e-6


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(4, 64),
       cap=st.integers(1, 64), tau=st.floats(0.0, 10.0))
@settings(**SET)
def test_partition_motion_count_bounded(seed, n, cap, tau):
    key = jax.random.PRNGKey(seed)
    sal = jax.random.uniform(key, (2, n)) * 5.0
    part = saliency.partition_tokens(sal, tau, min(cap, n))
    m = int(part.is_motion.sum(-1).max())
    assert m <= min(cap, n)
    # everything marked motion must exceed tau
    masked = np.asarray(jnp.where(part.is_motion, sal, jnp.inf))
    assert (masked > tau).all()


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SET)
def test_saliency_nonnegative_and_zero_iff_equal(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, 8, 4))
    s_zero = saliency.token_saliency(x, x)
    np.testing.assert_allclose(s_zero, 0.0, atol=1e-6)
    y = x + 0.1
    assert float(saliency.token_saliency(x, y).min()) > 0.0


@given(seed=st.integers(0, 2**31 - 1), sq=st.sampled_from([8, 16, 32]),
       chunk=st.sampled_from([4, 8, 16]), causal=st.booleans())
@settings(**SET)
def test_chunked_attention_equals_direct(seed, sq, chunk, causal):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, sq, 4, 8))
    k = jax.random.normal(ks[1], (1, sq, 2, 8))
    v = jax.random.normal(ks[2], (1, sq, 2, 8))
    pos = jnp.arange(sq)
    ref = attend_direct(q, k, v, pos, pos, causal=causal)
    out = attend_chunked(q, k, v, pos, pos, causal=causal, chunk_kv=chunk)
    np.testing.assert_allclose(out, ref, atol=3e-5)


@given(seed=st.integers(0, 2**31 - 1), shift=st.integers(0, 100))
@settings(**SET)
def test_rope_relative_property(seed, shift):
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]]), theta=100.0)
        kj = apply_rope(k, jnp.array([[j]]), theta=100.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(3 + shift, 1 + shift)) < 1e-3


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SET)
def test_adamw_matches_numpy_reference(seed):
    rng = np.random.default_rng(seed)
    p0 = rng.standard_normal((4, 3)).astype(np.float32)
    g = rng.standard_normal((4, 3)).astype(np.float32)
    opt = AdamW(b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0)
    params = {"w": jnp.asarray(p0)}
    state = opt.init(params)
    lr = 1e-2
    new_params, state = opt.update({"w": jnp.asarray(g)}, state, params, lr)
    # reference
    m = 0.1 * g
    v = 0.01 * g * g
    u = (m / 0.1) / (np.sqrt(v / 0.01) + 1e-8)
    ref = p0 - lr * u
    np.testing.assert_allclose(new_params["w"], ref, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1), keep=st.sampled_from([0.25, 0.5]))
@settings(**SET)
def test_merge_reduces_tokens_exactly(seed, keep):
    key = jax.random.PRNGKey(seed)
    h = jax.random.normal(key, (1, 32, 8))
    merged, mm = token_merge.merge_tokens(h, h, window=8, keep_ratio=keep,
                                          k=3, lam=1.0)
    assert merged.shape[1] == int(32 * keep)
    assert int(mm.assign.max()) < max(1, int(round(keep * 8)))


@given(alpha=st.floats(0.005, 0.3), nd=st.integers(100, 1_000_000))
@settings(**SET)
def test_threshold_decreases_with_alpha(alpha, nd):
    t1 = statcache.make_threshold(alpha, nd)
    t2 = statcache.make_threshold(min(0.5, alpha * 2), nd)
    assert t2 <= t1 + 1e-9
