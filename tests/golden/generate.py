"""Regenerate the pre-refactor golden run (``tests/golden/policies.npz``).

The golden file pins, per cache policy, the exact float32 latents and stat
counters produced by a fixed sampling run and a fixed serving trace.  It was
generated from the PRE-plugin-API monolithic ``CachedDiT`` (PR 4 tree), so
``tests/test_policies.py::test_golden_parity`` proves the plugin refactor is
a pure reorganization: every registered pre-existing policy must reproduce
these arrays bitwise.

Regenerate (only when intentionally changing policy numerics — which breaks
the "pure refactor" guarantee and should be called out in the PR):

    PYTHONPATH=src:. python tests/golden/generate.py

Determinism scope: bitwise reproducibility is guaranteed for the pinned jax
version on the same backend (CI: jax[cpu]==0.4.37 on x86-64 Linux).  XLA:CPU
gemms are reduction-order deterministic per (shape, dtype), which is all the
fixed-shape runs below exercise.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_dit
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT, POLICIES
from repro.diffusion import sample
from repro.serving import DiffusionRequest, DiffusionServingEngine

SAMPLE_STEPS = 6
SERVE_STEPS = 5          # serving-engine default plan budget

STAT_KEYS = ("blocks_computed", "blocks_skipped", "steps_reused",
             "motion_frac_sum")


def serving_trace():
    """Mixed-plan staggered trace: mid-flight admission, heterogeneous step
    budgets and guidance scales (1.0 exercises the unguided blend rows)."""
    return [DiffusionRequest(rid=0, label=1, seed=10, arrival_step=0,
                             num_steps=7, guidance_scale=4.0),
            DiffusionRequest(rid=1, label=2, seed=11, arrival_step=2,
                             num_steps=3, guidance_scale=1.0),
            DiffusionRequest(rid=2, label=3, seed=12, arrival_step=3,
                             num_steps=5, guidance_scale=2.0)]


def main() -> None:
    cfg, model, params = build_dit("dit-b2")
    img, ch = cfg.dit.image_size, cfg.dit.in_channels
    noise = jax.random.normal(jax.random.PRNGKey(123), (2, img, img, ch),
                              jnp.float32)
    out = {"policies": np.array(POLICIES)}
    for policy in POLICIES:
        runner = CachedDiT(model, FastCacheConfig(), policy=policy)
        x, state = sample(runner, params, jax.random.PRNGKey(0), batch=2,
                          labels=jnp.array([1, 2]), num_steps=SAMPLE_STEPS,
                          guidance_scale=4.0, x_init=noise)
        out[f"{policy}/sample/latents"] = np.asarray(x)
        for k in STAT_KEYS:
            out[f"{policy}/sample/{k}"] = np.asarray(state["stats"][k])

        runner = CachedDiT(model, FastCacheConfig(), policy=policy)
        eng = DiffusionServingEngine(runner, params, max_slots=2,
                                     num_steps=SERVE_STEPS, max_steps=7)
        done = eng.run(serving_trace())
        assert len(done) == 3
        for r in done:
            out[f"{policy}/serve/latents_rid{r.rid}"] = np.asarray(r.latents)
        cs = eng.cache_stats()
        out[f"{policy}/serve/headline"] = np.array(
            [cs["blocks_skipped"], cs["blocks_computed"],
             cs["steps_reused"]], np.float64)

    path = os.path.join(os.path.dirname(__file__), "policies.npz")
    np.savez_compressed(path, **out)
    print(f"wrote {path}: {len(out)} arrays, "
          f"{os.path.getsize(path) / 1024:.0f} KiB")


if __name__ == "__main__":
    main()
