"""MoE dispatch correctness: capacity dispatch == per-token dense reference
when capacity is ample; overflow drops are bounded; aux loss behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import MoEConfig
from repro.models import build_model
from repro.models.layers import moe_apply, moe_capacity, moe_defs
from repro.models.params import init_params
from tests.conftest import f32_cfg

F32 = jnp.float32


def _dense_reference(p, x, cfg):
    """Route every token through its top-k experts with an explicit loop."""
    m = cfg.moe
    b, s, d = x.shape
    from repro.models.common import rms_norm, swiglu
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    xt = np.asarray(h.reshape(-1, d))
    logits = xt @ np.asarray(p["router"])
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    top_w, top_i = jax.lax.top_k(probs, m.top_k)
    top_w = np.asarray(top_w / top_w.sum(-1, keepdims=True))
    top_i = np.asarray(top_i)
    wg, wu, wd = map(np.asarray, (p["we_gate"], p["we_up"], p["we_down"]))
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        for j in range(m.top_k):
            e = top_i[t, j]
            g = xt[t] @ wg[e]
            u = xt[t] @ wu[e]
            act = (g / (1 + np.exp(-g))) * u
            out[t] += top_w[t, j] * (act @ wd[e])
    return np.asarray(x) + out.reshape(b, s, d)


def test_capacity_dispatch_matches_dense_loop(key):
    cfg = f32_cfg(get_reduced("kimi-k2-1t-a32b")).replace(num_layers=2)
    cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, num_shared_experts=0, capacity_factor=16.0))
    p = init_params(moe_defs(cfg), key, "float32")
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    out, aux = moe_apply(p, x, cfg)
    ref = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(out, ref, atol=2e-4)


def test_capacity_formula():
    m = MoEConfig(num_experts=8, top_k=2, d_ff_expert=64,
                  capacity_factor=1.25, min_capacity=4)
    assert moe_capacity(m, 1024) == int(1.25 * 2 * 1024 / 8)
    assert moe_capacity(m, 8) == 4  # floor


def test_overflow_drops_are_bounded(key):
    """With capacity factor << 1, outputs degrade but stay finite and the
    residual path is preserved."""
    cfg = f32_cfg(get_reduced("arctic-480b"), big_capacity=False)
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=0.1,
                                              min_capacity=1))
    p = init_params(moe_defs(cfg), key, "float32")
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    out, aux = moe_apply(p, x, cfg)
    assert not bool(jnp.isnan(out).any())
    assert float(aux) >= 0.0


def test_aux_loss_uniform_router_near_weight(key):
    """A perfectly uniform router gives aux ~= router_aux_weight."""
    cfg = f32_cfg(get_reduced("kimi-k2-1t-a32b")).replace(num_layers=2)
    p = init_params(moe_defs(cfg), key, "float32")
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])  # uniform probs
    x = jax.random.normal(key, (4, 16, cfg.d_model))
    _, aux = moe_apply(p, x, cfg)
    w = cfg.moe.router_aux_weight
    # E * sum(f_e * p_e) with p uniform = E * (1/E) = 1 -> aux = weight
    assert abs(float(aux) - w) < 0.5 * w


def test_shared_expert_and_dense_parallel_paths(key):
    for arch in ("kimi-k2-1t-a32b", "arctic-480b"):
        cfg = f32_cfg(get_reduced(arch))
        m = build_model(cfg)
        params = m.init(key)
        toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
        h, aux = m.apply(params, {"tokens": toks})
        assert not bool(jnp.isnan(h).any())
        assert float(aux["moe_aux"]) > 0.0


def test_gather_path_matches_capacity_path(key):
    """moe_gather_apply (decode perf path) == capacity dispatch with ample
    capacity — exact same routing and expert math."""
    from repro.models.layers import moe_gather_apply
    cfg = f32_cfg(get_reduced("kimi-k2-1t-a32b")).replace(num_layers=2)
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    p = init_params(moe_defs(cfg), key, "float32")
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, cfg.d_model))
    out_cap, aux_cap = moe_apply(p, x, cfg)
    out_g, aux_g = moe_gather_apply(p, x, cfg)
    np.testing.assert_allclose(out_g, out_cap, atol=2e-4)
