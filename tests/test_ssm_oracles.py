"""SSM mixer oracles: chunkwise == recurrent == naive reference for mLSTM;
chunked associative scan == naive loop for Mamba; sLSTM scan == step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (_slstm_cell, mlstm_sequence, mlstm_step,
                              slstm_apply)
from repro.models.mamba import _chunk_scan

F32 = jnp.float32


def _naive_mlstm(q, k, v, li, lf):
    b, s, h, dh = q.shape
    scale = dh ** -0.5
    c = np.zeros((b, h, dh, dh))
    n = np.zeros((b, h, dh))
    hs = []
    q, k, v, li, lf = map(np.asarray, (q, k, v, li, lf))
    for t in range(s):
        f = np.exp(lf[:, t])
        i = np.exp(li[:, t])
        kk = k[:, t] * scale
        c = (f[..., None, None] * c
             + i[..., None, None] * (kk[..., :, None] * v[:, t][..., None, :]))
        n = f[..., None] * n + i[..., None] * kk
        qq = q[:, t]
        denom = np.maximum(np.abs(np.einsum("bhd,bhd->bh", qq, n)), 1.0)
        hs.append(np.einsum("bhd,bhde->bhe", qq, c) / denom[..., None])
    return np.stack(hs, 1)


@pytest.mark.parametrize("chunk", [1, 3, 4, 12])
def test_mlstm_chunkwise_matches_naive(chunk, key):
    b, s, h, dh = 2, 12, 2, 8
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, dh), F32)
    k = jax.random.normal(ks[1], (b, s, h, dh), F32)
    v = jax.random.normal(ks[2], (b, s, h, dh), F32)
    li = jax.random.normal(ks[3], (b, s, h), F32) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, s, h), F32) + 2.0)
    ref = _naive_mlstm(q, k, v, li, lf)
    st0 = (jnp.zeros((b, h, dh, dh)), jnp.zeros((b, h, dh)),
           jnp.zeros((b, h)))
    hs, _ = mlstm_sequence(q, k, v, li, lf, st0, chunk)
    np.testing.assert_allclose(hs, ref, atol=1e-5)


def test_mlstm_recurrent_matches_chunkwise_state(key):
    b, s, h, dh = 1, 8, 2, 4
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, dh), F32)
    k = jax.random.normal(ks[1], (b, s, h, dh), F32)
    v = jax.random.normal(ks[2], (b, s, h, dh), F32)
    li = jax.random.normal(ks[3], (b, s, h), F32)
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, s, h), F32) + 1.0)
    st0 = (jnp.zeros((b, h, dh, dh)), jnp.zeros((b, h, dh)),
           jnp.zeros((b, h)))
    hs_chunk, st_chunk = mlstm_sequence(q, k, v, li, lf, st0, 4)
    st = st0
    outs = []
    for t in range(s):
        o, st = mlstm_step(q[:, t], k[:, t], v[:, t], li[:, t], lf[:, t], st)
        outs.append(o)
    np.testing.assert_allclose(jnp.stack(outs, 1), hs_chunk, atol=1e-5)
    # continuing decode from the prefill state must be consistent:
    # un-stabilized state C*exp(m) must agree
    for a, b_ in ((st_chunk, st),):
        np.testing.assert_allclose(a[0] * jnp.exp(a[2])[..., None, None],
                                   b_[0] * jnp.exp(b_[2])[..., None, None],
                                   rtol=1e-4, atol=1e-5)


def _naive_mamba(da, dbx, c_mat, h0):
    da, dbx, c_mat = map(np.asarray, (da, dbx, c_mat))
    h = np.asarray(h0).copy()
    ys = []
    for t in range(da.shape[1]):
        h = da[:, t] * h + dbx[:, t]
        ys.append(np.einsum("bis,bs->bi", h, c_mat[:, t]))
    return np.stack(ys, 1), h


def test_mamba_chunk_scan_matches_naive(key):
    b, s, di, ds = 2, 16, 8, 4
    ks = jax.random.split(key, 3)
    da = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, di, ds))) * 0.9
    dbx = jax.random.normal(ks[1], (b, s, di, ds)) * 0.1
    c = jax.random.normal(ks[2], (b, s, ds))
    h0 = jax.random.normal(jax.random.fold_in(key, 9), (b, di, ds))
    y_ref, h_ref = _naive_mamba(da, dbx, c, h0)
    y, h_last = _chunk_scan(da, dbx, c, h0)
    np.testing.assert_allclose(y, y_ref, atol=1e-5)
    np.testing.assert_allclose(h_last, h_ref, atol=1e-5)


def test_slstm_scan_matches_decode_steps(key):
    from repro.configs import get_reduced
    from tests.conftest import f32_cfg
    cfg = f32_cfg(get_reduced("xlstm-1.3b"))
    from repro.models.ssm import slstm_defs, slstm_state_defs
    from repro.models.params import init_params
    p = init_params(slstm_defs(cfg), key, "float32")
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 6, cfg.d_model))
    out_seq, st_seq = slstm_apply(p, x, cfg=cfg, state=None, decode=False)
    st = None
    outs = []
    for t in range(6):
        o, st = slstm_apply(p, x[:, t:t + 1], cfg=cfg, state=st, decode=True)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), out_seq, atol=1e-4)
    for k_ in ("c", "n", "m", "h"):
        np.testing.assert_allclose(st[k_], st_seq[k_], atol=1e-4)
