"""BENCH trajectory plumbing: write_trajectory's (date, config) dedupe
and the bench_check perf-regression gate.  Both are exercised with
synthetic entries (monkeypatched ``trajectory``) — no engine runs, so
these stay cheap enough for the obs CI job.
"""
import json

import pytest

from benchmarks import serving_diffusion as sd
from benchmarks.bench_check import check_regression

pytestmark = pytest.mark.obs


def _entry(date="2026-08-08", seed=0, points=None):
    return {
        "date": date,
        "config": {"dit": "dit-b2", "requests": 6, "seed": seed},
        "points": points or [{"policy": "fastcache", "model_step_ms": 5.0}],
        "metrics_overhead_pct": 1.0,
    }


def test_write_trajectory_dedupes_same_day_same_config(tmp_path,
                                                       monkeypatch):
    path = str(tmp_path / "BENCH.json")
    entries = iter([_entry(), _entry(), _entry(seed=1),
                    _entry(date="2026-08-09")])
    monkeypatch.setattr(sd, "trajectory", lambda **kw: next(entries))

    doc = sd.write_trajectory(path)
    assert len(doc["entries"]) == 1
    # same (date, config): replaces, not appends
    doc = sd.write_trajectory(path)
    assert len(doc["entries"]) == 1
    # same day, different config: a new point
    doc = sd.write_trajectory(path)
    assert len(doc["entries"]) == 2
    # different day, original config: a new point
    doc = sd.write_trajectory(path)
    assert len(doc["entries"]) == 3
    with open(path) as f:
        on_disk = json.load(f)
    assert [e["date"] for e in on_disk["entries"]] \
        == ["2026-08-08", "2026-08-08", "2026-08-09"]
    # the fresh entry is always last (run.py prints entries[-1])
    assert on_disk["entries"][-1]["date"] == "2026-08-09"


def test_write_trajectory_survives_corrupt_prior_file(tmp_path,
                                                      monkeypatch):
    path = tmp_path / "BENCH.json"
    path.write_text("{ not json")
    monkeypatch.setattr(sd, "trajectory", lambda **kw: _entry())
    doc = sd.write_trajectory(str(path))
    assert doc["schema"] == 1 and len(doc["entries"]) == 1


def test_check_regression_gates_only_real_slowdowns():
    base = _entry(points=[
        {"policy": "nocache", "model_step_ms": 10.0},
        {"policy": "fastcache", "model_step_ms": 5.0},
        {"policy": "retired", "model_step_ms": 3.0},
        {"policy": "corrupt", "model_step_ms": 0.0},
    ])
    fresh = _entry(points=[
        {"policy": "nocache", "model_step_ms": 11.0},    # +10%: fine
        {"policy": "fastcache", "model_step_ms": 7.0},   # +40%: gates
        {"policy": "brand_new", "model_step_ms": 99.0},  # no baseline
        {"policy": "corrupt", "model_step_ms": 99.0},    # bad baseline
    ])
    failures = check_regression(base, fresh, max_regress_pct=25.0)
    assert [f["policy"] for f in failures] == ["fastcache"]
    assert failures[0]["regress_pct"] == pytest.approx(40.0)
    # a looser gate passes everything
    assert check_regression(base, fresh, max_regress_pct=50.0) == []
    # speedups never gate
    faster = _entry(points=[{"policy": "fastcache",
                             "model_step_ms": 0.5}])
    assert check_regression(base, faster) == []
