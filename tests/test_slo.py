"""SLO control-plane suite (`make test-slo`).

Pins the serving-under-load contract end to end: priority classes + EDF
ordering on the request queue, rate-modulated (bursty) trace generation,
deadline-aware admission (reject and defer), preempt/resume BITWISE
parity on both engines (the device-side row snapshot must make an
interrupted request indistinguishable from an uninterrupted one),
degradation-ladder hysteresis, and the multi-replica router.

The parity tests reuse ``assert_solo_replay_parity``: a request that was
preempted mid-flight, parked on the queue, and resumed into a (possibly
different) slot must still match its solo ``sample()`` replay bitwise —
the strongest statement that nothing about the snapshot/restore round
trip or the co-resident traffic leaked into its denoising trajectory.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT
from repro.models import build_model
from repro.serving import (AdmissionController, DegradationController,
                           DiffusionRequest, DiffusionServingEngine,
                           ReplicaRouter, RequestQueue,
                           ShardedDiffusionEngine, ShedLevel, SLOScheduler,
                           make_serving_mesh, piecewise_rate, poisson_trace,
                           summarize_by_class, summarize_by_steps)
from repro.serving.slo import REASON_EXPIRED, REASON_UNATTAINABLE
from tests.conftest import assert_solo_replay_parity, f32_cfg

pytestmark = pytest.mark.slo

STEPS = 8


@pytest.fixture(scope="module")
def dit():
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, *, slots=2, fc=None, policy="fastcache"):
    runner = CachedDiT(model, fc or FastCacheConfig(), policy=policy)
    return DiffusionServingEngine(runner, params, max_slots=slots,
                                  num_steps=STEPS, guidance_scale=4.0)


def _drain(eng, done, target):
    guard = 0
    while len(done) < target:
        done += eng.step()
        guard += 1
        if guard > 500:
            raise AssertionError(f"engine stalled: {len(done)}/{target}")
    return done


# -------------------------------------------------------------------------
# trace generation: piecewise rates, bursty mode, priority/deadline mixes
# -------------------------------------------------------------------------

def test_piecewise_rate_boundaries():
    fn = piecewise_rate([(5, 0.5), (10, 2.0), (1e9, 0.25)])
    assert fn(0.0) == 0.5
    assert fn(4.999) == 0.5
    assert fn(5.0) == 2.0      # boundaries belong to the NEXT segment
    assert fn(9.0) == 2.0
    assert fn(10.0) == 0.25
    assert fn(1e6) == 0.25


def test_poisson_trace_deterministic_and_legacy_fields():
    a = poisson_trace(12, 0.5, seed=7, num_classes=10)
    b = poisson_trace(12, 0.5, seed=7, num_classes=10)
    assert [(r.arrival_step, r.label, r.seed) for r in a] \
        == [(r.arrival_step, r.label, r.seed) for r in b]
    # a legacy call (no SLO knobs) leaves the SLO metadata at defaults
    assert all(r.priority == 0 and r.deadline_step is None for r in a)

    mix = poisson_trace(12, 0.5, seed=7, num_classes=10,
                        priority_mix=[0, 1, 1, 2],
                        deadline_slack_mix=[12, 20, 32])
    assert {r.priority for r in mix} <= {0, 1, 2}
    for r in mix:
        assert r.deadline_step is not None
        assert r.deadline_step - r.arrival_step in (12, 20, 32)
    # the new knobs draw EXTRA randomness; arrivals replay the legacy
    # stream bitwise (same rng consumption order up to each request)
    assert [r.arrival_step for r in mix] == [r.arrival_step for r in a]


def test_bursty_trace_compresses_arrivals():
    base, burst = 0.1, 2.0
    fn = piecewise_rate([(10, base), (30, burst), (1e9, base)])
    tr = poisson_trace(24, 0.0, seed=3, num_classes=10, rate_fn=fn)
    assert [r.arrival_step for r in tr] \
        == [r.arrival_step for r in poisson_trace(24, 0.0, seed=3,
                                                  num_classes=10,
                                                  rate_fn=fn)]
    arrivals = np.array([r.arrival_step for r in tr])
    in_burst = ((arrivals >= 10) & (arrivals < 30)).sum()
    # 20 steps at 2.0 req/step dominate the stream: most arrivals land
    # inside the burst window even though it covers a sliver of the axis
    assert in_burst >= len(tr) // 2
    assert (np.diff(arrivals) >= 0).all()


# -------------------------------------------------------------------------
# queue ordering: EDF within a class, strict priority across classes
# -------------------------------------------------------------------------

def _req(rid, *, arrival=0, priority=0, deadline=None, steps=None):
    return DiffusionRequest(rid=rid, label=1, seed=rid, arrival_step=arrival,
                            num_steps=steps, priority=priority,
                            deadline_step=deadline)


def test_edf_orders_by_deadline_and_parks_best_effort_last():
    q = RequestQueue([_req(0, deadline=30), _req(1, deadline=10),
                      _req(2), _req(3, deadline=20)], policy="edf")
    order = [q.pop_arrived(0).rid for _ in range(4)]
    assert order == [1, 3, 0, 2]     # best-effort (no deadline) drains last


def test_priority_classes_are_strict():
    q = RequestQueue([_req(0, priority=2, deadline=5),
                      _req(1, priority=0, deadline=50),
                      _req(2, priority=1, deadline=1)], policy="edf")
    order = [q.pop_arrived(0).rid for _ in range(3)]
    # class 0 first even though its deadline is the loosest
    assert order == [1, 2, 0]
    # not-yet-arrived requests stay invisible to pop/peek/depth
    q2 = RequestQueue([_req(5, arrival=10)], policy="edf")
    assert q2.pop_arrived(0) is None
    assert q2.ready_depth(0) == 0
    assert q2.ready_depth(10) == 1


# -------------------------------------------------------------------------
# summaries must account for rejected requests as a first-class outcome
# -------------------------------------------------------------------------

def test_summaries_with_rejections():
    done = _req(0, steps=8)
    done.finish_step, done.queue_wait_steps = 12, 2
    rej = _req(1, priority=1, deadline=4)    # plan never resolved
    rej.reject_reason = REASON_UNATTAINABLE
    by_steps = summarize_by_steps([done, rej])
    assert by_steps["rejected"]["requests"] == 1
    assert by_steps["8"]["requests"] == 1
    by_class = summarize_by_class([done, rej])
    assert by_class["0"]["finished"] == 1
    assert by_class["1"]["finished"] == 0
    assert by_class["1"]["reject_reasons"] == {REASON_UNATTAINABLE: 1}


# -------------------------------------------------------------------------
# degradation ladder: validation + watermark/patience hysteresis
# -------------------------------------------------------------------------

def test_shed_level_validation():
    with pytest.raises(ValueError):
        ShedLevel("bad", steps_scale=0.0)
    with pytest.raises(ValueError):
        ShedLevel("bad", steps_scale=1.5)
    with pytest.raises(ValueError):
        ShedLevel("bad", capacity_scale=0.0)
    with pytest.raises(ValueError):
        DegradationController(())
    with pytest.raises(ValueError):
        DegradationController(high_watermark=2, low_watermark=2)


def test_degradation_hysteresis_walk():
    ctl = DegradationController(
        (ShedLevel("nominal"), ShedLevel("shed-1", steps_scale=0.5)),
        high_watermark=4, low_watermark=1, patience=3)
    for _ in range(2):
        ctl.observe(10)
    assert ctl.level.name == "nominal"   # patience not yet reached
    ctl.observe(2)                       # mid-band tick resets the streak
    for _ in range(2):
        ctl.observe(10)
    assert ctl.level.name == "nominal"
    ctl.observe(10)
    assert ctl.level.name == "shed-1"    # 3 sustained high ticks escalate
    for _ in range(3):
        ctl.observe(0)
    assert ctl.level.name == "nominal"   # 3 sustained low ticks recover


def test_scale_request_protects_priority_classes():
    ctl = DegradationController(
        (ShedLevel("shed", steps_scale=0.5, min_priority=1),),
        min_steps=2)
    protected = _req(0, priority=0, steps=8)
    ctl.scale_request(protected, default_steps=STEPS)
    assert protected.num_steps == 8
    shed = _req(1, priority=1, steps=8)
    ctl.scale_request(shed, default_steps=STEPS)
    assert shed.num_steps == 4
    floored = _req(2, priority=2, steps=3)
    ctl.scale_request(floored, default_steps=STEPS)
    assert floored.num_steps == 2        # min_steps floor


# -------------------------------------------------------------------------
# preempt/resume bitwise parity (the tentpole contract)
# -------------------------------------------------------------------------

def _preempt_resume_run(eng):
    """Admit two requests, preempt one mid-flight next to its resident,
    let time pass, resume it, and run everything to completion."""
    a = DiffusionRequest(rid=0, label=1, seed=10, arrival_step=0,
                         num_steps=STEPS, guidance_scale=4.0)
    b = DiffusionRequest(rid=1, label=2, seed=11, arrival_step=0,
                         num_steps=STEPS, guidance_scale=4.0)
    assert eng.add_request(a) and eng.add_request(b)
    done = []
    for _ in range(3):
        done += eng.step()
    victim_slot = eng.slots.index(b)
    victim = eng.preempt(victim_slot)
    assert victim is b and b.steps_done == 3 and b.preemptions == 1
    assert eng.slots[victim_slot] is None
    for _ in range(2):                   # resident keeps running solo
        done += eng.step()
    assert eng.add_request(b)            # consumes the snapshot, resumes
    assert b.snapshot is None
    done = _drain(eng, done, 2)
    assert sorted(r.rid for r in done) == [0, 1]
    eng.finalize_requests(done)
    return done


def test_preempt_resume_solo_replay_parity(dit):
    cfg, model, params = dit
    eng = _engine(model, params)
    done = _preempt_resume_run(eng)
    assert_solo_replay_parity(eng, model, params, "fastcache", done)


def test_preempt_resume_parity_with_token_merge(dit):
    """Merge-on: the snapshot must carry the reducer's ``tokred`` rows too
    — a resumed request's merge bookkeeping picks up exactly where the
    preempted run left it."""
    cfg, model, params = dit
    fc = FastCacheConfig(merge_enabled=True, merge_ratio=0.5,
                         merge_window=8)
    eng = _engine(model, params, fc=fc)
    done = _preempt_resume_run(eng)
    assert_solo_replay_parity(eng, model, params, "fastcache", done, fc=fc)


def test_preempt_resume_parity_sharded(dit):
    """Same contract on the sharded engine: the snapshot is a pytree of
    PLACED device buffers and the restore lands it back under the same
    shardings (1x1 mesh here — CPU XLA miscompiles model>1 collectives,
    which the engine's numerics self-check refuses)."""
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    eng = ShardedDiffusionEngine(runner, params, max_slots=2,
                                 num_steps=STEPS, guidance_scale=4.0,
                                 mesh=make_serving_mesh(1, 1))
    done = _preempt_resume_run(eng)
    assert_solo_replay_parity(eng, model, params, "fastcache", done)


# -------------------------------------------------------------------------
# deadline-aware admission: reject and defer
# -------------------------------------------------------------------------

def test_admission_rejects_expired_and_unattainable(dit):
    cfg, model, params = dit
    eng = _engine(model, params, slots=1)
    adm = AdmissionController(eng, on_miss="reject")
    queue = RequestQueue([
        _req(0, steps=STEPS, deadline=9),            # fills the only slot
        _req(1, steps=STEPS, deadline=10),           # finish ~16 > 10
        _req(2, steps=STEPS, deadline=1),            # hopeless even solo
    ], policy="edf")
    admitted = adm.admit_ready(queue)
    assert [r.rid for r in admitted] == [0]
    reasons = {r.rid: r.reject_reason for r in adm.rejected}
    assert reasons == {2: REASON_EXPIRED, 1: REASON_UNATTAINABLE}
    assert len(queue) == 0


def test_admission_defer_parks_without_touching_arrival(dit):
    cfg, model, params = dit
    eng = _engine(model, params, slots=1)
    adm = AdmissionController(eng, on_miss="defer", defer_steps=2,
                              max_defers=1)
    blocker = _req(0, steps=STEPS, deadline=8)   # EDF-first, feasible
    hopeful = _req(1, steps=STEPS, deadline=10)
    queue = RequestQueue([blocker, hopeful], policy="edf")
    adm.admit_ready(queue)
    assert adm.pending_deferred == 1 and not adm.rejected
    assert hopeful.arrival_step == 0     # latency accounting untouched
    eng.step()                           # clock reaches the retry step
    eng.step()
    adm.admit_ready(queue)               # defer budget exhausted -> reject
    assert adm.pending_deferred == 0
    assert [r.rid for r in adm.rejected] == [1]
    assert hopeful.reject_reason == REASON_UNATTAINABLE


# -------------------------------------------------------------------------
# SLOScheduler end to end: shedding + priority preemption + parity
# -------------------------------------------------------------------------

def test_slo_scheduler_priority_preemption_end_to_end(dit):
    cfg, model, params = dit
    eng = _engine(model, params, slots=2)
    trace = [
        DiffusionRequest(rid=0, label=1, seed=20, arrival_step=0,
                         num_steps=STEPS, guidance_scale=4.0, priority=2),
        DiffusionRequest(rid=1, label=2, seed=21, arrival_step=0,
                         num_steps=STEPS, guidance_scale=4.0, priority=2),
        DiffusionRequest(rid=2, label=3, seed=22, arrival_step=2,
                         num_steps=4, guidance_scale=4.0, priority=0,
                         deadline_step=12),
    ]
    sched = SLOScheduler(eng, sched_policy="edf")
    done = sched.run(trace)
    assert sorted(r.rid for r in done) == [0, 1, 2] and not sched.rejected
    assert sum(r.preemptions for r in done) >= 1
    urgent = next(r for r in done if r.rid == 2)
    assert urgent.preemptions == 0       # the preemptOR, not a victim
    assert urgent.finish_step <= urgent.deadline_step
    assert all(r.queue_wait_steps >= 0 for r in done)
    # the interrupted low-priority runs still replay solo bitwise
    assert_solo_replay_parity(eng, model, params, "fastcache", done)


# -------------------------------------------------------------------------
# multi-replica router
# -------------------------------------------------------------------------

def test_router_validation():
    with pytest.raises(ValueError):
        ReplicaRouter([])
    with pytest.raises(TypeError):
        ReplicaRouter([object()])


def test_router_jsq_and_affinity_end_to_end(dit):
    cfg, model, params = dit
    scheds = [SLOScheduler(_engine(model, params, slots=1),
                           sched_policy="edf") for _ in range(2)]
    router = ReplicaRouter(scheds, affinity={0: 1})
    trace = [
        DiffusionRequest(rid=0, label=1, seed=30, arrival_step=0,
                         num_steps=4, guidance_scale=4.0, priority=1),
        DiffusionRequest(rid=1, label=2, seed=31, arrival_step=0,
                         num_steps=4, guidance_scale=4.0, priority=1),
        DiffusionRequest(rid=2, label=3, seed=32, arrival_step=1,
                         num_steps=4, guidance_scale=4.0, priority=0),
        DiffusionRequest(rid=3, label=4, seed=33, arrival_step=1,
                         num_steps=4, guidance_scale=4.0, priority=0),
    ]
    with pytest.raises(TypeError):
        router.run(RequestQueue(trace, policy="edf"))
    done = router.run(trace)
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    # JSQ spreads the simultaneous best-effort pair across both replicas
    assert {router.dispatched[0], router.dispatched[1]} == {0, 1}
    # class 0 is pinned to replica 1 and neither replica is overloaded
    # enough to break the soft affinity
    assert router.dispatched[2] == 1 and router.dispatched[3] == 1
    for sched in scheds:
        mine = [r for r in done if router.dispatched[r.rid]
                == scheds.index(sched)]
        assert_solo_replay_parity(sched.engine, model, params,
                                  "fastcache", mine)
