"""Shadow-compute audit plane (obs/audit.py): schedule determinism, error
measurement against the true forward, chi^2 bound accounting, drift/burn
summaries, and the host-side report.

The module fixture perturbs ``model.init`` params: DiT's adaLN-zero init
makes every block the identity and the zero-init head makes eps == 0
identically, so an unperturbed model has *exactly zero* end-to-end error
under any policy — useless for exercising the audit plane.  A small
seeded perturbation (0.02) keeps fastcache's gates firing (blocks
actually skip) while its measured error stays well inside the Eq. 9
chi^2 bound — which is precisely the acceptance criterion the
``test_fastcache_respects_chi2_bound`` case pins down.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT
from repro.core.policies import base as policies_base
from repro.core.policies.fastcache import FastCache
from repro.models import build_model
from repro.obs import MetricsCollector, audit_mask, audit_report
from repro.obs import audit as obs_audit
from repro.obs import metrics as obs_metrics
from repro.serving import DiffusionRequest, DiffusionServingEngine
from tests.conftest import f32_cfg

pytestmark = pytest.mark.obs


@pytest.fixture(scope="module")
def dit():
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # break the adaLN-zero / zero-head degeneracy (see module docstring)
    leaves, tdef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(42), len(leaves))
    leaves = [p + 0.02 * jax.random.normal(k, p.shape, p.dtype)
              for p, k in zip(leaves, keys)]
    return cfg, model, jax.tree.unflatten(tdef, leaves)


def _serve(runner, params, *, audit_fraction, num_steps=16, requests=2,
           audit_seed=0, collector=None):
    collector = collector or MetricsCollector()
    eng = DiffusionServingEngine(runner, params, max_slots=2,
                                 num_steps=num_steps, collector=collector,
                                 audit_fraction=audit_fraction,
                                 audit_seed=audit_seed)
    for i in range(requests):
        assert eng.add_request(DiffusionRequest(
            rid=i, label=i + 1, seed=10 + i, arrival_step=0,
            num_steps=num_steps))
    done = []
    for _ in range(10 * num_steps):
        done += eng.step()
        if len(done) == requests:
            break
    assert len(done) == requests
    return eng, done


# ---------------------------------------------------------------------------
# Schedule
# ---------------------------------------------------------------------------

def test_audit_mask_edges_and_determinism():
    assert not audit_mask(5, 0.0) and not audit_mask(5, -1.0)
    assert audit_mask(5, 1.0) and audit_mask(0, 2.0)
    picks = [audit_mask(s, 0.25, seed=7) for s in range(4096)]
    assert picks == [audit_mask(s, 0.25, seed=7) for s in range(4096)]
    rate = sum(picks) / len(picks)
    assert rate == 0.25, f"stratified rate {rate} must be exactly 0.25"
    # stratification: exactly one audited step per 4-step window, so the
    # realized rate matches the nominal fraction over ANY horizon
    assert all(sum(picks[w:w + 4]) == 1 for w in range(0, 4096, 4))
    # a different seed reshuffles which steps are audited
    other = [audit_mask(s, 0.25, seed=8) for s in range(4096)]
    assert other != picks


def test_rel_err_shapes_and_values():
    a = jnp.ones((3, 4, 5))
    assert np.allclose(np.asarray(obs_audit.rel_err_rows(a, a)), 0.0)
    b = a.at[0].multiply(2.0)
    err = np.asarray(obs_audit.rel_err_rows(b, a))
    assert err.shape == (3,)
    assert np.isclose(err[0], 1.0) and np.allclose(err[1:], 0.0)
    # zero reference rows clamp the denominator instead of dividing by 0
    z = jnp.zeros((2, 4))
    assert np.all(np.isfinite(np.asarray(obs_audit.rel_err_rows(z, z))))
    stack = jnp.ones((3, 2, 4, 5))
    lerr = np.asarray(obs_audit.layer_rel_err(stack * 1.5, stack))
    assert lerr.shape == (3, 2) and np.allclose(lerr, 0.5)


# ---------------------------------------------------------------------------
# The acceptance-criteria pair: bound respected / bound tripped
# ---------------------------------------------------------------------------

def test_fastcache_respects_chi2_bound(dit):
    """Seeded end-to-end: fastcache actually caches (blocks skip), the
    measured audited error is nonzero and finite, and every audited
    slot-step respects the policy's Eq. 9 chi^2-predicted bound."""
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    bound = runner.audit_bound()
    nd = runner.impl.capacity * cfg.d_model
    assert bound is not None and 1.0 < bound < 1.1  # chi2(0.95, nd)/nd
    assert nd == runner.impl.capacity * cfg.d_model

    col = MetricsCollector()
    eng, done = _serve(runner, params, audit_fraction=1.0, collector=col)
    w = eng.harvest_metrics()
    c = w["counters"]
    assert c[obs_metrics.AUDIT_STEPS] == eng.model_steps
    assert c[obs_metrics.AUDIT_SLOT_STEPS] > 0
    assert c["blocks_skipped_total"] > 0, "gates never fired: nothing cached"
    h = w["histograms"]["audit_rel_err"]
    assert h["count"] == c[obs_metrics.AUDIT_SLOT_STEPS]
    assert h["sum"] > 0.0, "audited error must be nonzero once blocks skip"
    assert c[obs_metrics.BOUND_VIOLATIONS] == 0.0, \
        "fastcache exceeded its own chi^2 bound"
    # per-request budgets harvested into req.cache
    for r in done:
        assert float(r.cache[obs_audit.ACC_STEPS]) == 16.0
        assert float(r.cache[obs_audit.ACC_ERR_SUM]) > 0.0
        assert float(r.cache[obs_audit.ACC_VIOLATIONS]) == 0.0
    # per-layer error accumulated for the L+1 cached hidden stack
    assert "audit" in w
    layer_mean = w["audit"]["layer_err_mean"]
    assert len(layer_mean) == runner.L + 1
    assert all(np.isfinite(layer_mean)) and max(layer_mean) > 0.0
    # window summary: burn rate is err_mean / bound, strictly inside budget
    assert 0.0 < w["audit"]["burn_rate_window"] < 1.0
    assert w["audit"]["violation_rate_window"] == 0.0


def test_misthresholded_policy_trips_bound_violations(dit):
    """A policy claiming an absurdly tight error bound must rack up
    ``bound_violations_total``: same fastcache execution, but
    ``predicted_error_bound`` overridden to 1e-6."""
    cfg, model, params = dit

    @policies_base.register("_audit_badbound")
    class BadBound(FastCache):
        def predicted_error_bound(self):
            return 1e-6

    try:
        runner = CachedDiT(model, FastCacheConfig(),
                           policy="_audit_badbound")
        assert runner.audit_bound() == 1e-6
        eng, done = _serve(runner, params, audit_fraction=1.0)
        w = eng.harvest_metrics()
        assert w["counters"][obs_metrics.BOUND_VIOLATIONS] > 0.0
        assert sum(float(r.cache[obs_audit.ACC_VIOLATIONS])
                   for r in done) \
            == w["counters"][obs_metrics.BOUND_VIOLATIONS]
        assert w["audit"]["violation_rate_window"] > 0.0
    finally:
        del policies_base._REGISTRY["_audit_badbound"]


def test_nocache_audits_exactly_zero(dit):
    """nocache computes the true forward every step, so the shadow audit
    must measure (bitwise) zero error and no hidden-stack group."""
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="nocache")
    assert runner.audit_bound() is None
    eng, done = _serve(runner, params, audit_fraction=1.0, num_steps=8)
    w = eng.harvest_metrics()
    h = w["histograms"]["audit_rel_err"]
    assert h["count"] > 0 and h["sum"] == 0.0
    assert w["counters"][obs_metrics.BOUND_VIOLATIONS] == 0.0
    for r in done:
        assert float(r.cache[obs_audit.ACC_ERR_SUM]) == 0.0


def test_sampled_schedule_audits_subset(dit):
    """fraction=0.5: the engine audits exactly the host-hash-selected
    steps — reproducible across runs with the same seed."""
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    eng, _ = _serve(runner, params, audit_fraction=0.5, audit_seed=3,
                    num_steps=8)
    w = eng.harvest_metrics()
    audited = w["counters"][obs_metrics.AUDIT_STEPS]
    expect = sum(audit_mask(s, 0.5, seed=3)
                 for s in range(eng.model_steps))
    assert audited == expect
    assert 0 < audited < eng.model_steps


# ---------------------------------------------------------------------------
# Collector: drift, burn, exports, quantiles
# ---------------------------------------------------------------------------

def test_drift_ratio_against_synthetic_baseline(dit):
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    col = MetricsCollector()
    # baseline (L, T): the calibration recorder's nocache inter-step
    # deltas; step 0 is its forced-1.0 column and is excluded
    baseline = np.full((runner.L + 1, 16), 0.05, np.float64)
    baseline[:, 0] = 1.0
    col.set_audit_context(baseline=baseline)
    eng, _ = _serve(runner, params, audit_fraction=1.0, collector=col)
    eng.harvest_metrics()
    w = col.windows[-1]
    assert "drift_ratio" in w["audit"]
    measured = float(np.mean(w["audit"]["layer_err_mean"][1:]))
    assert np.isclose(w["audit"]["drift_ratio"], measured / 0.05,
                      rtol=1e-6)
    assert w["gauges"]["audit_drift_ratio"] == w["audit"]["drift_ratio"]
    with pytest.raises(ValueError):
        col.set_audit_context(baseline=np.zeros((3,)))


def test_audit_gauges_export_and_quantiles(dit):
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    col = MetricsCollector(labels={"policy": "fastcache"})
    eng, _ = _serve(runner, params, audit_fraction=1.0, collector=col)
    eng.harvest_metrics()
    text = col.to_prometheus()
    parsed = obs_metrics.parse_prometheus(text)
    for g in ("audit_err_mean_window", "audit_burn_rate_window",
              "audit_violation_rate_window"):
        full = f"repro_{g}"      # the exporter's namespace prefix
        assert full in parsed and parsed[full]["samples"], f"missing {g}"
    assert "audit_rel_err_bucket" in text
    # JSONL windows carry the audit section verbatim
    lines = [json.loads(ln) for ln in col.to_jsonl().splitlines()]
    assert any("audit" in ln for ln in lines)
    p50 = col.quantile("audit_rel_err", 0.50)
    p95 = col.quantile("audit_rel_err", 0.95)
    assert 0.0 <= p50 <= p95


def test_histogram_quantile_interpolation():
    buckets = (1.0, 2.0, 4.0)
    # counts per bin (le=1, le=2, le=4, +Inf)
    counts = (0.0, 10.0, 0.0, 0.0)
    q = obs_metrics.histogram_quantile(buckets, counts, 0.5)
    assert 1.0 <= q <= 2.0
    # all mass in the overflow bin clamps to the last finite bound
    assert obs_metrics.histogram_quantile(buckets, (0, 0, 0, 5), 0.9) \
        == 4.0
    assert obs_metrics.histogram_quantile(buckets, (0, 0, 0, 0), 0.9) \
        == 0.0


# ---------------------------------------------------------------------------
# Host-side report
# ---------------------------------------------------------------------------

def test_request_budget_and_report(dit):
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    col = MetricsCollector()
    eng, done = _serve(runner, params, audit_fraction=1.0, collector=col)
    eng.harvest_metrics()
    budget = obs_audit.request_budget(done[0].cache)
    assert budget["audited_steps"] == 16.0
    assert budget["err_mean"] > 0.0 and budget["err_std"] >= 0.0
    doc = audit_report(done, fraction=1.0, bound=runner.audit_bound(),
                       collector=col)
    assert doc["predicted_bound"] == runner.audit_bound()
    assert len(doc["requests"]) == len(done)
    assert doc["violations_total"] == 0.0
    assert "window" in doc and "burn_rate_window" in doc["window"]
    json.dumps(doc)  # must be JSON-serializable as written by --audit-out
    # empty-cache requests (audit off / never audited) summarize to zeros
    assert obs_audit.request_budget({})["audited_steps"] == 0.0


def test_audit_requires_metrics_plane(dit):
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    with pytest.raises(ValueError, match="metrics"):
        DiffusionServingEngine(runner, params, max_slots=2, num_steps=8,
                               enable_metrics=False, audit_fraction=0.5)
    with pytest.raises(ValueError, match="audit_fraction"):
        DiffusionServingEngine(runner, params, max_slots=2, num_steps=8,
                               audit_fraction=1.5)
