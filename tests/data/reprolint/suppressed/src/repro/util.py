"""Fixture: the same bare assert, waived with the escape hatch."""


def check_window(n: int, window: int) -> int:
    assert n % window == 0  # reprolint: disable=no-bare-assert
    return n // window
