"""Fixture: a Pallas kernel with NO ref.py twin (kernel-parity must
fire: missing reference)."""
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def myk(x):
    return pl.pallas_call(_kernel, out_shape=x)(x)
