"""Fixture reference module: has `other` and `merge_assign`, lacks
`myk` and `unmerge_scatter`."""


def other(x):
    return x + 1.0


def merge_assign(h, s):
    return h * s
