"""Fixture reference module: has `other`, lacks `myk`."""


def other(x):
    return x + 1.0
