"""Fixture: a Pallas kernel whose ref twin exists but is never compared
by any test (kernel-parity must fire: missing parity test)."""
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] + 1.0


def other(x):
    return pl.pallas_call(_kernel, out_shape=x)(x)
