"""Fixture: a token-merge-style kernel module with TWO public entries —
kernel-parity must check each independently.  ``merge_assign`` has a ref
twin but no parity test (must fire: unverified); ``unmerge_scatter`` has
no twin at all (must fire: missing reference)."""
from jax.experimental import pallas as pl


def _merge_kernel(h_ref, s_ref, o_ref):
    o_ref[...] = h_ref[...] * s_ref[...]


def _scatter_kernel(m_ref, o_ref):
    o_ref[...] = m_ref[...]


def merge_assign(h, s):  # LINT: kernel-parity
    return pl.pallas_call(_merge_kernel, out_shape=h)(h, s)


def unmerge_scatter(merged):  # LINT: kernel-parity
    return pl.pallas_call(_scatter_kernel, out_shape=merged)(merged)
