"""Fixture: a float() host sync on a traced value inside a jitted function
(host-sync-in-jit must fire), reached through a module-level jax.jit."""
import jax
import jax.numpy as jnp


def _impl(x: jax.Array):
    s = jnp.sum(x)
    return float(s)  # LINT: host-sync-in-jit


step = jax.jit(_impl)
