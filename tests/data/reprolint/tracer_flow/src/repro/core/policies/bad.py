"""Fixture: Python `if` on a traced value inside a policy `step`
(tracer-control-flow must fire; `step` is a protocol jit root)."""
import jax
import jax.numpy as jnp


class BadPolicy:
    def step(self, params, state, x_in: jax.Array, c):
        delta = jnp.mean(x_in)
        if delta > 0.5:  # LINT: tracer-control-flow
            return x_in, state
        return x_in * 2.0, state
