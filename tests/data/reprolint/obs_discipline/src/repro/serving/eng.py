"""Fixture: ``MetricsCollector.harvest`` called inside a jitted step —
obs-discipline must fire at the call site (and at the now-jit-reachable
harvest definition in the fixture obs module).  The audit-plane calls
exercise rule 3: the guarded call (inside ``if self._audit_on:``) is
fine, the bare one must be flagged."""
import jax
import jax.numpy as jnp

from repro.obs import audit as obs_audit
from repro.obs.metrics import MetricsCollector, counter

# an engine re-registering a token counter instead of reusing the obs
# module's exported one: rule 1 must flag the second site
TOK = counter("tokens_kept_total")  # LINT: obs-discipline


def _impl(x: jax.Array, collector: MetricsCollector):
    s = jnp.sum(x)
    collector.harvest()  # LINT: obs-discipline
    return s


step = jax.jit(_impl)


class Engine:
    def __init__(self, audit_fraction: float = 0.0):
        self._audit_on = audit_fraction > 0.0

    def _serve_step_impl(self, metrics, x):
        if self._audit_on:
            metrics = obs_audit.apply_audit(metrics, x)  # guarded: ok
        metrics = obs_audit.apply_audit(metrics, x)  # LINT: obs-discipline
        return metrics, jnp.sum(x)

    def step(self, metrics, x):
        fn = jax.jit(self._serve_step_impl)
        return fn(metrics, x)
