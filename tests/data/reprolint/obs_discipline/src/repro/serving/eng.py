"""Fixture: ``MetricsCollector.harvest`` called inside a jitted step —
obs-discipline must fire at the call site (and at the now-jit-reachable
harvest definition in the fixture obs module)."""
import jax
import jax.numpy as jnp

from repro.obs.metrics import MetricsCollector


def _impl(x: jax.Array, collector: MetricsCollector):
    s = jnp.sum(x)
    collector.harvest()  # LINT: obs-discipline
    return s


step = jax.jit(_impl)
