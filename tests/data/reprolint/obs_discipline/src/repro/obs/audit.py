"""Fixture audit module: the shadow-compute plane whose call sites rule 3
of obs-discipline polices — calls into here from jit-reachable code must
sit under a static ``if <audit flag>:`` guard."""
import jax.numpy as jnp


def apply_audit(metrics, x):
    return {**metrics, "audit_err": metrics["audit_err"] + jnp.sum(x)}


def audit_mask(step: int, fraction: float) -> bool:
    return fraction > 0.0 and step % max(1, int(1.0 / fraction)) == 0
