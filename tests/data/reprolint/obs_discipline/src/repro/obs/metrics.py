"""Fixture obs metrics module: the registration helpers and collector the
obs-discipline check recognizes structurally.  One duplicate registration
(the second ``steps_total``) and a harvest method made jit-reachable by
``serving/eng.py`` — both must be flagged."""
METRICS = {}


def counter(name: str, help: str = "") -> str:
    METRICS[name] = ("counter", help)
    return name


def histogram(name: str, buckets=(1, 2, 4)) -> str:
    METRICS[name] = ("histogram", buckets)
    return name


class MetricsCollector:
    def harvest(self, device_metrics=None):  # LINT: obs-discipline
        return dict(METRICS)


STEPS = counter("steps_total")
LATENCY = histogram("latency_steps")
TOKENS_KEPT = counter("tokens_kept_total")
DUP = counter("steps_total")  # LINT: obs-discipline
