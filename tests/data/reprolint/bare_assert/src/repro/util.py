"""Fixture: a bare assert in library code (no-bare-assert must fire)."""


def check_window(n: int, window: int) -> int:
    assert n % window == 0  # LINT: no-bare-assert
    return n // window
